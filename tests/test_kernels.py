"""Per-kernel correctness sweeps: shapes x dtypes, assert_allclose against
the pure-jnp oracles, executed with pallas interpret=True on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6.kernel import rwkv6_chunked_bhtd
from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.kernels.ssd.kernel import ssd_chunked_bhtp
from repro.kernels.ssd.ref import ssd_ref


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,h,kvh,s,hd,bq,bkv",
        [
            (1, 2, 2, 64, 32, 32, 32),     # MHA
            (2, 4, 2, 128, 64, 64, 32),    # GQA 2:1
            (1, 8, 1, 128, 32, 32, 64),    # MQA
            (2, 2, 2, 96, 32, 32, 32),     # padding (96 % 32 == 0, 3 blocks)
            (1, 2, 2, 80, 32, 32, 32),     # ragged q padding
        ],
    )
    def test_causal_matches_ref(self, b, h, kvh, s, hd, bq, bkv):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, kvh, s, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, kvh, s, hd), jnp.float32)
        out = flash_attention_bhsd(
            q, k, v, scale=hd**-0.5, block_q=bq, block_kv=bkv, interpret=True
        )
        ref = attention_ref(q, k, v, scale=hd**-0.5)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [16, 48, 100])
    def test_sliding_window(self, window):
        b, h, s, hd = 1, 2, 128, 32
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, h, s, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, h, s, hd), jnp.float32)
        out = flash_attention_bhsd(
            q, k, v, scale=hd**-0.5, window=window,
            block_q=32, block_kv=32, interpret=True,
        )
        ref = attention_ref(q, k, v, scale=hd**-0.5, window=window)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_bf16_inputs(self):
        b, h, s, hd = 1, 2, 64, 32
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (b, h, s, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, h, s, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, h, s, hd), jnp.bfloat16)
        out = flash_attention_bhsd(
            q, k, v, scale=hd**-0.5, block_q=32, block_kv=32, interpret=True
        )
        ref = attention_ref(q, k, v, scale=hd**-0.5)
        np.testing.assert_allclose(
            out.astype(np.float32), ref.astype(np.float32), atol=2e-2
        )


def _rwkv_inputs(key, b, h, t, dk, dv, decay_sharpness=2.0):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, h, t, dk))
    k = jax.random.normal(ks[1], (b, h, t, dk))
    v = jax.random.normal(ks[2], (b, h, t, dv))
    # Realistic decays near 1 (w = exp(-exp(ww)), ww ~ N(-decay_sharpness,1))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, h, t, dk)) - decay_sharpness))
    u = 0.1 * jax.random.normal(ks[4], (h, dk))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, dk, dv))
    return r, k, v, w, u, s0


class TestRWKV6Kernel:
    @pytest.mark.parametrize(
        "b,h,t,d,chunk",
        [(1, 1, 32, 8, 16), (2, 3, 100, 16, 32), (1, 2, 64, 32, 64),
         (2, 2, 65, 16, 32)],  # ragged chunk padding
    )
    def test_matches_sequential_ref(self, b, h, t, d, chunk):
        r, k, v, w, u, s0 = _rwkv_inputs(jax.random.PRNGKey(0), b, h, t, d, d)
        out, s = rwkv6_chunked_bhtd(r, k, v, w, u, s0, chunk=chunk,
                                    interpret=True)
        out_r, s_r = rwkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(out, out_r, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(s, s_r, atol=1e-4, rtol=1e-3)

    def test_state_carries_across_calls(self):
        """Two chunked calls == one long call (streaming decode parity)."""
        b, h, t, d = 1, 2, 64, 16
        r, k, v, w, u, s0 = _rwkv_inputs(jax.random.PRNGKey(1), b, h, t, d, d)
        full, s_full = rwkv6_chunked_bhtd(r, k, v, w, u, s0, chunk=16,
                                          interpret=True)
        half = t // 2
        o1, s1 = rwkv6_chunked_bhtd(
            r[:, :, :half], k[:, :, :half], v[:, :, :half], w[:, :, :half],
            u, s0, chunk=16, interpret=True,
        )
        o2, s2 = rwkv6_chunked_bhtd(
            r[:, :, half:], k[:, :, half:], v[:, :, half:], w[:, :, half:],
            u, s1, chunk=16, interpret=True,
        )
        np.testing.assert_allclose(
            jnp.concatenate([o1, o2], axis=2), full, atol=1e-3, rtol=1e-3
        )
        np.testing.assert_allclose(s2, s_full, atol=1e-4, rtol=1e-3)


def _ssd_inputs(key, b, h, t, p, n):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, h, t, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, t)))
    a = jnp.exp(-jnp.exp(jax.random.normal(ks[2], (b, h, t)) - 1.0) * dt)
    B = jax.random.normal(ks[3], (b, t, n))
    C = jax.random.normal(ks[4], (b, t, n))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, p, n))
    return x, dt, a, B, C, s0


class TestSSDKernel:
    @pytest.mark.parametrize(
        "b,h,t,p,n,chunk",
        [(1, 1, 32, 8, 8, 16), (2, 3, 100, 16, 8, 32), (1, 2, 64, 32, 16, 64),
         (1, 2, 70, 16, 8, 32)],
    )
    def test_matches_sequential_ref(self, b, h, t, p, n, chunk):
        x, dt, a, B, C, s0 = _ssd_inputs(jax.random.PRNGKey(0), b, h, t, p, n)
        y, s = ssd_chunked_bhtp(x, dt, a, B, C, s0, chunk=chunk, interpret=True)
        y_r, s_r = ssd_ref(x, dt, a, B, C, s0)
        np.testing.assert_allclose(y, y_r, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(s, s_r, atol=1e-4, rtol=1e-3)

    def test_state_carries_across_calls(self):
        b, h, t, p, n = 1, 2, 64, 16, 8
        x, dt, a, B, C, s0 = _ssd_inputs(jax.random.PRNGKey(1), b, h, t, p, n)
        full, s_full = ssd_chunked_bhtp(x, dt, a, B, C, s0, chunk=16,
                                        interpret=True)
        half = t // 2
        y1, s1 = ssd_chunked_bhtp(
            x[:, :, :half], dt[:, :, :half], a[:, :, :half],
            B[:, :half], C[:, :half], s0, chunk=16, interpret=True,
        )
        y2, s2 = ssd_chunked_bhtp(
            x[:, :, half:], dt[:, :, half:], a[:, :, half:],
            B[:, half:], C[:, half:], s1, chunk=16, interpret=True,
        )
        np.testing.assert_allclose(
            jnp.concatenate([y1, y2], axis=2), full, atol=1e-3, rtol=1e-3
        )
        np.testing.assert_allclose(s2, s_full, atol=1e-4, rtol=1e-3)


class TestModelScansVsRefs:
    """The model-level chunked scans (used when the Pallas kernel is off)
    must match the sequential refs too."""

    def test_rwkv_model_chunked(self):
        from repro.models.rwkv import rwkv_scan_chunked, rwkv_scan_ref

        b, t, h, d = 2, 50, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        r = jax.random.normal(ks[0], (b, t, h, d))
        k = jax.random.normal(ks[1], (b, t, h, d))
        v = jax.random.normal(ks[2], (b, t, h, d))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, d)) - 2))
        u = 0.1 * jax.random.normal(ks[4], (h, d))
        s0 = 0.1 * jax.random.normal(ks[5], (b, h, d, d))
        o1, s1 = rwkv_scan_ref(r, k, v, w, u, s0)
        o2, s2 = rwkv_scan_chunked(r, k, v, w, u, s0, chunk=16)
        np.testing.assert_allclose(o1, o2, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-3)

    def test_ssd_model_chunked(self):
        from repro.models.ssd import ssd_scan_chunked, ssd_scan_ref

        b, t, h, p, n = 2, 50, 2, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(4), 6)
        x = jax.random.normal(ks[0], (b, t, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
        a = jnp.exp(-jnp.exp(jax.random.normal(ks[2], (b, t, h)) - 1) * dt)
        B = jax.random.normal(ks[3], (b, t, n))
        C = jax.random.normal(ks[4], (b, t, n))
        s0 = 0.1 * jax.random.normal(ks[5], (b, h, p, n))
        y1, s1 = ssd_scan_ref(x, dt, a, B, C, s0)
        y2, s2 = ssd_scan_chunked(x, dt, a, B, C, s0, chunk=16)
        np.testing.assert_allclose(y1, y2, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-3)
