"""SWIM-style workload synthesis (Sect. 4.1).

The paper uses SWIM [9] to synthesize a 100-job workload from Facebook
production traces (the *FB-dataset*):

* 53 *small* jobs — 75% with a single MAP task, 25% with 2 MAP tasks;
* 41 *medium* jobs — 5..500 MAP tasks; half with no REDUCE tasks, the rest
  with 2..100 REDUCE tasks;
* 6 *large* jobs — 2 with ~3000 MAP tasks and no REDUCE tasks, 3 with
  700..1500 MAP and 150..250 REDUCE tasks, 1 with 200 MAP and 1000 REDUCE
  tasks;
* Poisson arrivals: exponential inter-arrival times with mean 13 s
  (submission schedule ~22 min).

Task runtimes: the paper's experiments use I/O-bound jobs with *no skew in
task size distributions* (Sect. 4.1 "Individual jobs") — MAP tasks are
"generally stable and short" [31, 9].  We draw a per-job mean MAP task time
and apply a small configurable jitter; REDUCE tasks are longer (they carry
shuffle+sort+reduce work for a whole partition).

``ml_dataset`` synthesizes the TPU-adaptation analogue: jobs are train/serve
runs of the assigned architectures, tasks are step quanta (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.core.types import ClusterSpec, JobSpec, Phase, TaskSpec

FB_CLASSES = ("small", "medium", "large")

# The paper's FB-dataset composition (Sect. 4.1), shared by fb_dataset and
# fb_scaled_dataset so the stress workload can never drift from the
# fidelity workload's class mix.
FB_CLASS_COUNTS = {"small": 53, "medium": 41, "large": 6}  # per 100 jobs
FB_LARGE_TEMPLATES = (
    (3000, 0), (3000, 0), (700, 150), (1100, 200), (1500, 250), (200, 1000),
)


def _fb_class_sizes(num_jobs: int) -> tuple[int, int, int]:
    """(n_small, n_medium, n_large) for a scaled FB-dataset."""
    scale = num_jobs / 100.0
    return (
        max(1, round(FB_CLASS_COUNTS["small"] * scale)),
        max(1, round(FB_CLASS_COUNTS["medium"] * scale)),
        max(1, round(FB_CLASS_COUNTS["large"] * scale)),
    )


@dataclass
class WorkloadSpec:
    """Knobs for the synthetic FB-dataset."""

    num_machines: int = 100
    replication: int = 3           # HDFS replication factor (Sect. 4.1)
    mean_interarrival: float = 13.0
    map_time_lo: float = 15.0      # per-job mean MAP task runtime range (s)
    map_time_hi: float = 60.0
    # REDUCE runtimes: the paper gives none; these keep reduce phases
    # within ~2-3x of map phases ("I/O-intensive only", no pathological
    # serialized-size inversions between map-count classes).
    reduce_time_lo: float = 30.0   # per-job mean REDUCE task runtime range (s)
    reduce_time_hi: float = 150.0
    task_jitter: float = 0.0       # intra-job task-time skew (0 = none, paper)
    map_state_bytes: int = 256 << 20    # working set per task (preemption cost)
    reduce_state_bytes: int = 1 << 30
    reduce_slowstart: float = 1.0


def job_class(num_map: int) -> str:
    """The paper's size classes (Sect. 4.1)."""
    if num_map <= 2:
        return "small"
    if num_map <= 500:
        return "medium"
    return "large"


def _mk_tasks(
    rng: np.random.Generator,
    job_id: int,
    phase: Phase,
    n: int,
    mean_time: float,
    jitter: float,
    state_bytes: int,
    num_machines: int,
    replication: int,
) -> tuple[TaskSpec, ...]:
    if n == 0:
        return ()
    if jitter > 0:
        times = mean_time * rng.lognormal(0.0, jitter, size=n)
    else:
        times = np.full(n, mean_time)
    tasks = []
    for i in range(n):
        hosts = tuple(
            int(h)
            for h in rng.choice(
                num_machines, size=min(replication, num_machines), replace=False
            )
        )
        tasks.append(
            TaskSpec(
                job_id=job_id,
                phase=phase,
                index=i,
                duration=float(max(times[i], 1.0)),
                input_hosts=hosts if phase is Phase.MAP else (),
                state_bytes=state_bytes,
            )
        )
    return tuple(tasks)


def fb_dataset(
    seed: int = 0,
    spec: WorkloadSpec | None = None,
    num_jobs: int = 100,
) -> tuple[list[JobSpec], dict[int, str]]:
    """Generate the FB-dataset-like workload.  Returns (jobs, class_of)."""
    spec = spec or WorkloadSpec()
    rng = np.random.default_rng(seed)
    n_small, n_medium, n_large = _fb_class_sizes(num_jobs)

    shapes: list[tuple[int, int]] = []  # (num_map, num_reduce)
    for i in range(n_small):
        shapes.append((1 if rng.random() < 0.75 else 2, 0))
    for i in range(n_medium):
        n_map = int(rng.integers(5, 501))
        n_red = 0 if rng.random() < 0.5 else int(rng.integers(2, 101))
        shapes.append((n_map, n_red))
    # Large class mirrors the paper's exact composition, scaled.
    for i in range(n_large):
        shapes.append(FB_LARGE_TEMPLATES[i % len(FB_LARGE_TEMPLATES)])
    rng.shuffle(shapes)

    jobs: list[JobSpec] = []
    class_of: dict[int, str] = {}
    t = 0.0
    for job_id, (n_map, n_red) in enumerate(shapes):
        t += float(rng.exponential(spec.mean_interarrival))
        map_mu = float(rng.uniform(spec.map_time_lo, spec.map_time_hi))
        red_mu = float(rng.uniform(spec.reduce_time_lo, spec.reduce_time_hi))
        job = JobSpec(
            job_id=job_id,
            arrival_time=t,
            map_tasks=_mk_tasks(
                rng, job_id, Phase.MAP, n_map, map_mu, spec.task_jitter,
                spec.map_state_bytes, spec.num_machines, spec.replication,
            ),
            reduce_tasks=_mk_tasks(
                rng, job_id, Phase.REDUCE, n_red, red_mu, spec.task_jitter,
                spec.reduce_state_bytes, spec.num_machines, spec.replication,
            ),
            name=f"fb-{job_class(n_map)}-{job_id}",
            reduce_slowstart=spec.reduce_slowstart,
        )
        jobs.append(job)
        class_of[job_id] = job_class(n_map)
    return jobs, class_of


# ---------------------------------------------------------------------------
# Scheduler-stress scenario: the FB-dataset mix at trace scale
# ---------------------------------------------------------------------------
def _mk_tasks_fast(
    rng: np.random.Generator,
    job_id: int,
    phase: Phase,
    n: int,
    mean_time: float,
    jitter: float,
    state_bytes: int,
    num_machines: int,
    replication: int,
) -> tuple[TaskSpec, ...]:
    """Vectorized task synthesis for trace-scale workloads (millions of
    tasks).  Input hosts are drawn WITH replacement (duplicate replicas
    are harmless: the locality index is keyed by host and idempotent) —
    a deliberate, documented deviation from ``_mk_tasks``'s exact
    without-replacement HDFS placement, trading a hair of placement
    fidelity for ~20x faster generation."""
    if n == 0:
        return ()
    if jitter > 0:
        times = mean_time * rng.lognormal(0.0, jitter, size=n)
    else:
        times = np.full(n, mean_time)
    times = np.maximum(times, 1.0)
    r = min(replication, num_machines)
    if phase is Phase.MAP:
        hosts = rng.integers(0, num_machines, size=(n, r))
        host_tuples = [tuple(int(h) for h in row) for row in hosts]
    else:
        host_tuples = [()] * n
    return tuple(
        TaskSpec(
            job_id=job_id,
            phase=phase,
            index=i,
            duration=float(times[i]),
            input_hosts=host_tuples[i],
            state_bytes=state_bytes,
        )
        for i in range(n)
    )


def fb_scaled_dataset(
    seed: int = 0,
    num_jobs: int = 10_000,
    num_machines: int = 100,
    spec: WorkloadSpec | None = None,
) -> tuple[list[JobSpec], dict[int, str]]:
    """Trace-scale FB-dataset: the paper's class mix at ``num_jobs`` scale.

    The submission window is held at the paper's ~22 min regardless of
    ``num_jobs`` (mean inter-arrival shrinks as 13 s x 100/num_jobs), so
    scheduler load — concurrent live jobs — grows with the job count.
    This is the scheduler-overhead stress scenario used by
    ``benchmarks/bench_sched_overhead.py``; task synthesis is vectorized
    (see :func:`_mk_tasks_fast`) so generating ~10k jobs / ~1M tasks stays
    in seconds.
    """
    spec = spec or WorkloadSpec()
    spec = dataclasses.replace(
        spec,
        num_machines=num_machines,
        mean_interarrival=13.0 * 100.0 / max(num_jobs, 1),
    )
    rng = np.random.default_rng(seed)
    n_small, n_medium, n_large = _fb_class_sizes(num_jobs)

    shapes: list[tuple[int, int]] = []
    small_two = rng.random(n_small) >= 0.75
    for i in range(n_small):
        shapes.append((2 if small_two[i] else 1, 0))
    med_maps = rng.integers(5, 501, size=n_medium)
    med_has_red = rng.random(n_medium) >= 0.5
    med_reds = rng.integers(2, 101, size=n_medium)
    for i in range(n_medium):
        shapes.append((int(med_maps[i]), int(med_reds[i]) if med_has_red[i] else 0))
    for i in range(n_large):
        shapes.append(FB_LARGE_TEMPLATES[i % len(FB_LARGE_TEMPLATES)])
    rng.shuffle(shapes)

    interarrivals = rng.exponential(spec.mean_interarrival, size=len(shapes))
    arrivals = np.cumsum(interarrivals)
    map_mus = rng.uniform(spec.map_time_lo, spec.map_time_hi, size=len(shapes))
    red_mus = rng.uniform(spec.reduce_time_lo, spec.reduce_time_hi, size=len(shapes))

    jobs: list[JobSpec] = []
    class_of: dict[int, str] = {}
    for job_id, (n_map, n_red) in enumerate(shapes):
        job = JobSpec(
            job_id=job_id,
            arrival_time=float(arrivals[job_id]),
            map_tasks=_mk_tasks_fast(
                rng, job_id, Phase.MAP, n_map, float(map_mus[job_id]),
                spec.task_jitter, spec.map_state_bytes, spec.num_machines,
                spec.replication,
            ),
            reduce_tasks=_mk_tasks_fast(
                rng, job_id, Phase.REDUCE, n_red, float(red_mus[job_id]),
                spec.task_jitter, spec.reduce_state_bytes, spec.num_machines,
                spec.replication,
            ),
            name=f"fb-{job_class(n_map)}-{job_id}",
            reduce_slowstart=spec.reduce_slowstart,
        )
        jobs.append(job)
        class_of[job_id] = job_class(n_map)
    return jobs, class_of


# ---------------------------------------------------------------------------
# TPU-adaptation workload: jobs are ML train/serve runs (DESIGN.md §2)
# ---------------------------------------------------------------------------
#: (arch, kind, quanta, seconds-per-quantum, state_GB) — step times derived
#: from the §Roofline compute terms of the assigned architectures (see
#: EXPERIMENTS.md); state bytes = params + optimizer (train) or KV (serve).
ML_JOB_TEMPLATES = [
    ("olmo-1b", "train", 200, 2.1, 14.6),
    ("olmo-1b", "serve", 30, 1.2, 3.0),
    ("gemma2-2b", "train", 150, 3.9, 29.3),
    ("starcoder2-3b", "train", 120, 5.6, 44.0),
    ("rwkv6-1.6b", "train", 100, 2.5, 23.0),
    ("granite-moe-3b-a800m", "train", 150, 1.9, 38.0),
    ("zamba2-2.7b", "train", 100, 4.3, 39.0),
    ("whisper-base", "train", 60, 0.6, 1.0),
    ("command-r-35b", "train", 400, 38.0, 420.0),
    ("llama4-scout-17b-a16e", "train", 300, 19.0, 1290.0),
    ("llava-next-34b", "serve", 80, 7.3, 80.0),
    ("command-r-35b", "serve", 60, 9.0, 90.0),
]


def ml_dataset(
    seed: int = 0,
    num_jobs: int = 40,
    mean_interarrival: float = 30.0,
    gang_slots: int = 16,
) -> tuple[list[JobSpec], dict[int, str]]:
    """Jobs = ML runs; tasks = step quanta executable on any gang slot.

    A job's MAP phase holds its step quanta (size = quanta x sec/quantum,
    cluster-width independent); there is no REDUCE phase.  ``state_bytes``
    drives the EAGER-preemption (HBM->host offload) cost model.
    """
    rng = np.random.default_rng(seed)
    jobs: list[JobSpec] = []
    class_of: dict[int, str] = {}
    t = 0.0
    for job_id in range(num_jobs):
        arch, kind, quanta, sec, state_gb = ML_JOB_TEMPLATES[
            int(rng.integers(len(ML_JOB_TEMPLATES)))
        ]
        t += float(rng.exponential(mean_interarrival))
        quanta = max(1, int(quanta * rng.uniform(0.5, 1.5)))
        tasks = tuple(
            TaskSpec(
                job_id=job_id,
                phase=Phase.MAP,
                index=i,
                duration=float(sec),
                input_hosts=(),
                state_bytes=int(state_gb * (1 << 30) / gang_slots),
            )
            for i in range(quanta)
        )
        jobs.append(
            JobSpec(
                job_id=job_id,
                arrival_time=t,
                map_tasks=tasks,
                reduce_tasks=(),
                name=f"{arch}-{kind}-{job_id}",
            )
        )
        total = quanta * sec
        class_of[job_id] = (
            "small" if total < 300 else "medium" if total < 3000 else "large"
        )
    return jobs, class_of


def fb_cluster(num_machines: int = 100) -> ClusterSpec:
    """The paper's Amazon cluster: 4 MAP + 2 REDUCE slots per node."""
    return ClusterSpec(
        num_machines=num_machines,
        map_slots_per_machine=4,
        reduce_slots_per_machine=2,
    )
