"""Multi-tenant gang scheduling with REAL JAX jobs (DESIGN.md §2).

Four training jobs of different architectures arrive at a 2-gang cluster;
HFSP estimates their sizes online from quantum runtimes, focuses the gangs
on the job that would finish first under PS, EAGER-preempts (checkpoint
offload/restore) the larger ones, and survives injected gang failures.

Run:  PYTHONPATH=src python examples/multi_tenant_cluster.py
"""

import tempfile

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke
from repro.core import ClusterSpec, HFSPConfig, HFSPScheduler
from repro.runtime import GangRuntime, MLJob


def main() -> None:
    cluster = ClusterSpec(
        num_machines=2, map_slots_per_machine=1, reduce_slots_per_machine=0
    )
    jobs = [
        MLJob(0, get_smoke("llama4_scout_17b"), total_steps=8,
              steps_per_quantum=2, arrival_time=0.0, name="moe-pretrain"),
        MLJob(1, get_smoke("gemma2_2b"), total_steps=2, steps_per_quantum=1,
              arrival_time=1.0, name="gemma-finetune"),
        MLJob(2, get_smoke("rwkv6_1b6"), total_steps=4, steps_per_quantum=2,
              arrival_time=2.0, name="rwkv-ablation"),
        MLJob(3, get_smoke("zamba2_2b7"), total_steps=2, steps_per_quantum=1,
              arrival_time=3.0, name="zamba-eval"),
    ]
    with tempfile.TemporaryDirectory() as d:
        runtime = GangRuntime(
            cluster,
            HFSPScheduler(cluster, HFSPConfig(sample_set_size=1)),
            jobs,
            CheckpointStore(d),
            fail_quantum_prob=0.05,   # inject gang failures
            rng_seed=7,
        )
        report = runtime.run(max_wall_s=600)

    print("job sojourns (wall s):")
    by_id = {j.job_id: j for j in jobs}
    for jid, s in sorted(report["sojourn"].items()):
        print(f"  {by_id[jid].name:16s} {s:7.1f}s  "
              f"final loss {report['losses'][jid]:.3f}")
    print(f"mean sojourn: {report['mean_sojourn']:.1f}s")
    print(f"fault-tolerance stats: {report['stats']}")
    print("timeline (first 12 events):")
    for t, kind, what in report["events"][:12]:
        print(f"  t={t:6.1f}s {kind:12s} {what}")


if __name__ == "__main__":
    main()
