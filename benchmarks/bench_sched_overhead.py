"""Scheduler-overhead benchmark: decision latency and event throughput vs
cluster scale.

The paper's practicality claim (Sect. 4) rests on the scheduler's own
decision cost staying negligible as jobs x machines grow.  This bench
drives each scheduler through the trace-scale FB workload
(:func:`repro.workload.fb_scaled_dataset`) over a #jobs x #machines grid
and reports, per cell:

* **decision latency** — mean and p99 wall-clock of one ``schedule()``
  pass (the incremental engine targets O(changed-tasks + actions));
* **events/sec** — simulator events processed per wall-clock second;
* **passes** and **events** actually executed (each cell runs a bounded
  event budget so the big cells stay fast; the workload is oversized
  relative to the budget, so every cell measures the scheduler under
  full queue pressure, not the drain tail).

A second CSV block (``waterfill_micro``) characterizes the virtual-cluster
water-fill kernels themselves — ROADMAP's "numpy loops recomputed on every
structural event" — numpy reference vs the jitted JAX backend
(:mod:`repro.core.vcluster_jax`), per job-grid cell:

* **fill**: one weighted max-min water-fill over the cell's demands;
* **proj**: one PS finish-time projection (the water-fill driven in a
  loop, one round per job completion — HFSP's schedule-order kernel and
  the dominant per-structural-event cost at trace scale);
* **waterfill_speedup**: numpy/jax projection-loop ratio, the headline
  column recorded into BENCH_sched.json by ``benchmarks/run.py --quick``.

Two further CSV blocks characterize the PR-4 demand-indexed core and the
epsilon-window event coalescing:

* ``sparse_demand`` — steady-state decision latency at a cell with many
  live jobs but few actionable ones (every slot busy on long tasks, a
  tail of queued jobs that provably cannot act): the demand-indexed pass
  vs the legacy full walk over every live job
  (``SchedulerConfig.demand_indexed=False``) — bit-identical schedules,
  the ``sparse_speedup`` column is the headline the 5000x1000 cell
  records into BENCH_sched.json (``decision_latency_ms``);
* ``eps_sweep`` — passes/events/wall at several ``event_epsilon`` values
  on the bursty scaled-FB trace (near-timestamp arrival batches coalesce
  into one pass per window; eps=0 is the bit-identical legacy loop).

A ``discipline_latency`` block repeats the sparse-demand measurement for
every engine-family registry discipline (hfsp / srpt / las / psbs, see
:mod:`repro.core.disciplines`): cached rank orders must keep every
discipline's steady-state pass O(actionable), and ``scripts/bench_gate.py``
fails when any recorded discipline exceeds ~2x the hfsp latency at the
5000x1000 cell.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_sched_overhead \
      [--schedulers hfsp,fair,fifo] [--jobs 50,500,5000] \
      [--machines 20,200,1000] [--events 20000] [--seed 0] \
      [--no-sparse] [--no-eps]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import SCHEDULERS, CsvOut
from repro.core import HFSPConfig, HFSPScheduler, Simulator
from repro.core.simulator import EventLimitReached
from repro.core.types import ClusterSpec, JobSpec, Phase, TaskSpec
from repro.workload import fb_scaled_dataset

JOB_GRID = (50, 500, 5000)
MACHINE_GRID = (20, 200, 1000)

#: Epsilon values (seconds) for the coalescing sweep; 0 is the legacy
#: pass-per-event baseline.
EPS_GRID = (0.0, 0.5, 2.0, 10.0)


def waterfill_cell(
    n_jobs: int, *, seed: int = 0, reps: int = 5, machines: int = 1000
) -> dict:
    """Water-fill kernel microbenchmark at one job-count cell.

    Demands come from the scaled FB trace (heavy-tailed task counts);
    remaining work is task-count x a plausible per-task time, weights are
    1.0 and slots mirror the grid's 1000-machine MAP capacity — the state
    the virtual cluster actually feeds these kernels at this scale.
    Best-of-``reps`` timings (min is the standard noise-robust estimator
    for microbenches); jit warmup/compile happens before timing.
    """
    from repro.core.vcluster import _project_array, _water_fill

    jobs, _ = fb_scaled_dataset(
        seed=seed, num_jobs=n_jobs, num_machines=machines
    )
    caps = np.array([len(j.map_tasks) for j in jobs], dtype=np.float64)
    rng = np.random.default_rng(seed)
    # The scaled trace can return slightly fewer jobs than requested;
    # size everything off the demands actually produced.
    rem = caps * rng.uniform(5.0, 50.0, len(caps))
    ws = np.ones(len(caps))
    slots = float(4 * machines)  # map_slots_per_machine=4, as in run_cell

    def best(fn) -> float:
        out = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            out.append(time.perf_counter() - t0)
        return min(out) * 1e3

    cell = {
        "jobs": n_jobs,
        "fill_numpy_ms": best(lambda: _water_fill(caps, ws, slots)),
        "proj_numpy_ms": best(
            lambda: _project_array(rem.copy(), caps, ws, slots, 0.0)
        ),
        "fill_jax_ms": None,
        "proj_jax_ms": None,
        "waterfill_speedup": None,
    }
    try:
        from repro.core import vcluster_jax

        if not vcluster_jax.have_jax():
            return cell
    except Exception:
        return cell
    vcluster_jax.water_fill(caps, ws, slots)  # compile
    vcluster_jax.project_finish_times(rem, caps, ws, slots, 0.0)
    cell["fill_jax_ms"] = best(
        lambda: vcluster_jax.water_fill(caps, ws, slots)
    )
    cell["proj_jax_ms"] = best(
        lambda: vcluster_jax.project_finish_times(rem, caps, ws, slots, 0.0)
    )
    cell["waterfill_speedup"] = cell["proj_numpy_ms"] / cell["proj_jax_ms"]
    return cell


def run_waterfill_micro(job_grid=JOB_GRID, *, seed: int = 0) -> list[dict]:
    out = CsvOut(
        "waterfill_micro",
        ["jobs", "fill_numpy_ms", "fill_jax_ms", "proj_numpy_ms",
         "proj_jax_ms", "waterfill_speedup"],
    )
    cells = []
    for nj in job_grid:
        cell = waterfill_cell(nj, seed=seed)
        cells.append(cell)
        fmt = lambda v, nd=3: round(v, nd) if v is not None else ""
        out.add(
            cell["jobs"], fmt(cell["fill_numpy_ms"]),
            fmt(cell["fill_jax_ms"]), fmt(cell["proj_numpy_ms"]),
            fmt(cell["proj_jax_ms"]), fmt(cell["waterfill_speedup"], 2),
        )
        speed = cell["waterfill_speedup"]
        print(
            f"# waterfill jobs={nj}: proj numpy "
            f"{cell['proj_numpy_ms']:.2f}ms vs jax "
            + (f"{cell['proj_jax_ms']:.2f}ms ({speed:.1f}x)"
               if speed is not None else "n/a (jax unavailable)"),
            flush=True,
        )
    out.emit()
    return cells


def sparse_demand_workload(
    n_jobs: int, *, sample_t: float = 10.0, body_t: float = 1e6
) -> list[JobSpec]:
    """Many live jobs, few actionable: each job has one short sample task
    (so HFSP's training finalizes quickly) and one very long body task.
    All jobs arrive in a single t=0 batch (one coalesced pass), bodies
    saturate every slot, and the queued tail stays live — but only the
    boundary jobs can ever act, which is exactly the demand-sparsity the
    indexed core exploits."""
    jobs = []
    for j in range(n_jobs):
        maps = (
            TaskSpec(j, Phase.MAP, 0, sample_t),
            TaskSpec(j, Phase.MAP, 1, body_t),
        )
        jobs.append(
            JobSpec(job_id=j, arrival_time=0.0, map_tasks=maps, reduce_tasks=())
        )
    return jobs


def run_sparse_cell(
    n_jobs: int,
    n_machines: int,
    *,
    demand_indexed: bool = True,
    warmup_t: float = 120.0,
    measure_events: int = 300,
    discipline: str = "hfsp",
) -> dict:
    """Steady-state decision latency at one sparse-demand cell.

    Runs the warmup (arrival batch + training waves) untimed, then
    measures ``measure_events`` heartbeat-driven passes with every slot
    busy and the queue tail pending — the state where the legacy pass
    still walks O(live jobs) while the demand-indexed pass touches only
    actionable ones.  vc_backend is pinned to numpy so the cell is
    hermetic (steady-state passes run no projections either way;
    sample_set_size=1 keeps the training warmup to two waves).

    ``discipline`` resolves any engine-family registry discipline
    (hfsp / srpt / las / psbs) — the per-discipline latency block uses
    this to sanity-bound the new ranks at trace scale."""
    from repro.core import disciplines

    cluster = ClusterSpec(
        num_machines=n_machines,
        map_slots_per_machine=4,
        reduce_slots_per_machine=2,
    )
    cfg = HFSPConfig(
        sample_set_size=1, vc_backend="numpy", demand_indexed=demand_indexed
    )
    sch = _TimedScheduler(
        disciplines.build_scheduler(discipline, cluster, config=cfg)
    )
    sim = Simulator(cluster, sch, sparse_demand_workload(n_jobs))
    sim.run(until=warmup_t)
    # Six consecutive steady-state windows on the same simulation; the
    # reported latency is the MINIMUM of the per-window medians.  The
    # gate compares this across PRs and container timing noise is
    # run-level (whole windows run slow under host contention), far
    # beyond the gate threshold at sub-millisecond scale — the lower
    # envelope of window medians is the noise-robust estimator (same
    # reasoning as best-of-reps in waterfill_cell); windows are cheap
    # next to the warmup, so more of them tighten the envelope.
    medians, all_times = [], []
    horizon = warmup_t
    t0 = time.perf_counter()
    for _ in range(6):
        sch.pass_times = []
        horizon += 10 * measure_events
        try:
            sim.run(until=horizon, max_events=measure_events)
        except EventLimitReached:
            pass
        times = sorted(sch.pass_times)
        if times:
            medians.append(times[len(times) // 2])
            all_times.extend(times)
    wall = time.perf_counter() - t0
    inner = sch._inner
    all_times.sort()
    n = len(all_times)
    return {
        "jobs": n_jobs,
        "machines": n_machines,
        "discipline": discipline,
        "demand_indexed": demand_indexed,
        "live": inner.n_live_phase(Phase.MAP),
        "actionable": len(inner._jobs_pending[Phase.MAP.value])
        + len(inner._jobs_suspended[Phase.MAP.value]),
        "passes": n,
        "wall_s": wall,
        "decision_latency_ms": 1e3 * min(medians) if medians else 0.0,
        "mean_pass_ms": 1e3 * sum(all_times) / n if n else 0.0,
        "p99_pass_ms": (
            1e3 * all_times[min(n - 1, int(0.99 * n))] if n else 0.0
        ),
    }


def run_sparse_demand(
    cells: tuple[tuple[int, int], ...] = ((500, 100), (5000, 1000)),
) -> list[dict]:
    """The sparse-demand block: demand-indexed vs legacy walk per cell."""
    out = CsvOut(
        "sparse_demand",
        ["jobs", "machines", "live", "actionable", "passes",
         "indexed_ms", "legacy_ms", "sparse_speedup"],
    )
    rows = []
    for nj, nm in cells:
        new = run_sparse_cell(nj, nm, demand_indexed=True)
        old = run_sparse_cell(nj, nm, demand_indexed=False)
        speed = (
            old["decision_latency_ms"] / new["decision_latency_ms"]
            if new["decision_latency_ms"] > 0
            else float("inf")
        )
        row = {**new, "legacy_ms": old["decision_latency_ms"],
               "sparse_speedup": speed}
        rows.append(row)
        out.add(
            nj, nm, row["live"], row["actionable"], row["passes"],
            round(row["decision_latency_ms"], 4),
            round(row["legacy_ms"], 4), round(speed, 1),
        )
        print(
            f"# sparse jobs={nj} machines={nm}: live={row['live']} "
            f"actionable={row['actionable']}; "
            f"indexed {row['decision_latency_ms']:.3f}ms vs legacy "
            f"{row['legacy_ms']:.3f}ms per pass ({speed:.1f}x)",
            flush=True,
        )
    out.emit()
    return rows


#: Engine-family disciplines the per-discipline latency block measures
#: (hfsp is the reference the others are sanity-bounded against).
DISCIPLINES = ("hfsp", "srpt", "las", "psbs")


def run_discipline_latency(
    cells: tuple[tuple[int, int], ...] = ((5000, 1000),),
    disciplines: tuple[str, ...] = DISCIPLINES,
) -> list[dict]:
    """Steady-state decision latency per registry discipline.

    Same measurement as the sparse-demand block (demand-indexed mode
    only), once per discipline: the Discipline API's contract is that a
    rank policy's cached order keeps steady-state passes O(actionable),
    so no discipline should cost more than ~2x hfsp at the trace-scale
    cell — scripts/bench_gate.py enforces that bound on the recorded
    ``sched_disciplines_5000x1000`` latencies."""
    out = CsvOut(
        "discipline_latency",
        ["discipline", "jobs", "machines", "live", "actionable", "passes",
         "decision_latency_ms", "p99_pass_ms"],
    )
    rows = []
    for nj, nm in cells:
        for name in disciplines:
            row = run_sparse_cell(nj, nm, discipline=name)
            rows.append(row)
            out.add(
                name, nj, nm, row["live"], row["actionable"], row["passes"],
                round(row["decision_latency_ms"], 4),
                round(row["p99_pass_ms"], 4),
            )
            print(
                f"# discipline {name} jobs={nj} machines={nm}: "
                f"{row['decision_latency_ms']:.3f}ms per pass "
                f"(p99 {row['p99_pass_ms']:.3f}ms)",
                flush=True,
            )
    out.emit()
    return rows


def run_eps_sweep(
    *,
    n_jobs: int = 600,
    n_machines: int = 200,
    max_events: int = 6_000,
    max_seconds: float = 45.0,
    seed: int = 0,
    eps_grid: tuple[float, ...] = EPS_GRID,
) -> list[dict]:
    """Pass counts vs ``event_epsilon`` on the bursty scaled-FB trace.

    Every row is driven toward the same ``max_events`` budget; eps>0
    rows run one pass per near-timestamp window instead of one per
    event.  ``max_seconds`` is a safety cap only — a row that hits it
    processes fewer events, so downstream consumers must compare
    ``passes_per_event`` (events-normalized), not raw pass counts,
    across rows (benchmarks/run.py and check.sh do)."""
    jobs, _ = fb_scaled_dataset(
        seed=seed, num_jobs=n_jobs, num_machines=n_machines
    )
    cluster = ClusterSpec(
        num_machines=n_machines,
        map_slots_per_machine=4,
        reduce_slots_per_machine=2,
    )
    out = CsvOut(
        "eps_sweep",
        ["eps", "events", "passes", "passes_per_event", "wall_s",
         "mean_pass_ms", "sim_t"],
    )
    rows = []
    for eps in eps_grid:
        sch = _TimedScheduler(
            HFSPScheduler(cluster, HFSPConfig(vc_backend="numpy"))
        )
        sim = Simulator(cluster, sch, jobs, event_epsilon=eps)
        t0 = time.perf_counter()
        while (
            sim.events_processed < max_events
            and time.perf_counter() - t0 < max_seconds
        ):
            try:
                sim.run(
                    max_events=min(250, max_events - sim.events_processed)
                )
                break
            except EventLimitReached:
                continue
        wall = time.perf_counter() - t0
        n = len(sch.pass_times)
        row = {
            "eps": eps,
            "events": sim.events_processed,
            "passes": sim.passes,
            "passes_per_event": sim.passes / max(sim.events_processed, 1),
            "wall_s": wall,
            "mean_pass_ms": 1e3 * sum(sch.pass_times) / n if n else 0.0,
            "sim_t": sim._now,
        }
        rows.append(row)
        out.add(
            eps, row["events"], row["passes"],
            round(row["passes_per_event"], 4), round(wall, 3),
            round(row["mean_pass_ms"], 4), round(row["sim_t"], 1),
        )
        print(
            f"# eps={eps}: {row['passes']} passes / {row['events']} events "
            f"({row['passes_per_event']:.2f} passes/event), "
            f"{wall:.2f}s wall",
            flush=True,
        )
    out.emit()
    return rows


class _TimedScheduler:
    """Wraps a scheduler, timing every schedule() pass."""

    def __init__(self, inner):
        self._inner = inner
        self.pass_times: list[float] = []

    def schedule(self, view, now):
        t0 = time.perf_counter()
        actions = self._inner.schedule(view, now)
        self.pass_times.append(time.perf_counter() - t0)
        return actions

    def __getattr__(self, name):
        return getattr(self._inner, name)


def run_cell(
    sched_name: str,
    num_jobs: int,
    num_machines: int,
    *,
    seed: int = 0,
    max_events: int = 20_000,
    max_seconds: float = 45.0,
    chunk: int = 250,
) -> dict:
    """One (scheduler, #jobs, #machines) cell.

    Bounded two ways so pathological cells (e.g. 5000 jobs jammed onto 20
    machines) cannot stall the grid: an event budget AND a wall-clock cap.
    The simulator supports incremental continuation, so the cell runs in
    ``chunk``-event slices and stops at whichever bound hits first; the
    row reports the events actually processed (no silent truncation).
    """
    jobs, _ = fb_scaled_dataset(
        seed=seed, num_jobs=num_jobs, num_machines=num_machines
    )
    cluster = ClusterSpec(
        num_machines=num_machines,
        map_slots_per_machine=4,
        reduce_slots_per_machine=2,
    )
    sch = _TimedScheduler(SCHEDULERS[sched_name](cluster))
    sim = Simulator(cluster, sch, jobs)
    t0 = time.perf_counter()
    while (
        sim.events_processed < max_events
        and time.perf_counter() - t0 < max_seconds
    ):
        try:
            sim.run(max_events=min(chunk, max_events - sim.events_processed))
            break  # drained the whole workload inside the budget
        except EventLimitReached:
            continue  # slice exhausted; loop re-checks both bounds
    wall = time.perf_counter() - t0
    events = sim.events_processed
    times = sorted(sch.pass_times)
    n = len(times)
    mean_ms = 1e3 * sum(times) / n if n else 0.0
    p99_ms = 1e3 * times[min(n - 1, int(0.99 * n))] if n else 0.0
    return {
        "passes": n,
        "events": events,
        "sim_t": sim._now,
        "wall_s": wall,
        "mean_pass_ms": mean_ms,
        "p99_pass_ms": p99_ms,
        "events_per_s": events / wall if wall > 0 else 0.0,
        "sched_frac": sum(times) / wall if wall > 0 else 0.0,
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--schedulers", default="fifo,fair,hfsp")
    ap.add_argument("--jobs", default=",".join(map(str, JOB_GRID)))
    ap.add_argument("--machines", default=",".join(map(str, MACHINE_GRID)))
    ap.add_argument("--events", type=int, default=20_000,
                    help="event budget per cell")
    ap.add_argument("--max-cell-seconds", type=float, default=45.0,
                    help="wall-clock cap per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-waterfill", action="store_true",
                    help="skip the water-fill kernel microbenchmark")
    ap.add_argument("--no-sparse", action="store_true",
                    help="skip the sparse-demand decision-latency block")
    ap.add_argument("--no-disciplines", action="store_true",
                    help="skip the per-discipline decision-latency block")
    ap.add_argument("--no-eps", action="store_true",
                    help="skip the epsilon-window coalescing sweep")
    args = ap.parse_args(argv)

    out = CsvOut(
        "sched_overhead",
        ["scheduler", "jobs", "machines", "passes", "events", "sim_t",
         "wall_s", "mean_pass_ms", "p99_pass_ms", "events_per_s",
         "sched_frac"],
    )
    for name in args.schedulers.split(","):
        for nj in (int(x) for x in args.jobs.split(",")):
            for nm in (int(x) for x in args.machines.split(",")):
                cell = run_cell(
                    name, nj, nm, seed=args.seed, max_events=args.events,
                    max_seconds=args.max_cell_seconds,
                )
                out.add(
                    name, nj, nm, cell["passes"], cell["events"],
                    round(cell["sim_t"], 1),
                    round(cell["wall_s"], 3),
                    round(cell["mean_pass_ms"], 4),
                    round(cell["p99_pass_ms"], 4),
                    round(cell["events_per_s"], 1),
                    round(cell["sched_frac"], 3),
                )
                print(
                    f"# {name} jobs={nj} machines={nm}: "
                    f"{cell['wall_s']:.2f}s wall, "
                    f"{cell['mean_pass_ms']:.3f}ms/pass (p99 "
                    f"{cell['p99_pass_ms']:.3f}), "
                    f"{cell['events_per_s']:.0f} events/s",
                    flush=True,
                )
    out.emit()
    if not args.no_waterfill:
        run_waterfill_micro(
            tuple(int(x) for x in args.jobs.split(",")), seed=args.seed
        )
    if not args.no_sparse:
        run_sparse_demand()
    if not args.no_disciplines:
        run_discipline_latency()
    if not args.no_eps:
        run_eps_sweep(seed=args.seed)


if __name__ == "__main__":
    main()
