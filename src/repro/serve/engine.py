"""Serving steps: prefill (process a full prompt, build the cache) and
decode (one token per call against the cache), plus a batched greedy
generation loop used by the examples and the runtime's serve jobs.

``make_prefill_step`` / ``make_decode_step`` return pure jit-able
functions; the dry-run lowers ``decode_step`` for the ``decode_*`` /
``long_*`` shapes per the assignment ("one new token with a KV cache of
seq_len").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward, init_cache


def make_prefill_step(cfg: ModelConfig, *, use_flash: bool = False,
                      interpret: bool = False, last_token_only: bool = False):
    """``last_token_only`` returns logits for the final position only — the
    only logits serving needs after a prefill.  XLA then dead-code-
    eliminates the full (b, s, vocab) unembedding: at 32k x 200k-vocab
    that removes the single largest memory consumer of the prefill step."""

    def prefill_step(params: dict, batch: dict) -> jnp.ndarray:
        if last_token_only:
            logits, _ = forward(
                cfg, params, batch, use_flash=use_flash, interpret=interpret,
                unembed_last_only=True,
            )
            return logits
        logits, _ = forward(
            cfg, params, batch, use_flash=use_flash, interpret=interpret
        )
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def _decode(params: dict, tokens: jnp.ndarray, positions: jnp.ndarray,
                cache: dict):
        return decode_step(cfg, params, tokens, positions, cache)

    return _decode


def greedy_generate(
    cfg: ModelConfig,
    params: dict,
    prompt: jnp.ndarray,      # (b, s0)
    max_new_tokens: int,
    max_seq: int | None = None,
) -> jnp.ndarray:
    """Greedy decoding: prefill via repeated decode (cache-exact), then
    generate.  Small-scale utility — the production path jits decode_step
    once and drives it from the runtime."""
    b, s0 = prompt.shape
    max_seq = max_seq or (s0 + max_new_tokens)
    cache = init_cache(cfg, b, max_seq)
    step = jax.jit(make_decode_step(cfg))

    tokens = prompt[:, :1]
    out = [prompt]
    logits = None
    for t in range(s0 + max_new_tokens - 1):
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = step(params, tokens, pos, cache)
        if t + 1 < s0:
            tokens = prompt[:, t + 1 : t + 2]
        else:
            tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(tokens)
    return jnp.concatenate(out, axis=1)


class BatchingQueue:
    """Continuous-batching request queue for the serve runtime: requests
    join/leave the decode batch at token boundaries (slot-based, static
    batch shape — the JAX-friendly formulation of vLLM-style batching)."""

    def __init__(self, cfg: ModelConfig, batch_slots: int, max_seq: int):
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self.free = list(range(batch_slots))
        self.active: dict[int, dict] = {}   # slot -> request
        self.waiting: list[dict] = []
        self.finished: list[dict] = []

    def submit(self, request: dict) -> None:
        """request: {"id", "prompt" (list[int]), "max_new_tokens"}."""
        self.waiting.append(request)

    def admit(self) -> list[tuple[int, dict]]:
        admitted = []
        while self.free and self.waiting:
            slot = self.free.pop()
            req = self.waiting.pop(0)
            req = {**req, "generated": [], "pos": 0}
            self.active[slot] = req
            admitted.append((slot, req))
        return admitted

    def step_done(self, slot: int, token: int) -> None:
        req = self.active[slot]
        if req["pos"] + 1 >= len(req["prompt"]):
            req["generated"].append(token)
        req["pos"] += 1
        done_len = len(req["generated"]) >= req["max_new_tokens"]
        if done_len or req["pos"] >= self.max_seq - 1:
            self.finished.append(req)
            del self.active[slot]
            self.free.append(slot)

    @property
    def idle(self) -> bool:
        return not self.active and not self.waiting
