"""Live telemetry: the scenario_report vocabulary as running counters.

One snapshot is a JSON dict in the same terms the offline reports use
— mean/tail sojourn, per-job slowdown distribution, Jain's fairness
index, goodput — plus service-only signals: admission counters, queue
depth, decision latency quantiles (wall seconds the engine spent
inside work-doing advances), current epsilon and worker liveness.
Clients pull one snapshot with ``{"op": "status"}`` or stream them
with ``{"op": "telemetry", ...}``.
"""

from __future__ import annotations

from repro.core.metrics import (
    ecdf_quantiles,
    jain_index,
    slowdowns,
    tail_quantiles,
)


class Telemetry:
    """Counter registry; the master owns one and feeds it events."""

    def __init__(self, engine):
        self.engine = engine
        self.counters = {
            "submitted": 0,   # accepted into engine (admit or drained queue)
            "queued": 0,      # backpressured at offer time
            "rejected": 0,    # rate/queue-full rejections
            "deduped": 0,     # idempotent resubmits answered from the tag map
            "worker_crashes": 0,
            "worker_rejoins": 0,
        }
        #: job_id -> size (sum of task durations) for slowdown/goodput.
        self.size_of: dict[int, float] = {}

    def note_job(self, spec) -> None:
        self.counters["submitted"] += 1
        self.size_of[spec.job_id] = spec.size

    def snapshot(self, *, workers: dict | None = None) -> dict:
        sim = self.engine.sim
        res = sim.result
        soj = list(res.sojourn.values())
        slow = list(slowdowns(res, self.size_of).values())
        lat_ms = [s * 1e3 for s in self.engine.decision_latency_s]
        useful = sum(
            self.size_of[j] for j in res.completion if j in self.size_of
        )
        lost = (sim._injector.stats_dict() if sim._injector else {}).get(
            "work_lost_s", 0.0
        )
        return {
            "v_now": self.engine.virtual_now(),
            "jobs": {
                **self.counters,
                "completed": len(res.completion),
                "live": self.engine.live_jobs(),
            },
            "sojourn": {
                "mean_s": res.mean_sojourn(),
                **ecdf_quantiles(soj),
                **tail_quantiles(soj),
            },
            "slowdown": {
                **ecdf_quantiles(slow),
                **tail_quantiles(slow),
            },
            "fairness": {
                "jain_sojourn": jain_index(soj),
                "jain_slowdown": jain_index(slow),
            },
            "goodput": useful / (useful + lost) if useful + lost > 0 else 1.0,
            "decision_latency_ms": {
                "count": len(lat_ms),
                **ecdf_quantiles(lat_ms),
                **tail_quantiles(lat_ms),
            },
            "event_epsilon": sim.event_epsilon,
            "events": sim.events_processed,
            "passes": sim.passes,
            "workers": workers or {},
        }
