"""Distributed sweep fabric tests: the backend-parametrized store
contract, lease fold/keeper semantics, concurrent-writer interleavings
with injected partial writes, per-backend crash-consistency properties,
worker-loop convergence, the coordinator view, and the chaos acceptance
test (workers SIGKILLed mid-cell; the sweep still converges exactly-once
with payloads bit-identical to a single-process run).

Crash models differ per backend and the tests encode that: the JSONL
reference backend must survive truncation at EVERY byte offset (its
crash surface is a torn trailing line), while sqlite's journaled commits
are exercised by SIGKILLing a live appender process at seeded-random
points — arbitrary byte truncation of a sqlite file is disk corruption,
not a crash, and is out of contract.
"""

import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.scenarios import (
    ClusterAxis,
    ResultStore,
    ScenarioSpec,
    SchedulerAxis,
    SqliteResultStore,
    SweepSpec,
    WorkloadAxis,
    get_preset,
    matrix_report,
    open_store,
    quick_sweep,
    run_sweep,
    run_worker,
    sweep_status,
)
from repro.scenarios.lease import COUNTERS, Lease, LeaseKeeper, fold_lease_log
from repro.scenarios.worker import _TEST_HOOK_ENV

REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(params=["jsonl", "sqlite"])
def store(request, tmp_path):
    """One store per backend; every contract test runs against both."""
    if request.param == "jsonl":
        return ResultStore(tmp_path / "store.jsonl")
    return SqliteResultStore(tmp_path / "store.sqlite")


def _worker_env(hook_path=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    if hook_path is not None:
        env[_TEST_HOOK_ENV] = str(hook_path)
    else:
        env.pop(_TEST_HOOK_ENV, None)
    return env


# ---------------------------------------------------------------------------
# Store contract (both backends)
# ---------------------------------------------------------------------------
def test_open_store_routes_by_path(tmp_path):
    assert isinstance(open_store(tmp_path / "a.jsonl"), ResultStore)
    assert isinstance(open_store(tmp_path / "a.sqlite"), SqliteResultStore)
    assert isinstance(open_store(tmp_path / "a.db"), SqliteResultStore)
    assert isinstance(
        open_store("sqlite:" + str(tmp_path / "noext")), SqliteResultStore
    )
    assert isinstance(
        open_store(tmp_path / "a.jsonl", backend="sqlite"), SqliteResultStore
    )
    existing = ResultStore(tmp_path / "b.jsonl")
    assert open_store(existing) is existing
    with pytest.raises(ValueError):
        open_store(tmp_path / "x", backend="nope")


def test_append_is_exactly_once_and_counts_duplicates(store):
    assert store.append("c1", "h1", {"v": 1}) is True
    assert store.append("c1", "h1", {"v": 999}) is False  # first wins
    assert store.append("c1", "h2", {"v": 2}) is True  # new hash = new key
    loaded = store.load()
    assert loaded[("c1", "h1")] == {"v": 1}
    assert loaded[("c1", "h2")] == {"v": 2}
    assert store.stats()["duplicates"] == 1


def test_stats_keys_always_present(store):
    assert set(store.stats()) >= set(COUNTERS)
    assert all(v == 0 for v in store.stats().values())


def test_lease_lifecycle_and_expired_reclaim(store):
    t0 = 1000.0
    assert store.claim("c", "h", "w1", ttl=10.0, now=t0)
    # Live foreign lease: claim fails, renew by a stranger fails.
    assert not store.claim("c", "h", "w2", ttl=10.0, now=t0 + 5.0)
    assert not store.renew("c", "h", "w2", ttl=10.0, now=t0 + 5.0)
    # The holder renews and re-claims freely.
    assert store.renew("c", "h", "w1", ttl=10.0, now=t0 + 5.0)
    assert store.claim("c", "h", "w1", ttl=10.0, now=t0 + 6.0)
    lease = store.leases()[("c", "h")]
    assert lease.worker == "w1" and lease.expires == t0 + 16.0
    # Past the TTL the foreign claim takes over — a counted reissue.
    assert store.claim("c", "h", "w2", ttl=10.0, now=t0 + 20.0)
    assert store.leases()[("c", "h")].worker == "w2"
    stats = store.stats()
    assert stats["reissues"] == 1
    assert stats["claims"] == 3
    assert stats["renews"] == 1
    # Release by a non-holder is a no-op; by the holder it drops the row.
    store.release("c", "h", "w1")
    assert ("c", "h") in store.leases()
    store.release("c", "h", "w2")
    assert ("c", "h") not in store.leases()
    assert store.stats()["releases"] == 1


def test_heartbeat_merges_info_and_keeps_last_seen_monotonic(store):
    store.heartbeat("w1", info={"host": "a", "done": 1}, now=100.0)
    store.heartbeat("w1", info={"done": 2}, now=200.0)
    store.heartbeat("w1", now=50.0)  # late-arriving beat must not rewind
    w = store.workers()["w1"]
    assert w["last_seen"] == 200.0
    assert w["info"] == {"host": "a", "done": 2}


# ---------------------------------------------------------------------------
# Lease fold + keeper
# ---------------------------------------------------------------------------
def test_fold_lease_log_is_reader_clock_independent():
    # Whether a claim was a reissue travels IN the claim row (decided by
    # the claiming writer under the store lock), so the fold needs no
    # clock of its own and every reader agrees on the counters.
    state = fold_lease_log([
        {"op": "claim", "cell_id": "c", "spec_hash": "h", "worker": "w1",
         "expires": 10.0, "t": 0.0, "reissue": False},
        {"op": "renew", "cell_id": "c", "spec_hash": "h", "worker": "w1",
         "expires": 20.0, "t": 5.0},
        # A stranger's renew must not steal the lease.
        {"op": "renew", "cell_id": "c", "spec_hash": "h", "worker": "wX",
         "expires": 99.0, "t": 6.0},
        {"op": "claim", "cell_id": "c", "spec_hash": "h", "worker": "w2",
         "expires": 40.0, "t": 25.0, "reissue": True},
        {"op": "dup", "cell_id": "c", "spec_hash": "h", "worker": "w1",
         "t": 26.0},
        {"op": "release", "cell_id": "c", "spec_hash": "h", "worker": "w2",
         "t": 30.0},
        {"op": "beat", "worker": "w3", "t": 31.0, "info": {"pid": 7}},
        {"op": "from-the-future", "worker": "w9", "t": 99.0},  # ignored
    ])
    assert state.leases == {}
    assert state.counters == {
        "claims": 2, "reissues": 1, "renews": 2, "releases": 1,
        "duplicates": 1,
    }
    assert state.workers["w3"]["info"] == {"pid": 7}
    assert "w9" not in state.workers


def test_lease_dataclass_expiry():
    lease = Lease("c", "h", "w", expires=100.0)
    assert not lease.expired(99.9)
    assert lease.expired(100.0)
    assert lease.remaining(90.0) == pytest.approx(10.0)


def test_lease_keeper_renews_then_detects_loss(tmp_path):
    store = ResultStore(tmp_path / "s.jsonl")
    assert store.claim("c", "h", "w1", ttl=5.0)
    keeper = LeaseKeeper(store, "c", "h", "w1", ttl=5.0, renew_every=0.01)
    time.sleep(0.02)
    keeper.tick()
    assert keeper.renewals == 1 and not keeper.lost
    # Another worker takes the cell over (as after this worker's TTL
    # expired); the keeper notices on its next due tick but keeps going.
    store.claim("c", "h", "w2", ttl=5.0, now=time.time() + 100.0)
    time.sleep(0.02)
    keeper.tick()
    assert keeper.lost


# ---------------------------------------------------------------------------
# Concurrent writers + injected partial writes
# ---------------------------------------------------------------------------
_APPENDER = """
import json, os, sys, time
sys.path.insert(0, {src!r})
from repro.scenarios.store import open_store

store = open_store({store_path!r})
n = {n}
ack = open({ack_path!r}, "a")
for i in range({start}, n):
    if store.append(f"cell-{{i}}", "hash", {{"payload": i, "by": {tag!r}}}):
        ack.write(f"{{i}}\\n")
        ack.flush()
        os.fsync(ack.fileno())
    time.sleep({delay})
"""


def _spawn_appender(tmp_path, store_path, tag, n, *, start=0, delay=0.0):
    script = tmp_path / f"appender-{tag}.py"
    script.write_text(_APPENDER.format(
        src=str(REPO_ROOT / "src"), store_path=str(store_path),
        ack_path=str(tmp_path / f"ack-{tag}.txt"), n=n, start=start,
        tag=tag, delay=delay,
    ))
    return subprocess.Popen(
        [sys.executable, str(script)], env=_worker_env(),
        stdout=subprocess.DEVNULL, stderr=subprocess.PIPE,
    )


def test_concurrent_writers_never_lose_or_duplicate(store, tmp_path):
    """Two racing processes append the SAME 20 (cell_id, spec_hash) keys
    against one store file: every key lands exactly once, each payload is
    one writer's intact record (never an interleaving of both), and the
    losers' appends are counted as duplicates."""
    n = 20
    procs = [
        _spawn_appender(tmp_path, store.path, tag, n) for tag in ("a", "b")
    ]
    for p in procs:
        assert p.wait(timeout=60) == 0, p.stderr.read().decode()
    loaded = store.load()
    assert len(loaded) == n
    for i in range(n):
        rec = loaded[(f"cell-{i}", "hash")]
        assert rec["payload"] == i
        assert rec["by"] in ("a", "b")  # one writer's intact record
    acked = set()
    for tag in ("a", "b"):
        acked |= {
            int(x) for x in (tmp_path / f"ack-{tag}.txt").read_text().split()
        }
    assert acked == set(range(n))  # every key acked by exactly the winners
    assert store.stats()["duplicates"] == 2 * n - len(loaded)


def test_jsonl_writers_survive_injected_partial_writes(tmp_path):
    """A torn partial line injected between two writers' rounds (as a
    crash mid-write would leave) must corrupt nothing: the next append
    repairs the missing newline, the torn fragment is dropped on load,
    and no acknowledged record is lost or duplicated."""
    store = ResultStore(tmp_path / "store.jsonl")
    assert store.append("pre", "h", {"v": 0})
    # Crash artifact: half a JSON record, no trailing newline.
    with store.path.open("a") as f:
        f.write('{"cell_id": "torn", "spec_hash": "h", "result": {"v"')
    procs = [
        _spawn_appender(tmp_path, store.path, tag, 10, delay=0.001)
        for tag in ("a", "b")
    ]
    for p in procs:
        assert p.wait(timeout=60) == 0, p.stderr.read().decode()
    loaded = ResultStore(store.path).load()  # fresh instance: no caches
    assert len(loaded) == 11  # "pre" + 10 raced keys; torn line dropped
    assert ("torn", "h") not in loaded
    assert loaded[("pre", "h")] == {"v": 0}
    # Exactly-once at the raw-line level, not just the folded dict.
    keys = [
        (json.loads(ln)["cell_id"], json.loads(ln)["spec_hash"])
        for ln in store.path.read_text().splitlines()
        if _parses(ln)
    ]
    assert len(keys) == len(set(keys))


def _parses(ln: str) -> bool:
    try:
        json.loads(ln)
        return True
    except json.JSONDecodeError:
        return False


# ---------------------------------------------------------------------------
# Crash consistency, per backend's crash model
# ---------------------------------------------------------------------------
def test_jsonl_store_truncation_at_every_byte(tmp_path):
    """The PR 3 property, pinned at the store layer on canned records
    (no simulator): truncate at EVERY byte offset; a fresh store must
    load exactly the records whose full JSON survived, and the next
    append must repair the tail without losing them."""
    path = tmp_path / "s.jsonl"
    seed = ResultStore(path)
    for i in range(4):
        seed.append(f"c{i}", f"h{i}", {"v": i, "pad": "x" * (7 * i)})
    raw = path.read_bytes()
    newline_at = [i for i, b in enumerate(raw) if b == ord("\n")]
    for off in range(len(raw) + 1):
        path.write_bytes(raw[:off])
        fresh = ResultStore(path)
        loaded = fresh.load()
        # Record k survives once its JSON content (everything before its
        # newline) is on disk — the newline itself may be torn.
        expect = sum(1 for e in newline_at if e <= off)
        assert len(loaded) == expect, f"offset {off}"
        # Appending onto any truncation point repairs the tail: the
        # surviving records and the new one all load.
        assert fresh.append("new", "hn", {"v": -1})
        assert len(fresh.load()) == expect + 1, f"offset {off}"


def test_jsonl_lease_log_truncation_never_errors(tmp_path):
    """The coordination sidecar obeys the same torn-line discipline:
    after truncation at any byte, a fresh store's leases()/workers()/
    stats() parse cleanly and reflect exactly the surviving full rows."""
    store = ResultStore(tmp_path / "s.jsonl")
    store.claim("c1", "h", "w1", ttl=30.0, now=100.0)
    store.claim("c2", "h", "w2", ttl=30.0, now=100.0)
    store.renew("c1", "h", "w1", ttl=30.0, now=110.0)
    store.heartbeat("w3", info={"pid": 1}, now=120.0)
    raw = store.lease_path.read_bytes()
    newline_at = [i for i, b in enumerate(raw) if b == ord("\n")]
    for off in range(len(raw) + 1):
        store.lease_path.write_bytes(raw[:off])
        fresh = ResultStore(store.path)
        stats = fresh.stats()
        leases = fresh.leases()
        fresh.workers()
        # The lease fold only consumes newline-terminated rows (unlike
        # results, where a complete-JSON torn tail still loads): a row
        # survives once its newline byte is on disk.
        n_rows = sum(1 for e in newline_at if e < off)
        assert stats["claims"] == min(2, n_rows)
        if n_rows == 0:
            assert leases == {}


def test_sqlite_survives_sigkill_at_random_points(tmp_path):
    """Sqlite's crash model: SIGKILL a live appender at seeded-random
    moments.  After every kill the database must open and load cleanly,
    every acknowledged append must be present (synchronous=FULL: the ack
    implies a durable commit), nothing outside the intended set appears,
    and a resumed appender completes the set."""
    import random

    rng = random.Random(0xD15C)
    path = tmp_path / "s.sqlite"
    n = 40
    intended = {(f"cell-{i}", "hash") for i in range(n)}
    for round_no in range(3):
        proc = _spawn_appender(
            tmp_path, path, f"r{round_no}", n, delay=0.002
        )
        time.sleep(rng.uniform(0.05, 0.6))
        proc.kill()  # SIGKILL — no atexit, no journal cleanup
        proc.wait(timeout=30)
        acked = set()
        for tag in [f"r{r}" for r in range(round_no + 1)]:
            ack = tmp_path / f"ack-{tag}.txt"
            if ack.exists():
                acked |= {int(x) for x in ack.read_text().split()}
        loaded = SqliteResultStore(path).load()  # journal rollback here
        assert {(f"cell-{i}", "hash") for i in acked} <= set(loaded)
        assert set(loaded) <= intended
        for (cid, h), rec in loaded.items():
            assert rec["payload"] == int(cid.split("-")[1])
    # A clean resume completes the set exactly-once.
    proc = _spawn_appender(tmp_path, path, "final", n)
    assert proc.wait(timeout=60) == 0, proc.stderr.read().decode()
    loaded = SqliteResultStore(path).load()
    assert set(loaded) == intended


# ---------------------------------------------------------------------------
# Worker loop + coordinator view
# ---------------------------------------------------------------------------
def _tiny_sweep(n_cells: int = 2) -> SweepSpec:
    base = ScenarioSpec(
        name="tiny",
        workload=WorkloadAxis(kind="fb", num_jobs=6),
        cluster=ClusterAxis(num_machines=4),
        scheduler=SchedulerAxis(policy="fifo"),
    )
    return SweepSpec(
        name="tiny", base=base,
        grids=(SweepSpec.grid(**{"workload.seed": tuple(range(n_cells))}),),
    )


def _strip_wall(result: dict) -> dict:
    return {k: v for k, v in result.items() if k != "wall_s"}


def test_run_worker_converges_and_matches_inline(store):
    """A single worker loop converges the sweep and stores payloads
    bit-identical (minus wall clock) to the inline supervisor's."""
    sweep = _tiny_sweep(2)
    inline = run_sweep(sweep, workers=0)
    summary = run_worker(
        sweep, store, worker_id="w1", ttl=5.0, timeout=60.0, deadline=120.0,
    )
    assert sorted(summary["computed"]) == sorted(cid for cid, _ in sweep.expand())
    assert not summary["stalled"]
    assert summary["duplicates_dropped"] == 0
    stored = store.load()
    for cid, spec in sweep.expand():
        assert _strip_wall(stored[(cid, spec.spec_hash())]) == _strip_wall(
            inline[cid]
        )
    status = sweep_status(sweep, store)
    assert status["converged"]
    assert status["pending"] == [] and status["leased"] == {}
    # The worker's own bookkeeping went through the lease protocol.
    assert summary["stats"]["claims"] == 2
    assert summary["stats"]["releases"] == 2


def test_sweep_status_classifies_cells_and_workers(store):
    sweep = _tiny_sweep(3)
    cells = sweep.expand()
    cids = [cid for cid, _ in cells]
    hashes = {cid: spec.spec_hash() for cid, spec in cells}
    now = time.time()
    # One done, one live-leased, one with an expired (reclaimable) lease.
    store.append(cids[0], hashes[cids[0]], {"mean_sojourn_s": 1.0})
    store.claim(cids[1], hashes[cids[1]], "w-live", ttl=300.0, now=now)
    store.claim(cids[2], hashes[cids[2]], "w-dead", ttl=1.0, now=now - 100.0)
    store.heartbeat("w-live", info={"pid": 1}, now=now)
    store.heartbeat("w-dead", now=now - 500.0)
    status = sweep_status(sweep, store, now=now, dead_after=60.0)
    assert status["done"] == [cids[0]]
    assert list(status["leased"]) == [cids[1]]
    assert status["leased"][cids[1]]["worker"] == "w-live"
    assert status["expired_leases"] == [cids[2]]
    assert status["pending"] == [cids[2]]  # expired lease = claimable
    assert not status["converged"]
    assert status["workers"]["w-live"]["live"]
    assert not status["workers"]["w-dead"]["live"]
    # A stored result under an outdated hash is stale, not done.
    store.append(cids[1], "stale-hash", {"mean_sojourn_s": 2.0})
    status = sweep_status(sweep, store, now=now)
    assert status["stale"] == [cids[1]]
    assert cids[1] not in status["done"]


def test_matrix_report_lists_missing_cells():
    """Graceful degradation: a partial matrix says exactly what is
    absent instead of silently shrinking."""
    results = {
        "a": {"mean_sojourn_s": 1.0, "p99_sojourn_s": 2.0},
        "q": {"quarantined": True, "cell_id": "q", "error": "x",
              "attempts": 3},
    }
    matrix = matrix_report(results, expected=["a", "b", "q"])
    assert matrix["missing"] == ["b"]
    assert matrix["quarantined"] == ["q"]
    assert matrix["cells"] == 1
    # Complete matrices report the empty list, not a missing key.
    assert matrix_report(results)["missing"] == []


# ---------------------------------------------------------------------------
# Chaos: SIGKILL a worker mid-cell, the sweep still converges exactly-once
# ---------------------------------------------------------------------------
@pytest.fixture(scope="session")
def inline_paper_fb_quick():
    """Single-process reference payloads for the chaos test (shared
    across backend params; wall_s is the only volatile field)."""
    return run_sweep(quick_sweep(get_preset("paper-fb")), workers=0)


def _spawn_cli_worker(store_path, worker_id, hook_path, *, ttl=1.5):
    return subprocess.Popen(
        [
            sys.executable, "-m", "repro.scenarios", "worker", "paper-fb",
            "--quick", "--store", str(store_path), "--worker-id", worker_id,
            "--ttl", str(ttl), "--renew-every", str(ttl / 5), "--poll",
            "0.2", "--timeout", "120", "--deadline", "240",
        ],
        env=_worker_env(hook_path), cwd=str(REPO_ROOT),
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )


@pytest.mark.parametrize("backend", ["sqlite", "jsonl"])
def test_chaos_sigkill_worker_sweep_converges_exactly_once(
    backend, tmp_path, inline_paper_fb_quick
):
    """The tentpole acceptance property.  Two CLI workers share a store
    on the paper-fb@quick matrix; every cell's first attempt is slowed
    (widening the kill window) and the worker holding a lease is
    SIGKILLed at a seeded-random point inside it.  The survivor must
    reclaim the orphaned lease (reissues > 0), converge the matrix with
    zero quarantines, and store payloads bit-identical (minus wall
    clock) to the single-process run — with every (cell_id, spec_hash)
    appearing exactly once."""
    import random

    rng = random.Random(0xC4A05)
    ttl = 1.5
    store_path = tmp_path / (
        "store.sqlite" if backend == "sqlite" else "store.jsonl"
    )
    hook_path = tmp_path / "hook.json"
    hook_path.write_text(json.dumps({
        "slow_once": {"cells": "*", "seconds": 3.0},
        "state_dir": str(tmp_path),
    }))
    sweep = quick_sweep(get_preset("paper-fb"))
    hashes = {cid: spec.spec_hash() for cid, spec in sweep.expand()}
    store = open_store(store_path)

    victim = _spawn_cli_worker(store_path, "chaos-victim", hook_path, ttl=ttl)
    survivor = None
    try:
        # Wait until the victim holds a lease on a cell that is not yet
        # stored — it is inside the slowed first attempt.
        deadline = time.monotonic() + 60.0
        claimed = None
        while time.monotonic() < deadline and claimed is None:
            done = set(store.load())
            for key, lease in store.leases().items():
                if lease.worker == "chaos-victim" and key not in done:
                    claimed = key
                    break
            time.sleep(0.05)
        assert claimed is not None, "victim never claimed a cell"
        # Randomized kill point inside the slow window.
        time.sleep(rng.uniform(0.0, 1.0))
        os.kill(victim.pid, signal.SIGKILL)
        victim.wait(timeout=30)

        survivor = _spawn_cli_worker(
            store_path, "chaos-survivor", hook_path, ttl=ttl
        )
        assert survivor.wait(timeout=240) == 0
    finally:
        for p in (victim, survivor):
            if p is not None and p.poll() is None:
                p.kill()
                p.wait(timeout=30)

    status = sweep_status(sweep, store_path)
    assert status["converged"], status
    assert status["quarantined"] == []
    assert status["stats"]["reissues"] >= 1  # the orphaned lease was reclaimed
    stored = store.load()
    assert set(stored) == {(cid, h) for cid, h in hashes.items()}
    for cid, h in hashes.items():
        assert _strip_wall(stored[(cid, h)]) == _strip_wall(
            inline_paper_fb_quick[cid]
        ), cid
    if backend == "jsonl":
        # Exactly-once at the raw line level: no dropped-duplicate path
        # may have physically double-appended.
        keys = [
            (json.loads(ln)["cell_id"], json.loads(ln)["spec_hash"])
            for ln in store_path.read_text().splitlines()
        ]
        assert len(keys) == len(set(keys))
