"""Jitted wrapper for the ssd Pallas kernel in the model's layout."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_chunked_bhtp


def ssd_chunked(xh, dt, a, B, C, s0, *, chunk: int = 64, interpret: bool = False):
    """Model layout: xh (b,t,h,p), dt/a (b,t,h), B/C (b,t,n), s0 (b,h,p,n).
    Returns (y (b,t,h,p), state (b,h,p,n))."""
    y, s = ssd_chunked_bhtp(
        jnp.moveaxis(xh, 1, 2),
        jnp.moveaxis(dt, 1, 2),
        jnp.moveaxis(a, 1, 2),
        B, C, s0,
        chunk=chunk, interpret=interpret,
    )
    return jnp.moveaxis(y, 1, 2), s
