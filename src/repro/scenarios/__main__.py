"""Scenario engine CLI.

  python -m repro.scenarios list
  python -m repro.scenarios show paper-fb [--quick]
  python -m repro.scenarios run  paper-fb [--quick] [--workers N]
                                 [--store results.jsonl] [--json out.json]
                                 [--max-cells N]
  python -m repro.scenarios export-trace fb --seed 0 --num-jobs 100 \
                                 --machines 100 --out trace.jsonl
  python -m repro.scenarios replay trace.jsonl --policy hfsp [--machines 100]
  python -m repro.scenarios worker paper-fb --store shared.sqlite \
                                 [--quick] [--ttl 30] [--worker-id ID]
  python -m repro.scenarios sweep-status paper-fb --store shared.sqlite \
                                 [--quick] [--json-out]

``run`` executes a named preset sweep (optionally at reduced --quick
scale), streaming per-cell progress, and prints the cross-cell matrix
summary.  With ``--store`` the sweep is resumable: re-running skips every
finished cell recorded in the store (a ``.sqlite``/``.db`` path selects
the sqlite backend — see repro.scenarios.store).

``worker`` joins a *distributed* sweep: any number of worker processes,
on any machines sharing the store, claim cells under TTL'd leases and
converge the matrix exactly-once (docs/scenarios.md "Distributed
sweeps").  ``sweep-status`` is the read-only coordinator view: per-cell
done/leased/pending/quarantined state, per-worker liveness, and the
store's claim/reissue/duplicate counters.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.scenarios.presets import get_preset, list_presets, quick_sweep
from repro.scenarios.report import matrix_report
from repro.scenarios.runner import run_scenario
from repro.scenarios.spec import (
    ClusterAxis,
    ScenarioSpec,
    SchedulerAxis,
    WorkloadAxis,
)
from repro.scenarios.sweep import run_sweep
from repro.scenarios.trace import export_trace


def _cmd_list(_args) -> int:
    for name in list_presets():
        sweep = get_preset(name)
        print(f"{name:24s} {len(sweep.expand()):3d} cells")
    return 0


def _cmd_show(args) -> int:
    sweep = get_preset(args.preset)
    if args.quick:
        sweep = quick_sweep(sweep)
    for cid, spec in sweep.expand():
        print(f"{cid}: {json.dumps(spec.to_dict(), sort_keys=True)}")
    return 0


def _cmd_run(args) -> int:
    sweep = get_preset(args.preset)
    if args.quick:
        sweep = quick_sweep(sweep)
    total = len(sweep.expand())
    print(f"== sweep {sweep.name}: {total} cells ==")

    def progress(cid: str, result: dict) -> None:
        if result.get("quarantined"):
            print(
                f"  {cid}: QUARANTINED after {result['attempts']} attempts "
                f"({result['error']})",
                flush=True,
            )
            return
        line = (
            f"  {cid}: mean_sojourn {result['mean_sojourn_s']:.1f}s  "
            f"makespan {result['makespan_s']:.0f}s  "
            f"wall {result['wall_s']:.2f}s"
        )
        if result.get("faults"):
            f = result["faults"]
            line += (
                f"  goodput {f['goodput']:.3f}  "
                f"retries {f['retries']}  spec_wins {f['speculative_wins']}"
            )
        print(line, flush=True)

    results = run_sweep(
        sweep,
        store=args.store,
        workers=args.workers,
        max_cells=args.max_cells,
        progress=progress,
    )
    matrix = matrix_report(results, expected=[cid for cid, _ in sweep.expand()])
    # Quarantined cells (self-healing sweep's poison records) carry no
    # metrics: matrix_report lists and excludes them; missing cells (a
    # --max-cells cut or an interrupted/partial distributed run) are
    # named so a degraded matrix states exactly what was dropped.
    means = matrix["mean_sojourn_s"]
    print(f"== matrix ({len(means)}/{total} cells) ==")
    for cid in sorted(means, key=lambda c: means[c]):
        print(f"  {cid}: mean_sojourn {means[cid]:.1f}s")
    for cid in matrix["quarantined"]:
        print(f"  {cid}: QUARANTINED ({results[cid]['error']})")
    for cid in matrix["missing"]:
        print(f"  {cid}: MISSING (not computed this run)")
    # Classify by the expanded spec, not the cell-id string: a grid that
    # does not sweep scheduler.policy produces ids without a policy key.
    policy_of = {cid: spec.scheduler.policy for cid, spec in sweep.expand()}
    hfsp_cells = [c for c in means if policy_of.get(c) == "hfsp"]
    other_cells = [c for c in means if policy_of.get(c) != "hfsp"]
    if hfsp_cells and other_cells:
        best_hfsp = min(means[c] for c in hfsp_cells)
        best_other = min(means[c] for c in other_cells)
        print(
            f"hfsp strictly lowest mean sojourn: {best_hfsp < best_other} "
            f"(hfsp {best_hfsp:.1f}s vs best-other {best_other:.1f}s)"
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"sweep": sweep.name, "matrix": matrix, "cells": results},
                f, indent=2, sort_keys=True,
            )
        print(f"wrote {args.json}")
    return 0


def _cmd_worker(args) -> int:
    from repro.scenarios.worker import run_worker

    sweep = get_preset(args.preset)
    if args.quick:
        sweep = quick_sweep(sweep)

    def progress(cid: str, result: dict) -> None:
        if result.get("quarantined"):
            print(f"  {cid}: QUARANTINED ({result['error']})", flush=True)
        else:
            print(
                f"  {cid}: mean_sojourn {result['mean_sojourn_s']:.1f}s  "
                f"wall {result['wall_s']:.2f}s",
                flush=True,
            )

    summary = run_worker(
        sweep,
        args.store,
        worker_id=args.worker_id,
        ttl=args.ttl,
        renew_every=args.renew_every,
        timeout=args.timeout,
        max_retries=args.max_retries,
        retry_backoff=args.retry_backoff,
        poll=args.poll,
        max_cells=args.max_cells,
        deadline=args.deadline,
        progress=progress,
    )
    print(json.dumps(summary, indent=2, sort_keys=True))
    return 1 if summary["stalled"] else 0


def _cmd_sweep_status(args) -> int:
    from repro.scenarios.coordinator import format_status, sweep_status

    sweep = get_preset(args.preset)
    if args.quick:
        sweep = quick_sweep(sweep)
    status = sweep_status(sweep, args.store, dead_after=args.dead_after)
    if args.json_out:
        print(json.dumps(status, indent=2, sort_keys=True))
    else:
        print(format_status(status))
    return 0


def _cmd_export_trace(args) -> int:
    spec = ScenarioSpec(
        name=f"{args.kind}-trace",
        workload=WorkloadAxis(
            kind=args.kind, seed=args.seed, num_jobs=args.num_jobs
        ),
        cluster=ClusterAxis(num_machines=args.machines),
    )
    from repro.scenarios.runner import build_workload

    jobs, class_of = build_workload(spec)
    meta = {
        "generator": args.kind,
        "seed": args.seed,
        "num_jobs": args.num_jobs,
        "num_machines": args.machines,
    }
    export_trace(args.out, jobs, class_of, meta)
    n_tasks = sum(len(j.map_tasks) + len(j.reduce_tasks) for j in jobs)
    print(f"wrote {args.out}: {len(jobs)} jobs, {n_tasks} tasks")
    return 0


def _cmd_replay(args) -> int:
    spec = ScenarioSpec(
        name=f"replay-{args.policy}",
        workload=WorkloadAxis(kind="trace", trace_path=args.trace),
        cluster=ClusterAxis(num_machines=args.machines),
        scheduler=SchedulerAxis(policy=args.policy),
    )
    result = run_scenario(spec)
    print(json.dumps(result, indent=2, sort_keys=True))
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.scenarios", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="list registered presets")

    p = sub.add_parser("show", help="print a preset's expanded cells")
    p.add_argument("preset")
    p.add_argument("--quick", action="store_true")

    p = sub.add_parser("run", help="run a preset sweep")
    p.add_argument("preset")
    p.add_argument("--quick", action="store_true",
                   help="reduced-scale smoke variant")
    p.add_argument("--workers", type=int, default=0,
                   help="worker processes (0/1 = inline)")
    p.add_argument("--store", default=None, metavar="PATH",
                   help="resumable JSONL result store")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the matrix + per-cell reports as JSON")
    p.add_argument("--max-cells", type=int, default=None,
                   help="compute at most N new cells (testing/resume demos)")

    p = sub.add_parser(
        "worker",
        help="join a distributed sweep: claim cells under leases from a "
             "shared store until the matrix converges",
    )
    p.add_argument("preset")
    p.add_argument("--store", required=True, metavar="PATH",
                   help="shared result store (JSONL, or .sqlite/.db for "
                        "the sqlite backend)")
    p.add_argument("--quick", action="store_true")
    p.add_argument("--worker-id", default=None,
                   help="unique worker name (default hostname-pid)")
    p.add_argument("--ttl", type=float, default=30.0,
                   help="lease TTL seconds; a dead worker's cells are "
                        "reclaimable this long after its last renewal")
    p.add_argument("--renew-every", type=float, default=None,
                   help="lease renewal interval (default ttl/3)")
    p.add_argument("--poll", type=float, default=0.5,
                   help="idle wait when all pending cells are leased")
    p.add_argument("--timeout", type=float, default=600.0,
                   help="per-attempt wall-clock budget (seconds)")
    p.add_argument("--max-retries", type=int, default=2)
    p.add_argument("--retry-backoff", type=float, default=0.5)
    p.add_argument("--max-cells", type=int, default=None,
                   help="compute at most N cells then exit")
    p.add_argument("--deadline", type=float, default=None,
                   help="total wall-clock bound; exit stalled (rc 1) on "
                        "expiry instead of waiting on foreign leases")

    p = sub.add_parser(
        "sweep-status",
        help="read-only coordinator view of a distributed sweep's store",
    )
    p.add_argument("preset")
    p.add_argument("--store", required=True, metavar="PATH")
    p.add_argument("--quick", action="store_true")
    p.add_argument("--json-out", action="store_true",
                   help="machine-readable JSON instead of the text view")
    p.add_argument("--dead-after", type=float, default=60.0,
                   help="heartbeat age (seconds) past which a worker is "
                        "reported dead")

    p = sub.add_parser("export-trace", help="synthesize + export a trace")
    p.add_argument("kind", choices=("fb", "fb_scaled", "ml"))
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--num-jobs", type=int, default=100)
    p.add_argument("--machines", type=int, default=100)
    p.add_argument("--out", required=True)

    p = sub.add_parser("replay", help="replay a JSONL trace")
    p.add_argument("trace")
    # Any registered discipline replays.  No argparse `choices`: policy
    # names validate lazily against the discipline registry at build
    # time (repro.scenarios.runner.build_scheduler), whose KeyError
    # lists what IS registered — snapshotting the registry here would
    # reject disciplines registered after import.
    from repro.core import disciplines

    p.add_argument(
        "--policy", default="hfsp",
        help=f"scheduling discipline (registered: "
             f"{', '.join(disciplines.names())}, or any name registered "
             f"from user code)",
    )
    p.add_argument("--machines", type=int, default=100)

    args = ap.parse_args(argv)
    return {
        "list": _cmd_list,
        "show": _cmd_show,
        "run": _cmd_run,
        "worker": _cmd_worker,
        "sweep-status": _cmd_sweep_status,
        "export-trace": _cmd_export_trace,
        "replay": _cmd_replay,
    }[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
