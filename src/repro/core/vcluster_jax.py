"""JAX backend for the virtual cluster's numeric kernels (see docs/vcluster.md).

Provides jittable, fixed-shape replacements for the two hot loops in
:mod:`repro.core.vcluster`:

* :func:`water_fill` — the weighted max-min (water-filling) allocation,
  as a bounded ``lax.while_loop`` over redistribute rounds: one fused XLA
  program replaces O(#cap-levels) numpy round trips of Python dispatch;
* :func:`water_fill_batch` — the fill ``vmap``-ed over a leading scenario
  axis: B candidate allocations (what-if demands, per-scenario slot
  counts) price in ONE kernel dispatch instead of B Python loops — the
  speedup the scheduler-overhead microbenchmark tracks;
* :func:`project_finish_times` — the piecewise-constant PS forward
  simulation behind HFSP's schedule order, as a ``lax.while_loop`` with a
  warm-started water level (monotone across finish events) and segmented
  host-side compaction of survivors into shrinking buckets (at most one
  loop iteration per job completion, exactly like the numpy reference);
* :func:`project_finish_times_batch` — the same projection ``vmap``-ed
  over a leading scenario axis, so many what-if projections (hypothetical
  job sizes from the estimator, candidate allocations, epsilon-window
  event batches, both phases of a scheduling pass) price in one dispatch.

Shape contract (when recompiles happen)
---------------------------------------
All entry points pad inputs to the next power-of-two length (floor 8) and
mask the tail with ``present=False``.  XLA therefore compiles one program
per *bucket* (8, 16, 32, ...), not per job count: a cluster oscillating
between 900 and 1100 live jobs reuses the 1024-wide executable.  Masked
padding is exact — padded entries contribute ``0.0`` terms to every sum
and sort behind an ``inf`` rank, so the result on real entries is
bit-identical across bucket sizes (adding a float zero is exact).

Everything runs in float64 (via the scoped ``jax.experimental.enable_x64``
context, so the global x64 flag — and with it the rest of the process —
is untouched) to stay within 1e-9 of the numpy reference.

JAX is imported lazily: the numpy backend, the schedulers, and the
simulator never pay the import (or require the dependency) unless a
``VirtualCluster(backend="jax")`` is actually constructed.
"""

from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "have_jax",
    "water_fill",
    "water_fill_batch",
    "project_finish_times",
    "project_finish_times_batch",
]


def have_jax() -> bool:
    """True when a usable jax is importable (checked lazily, cached)."""
    return _modules() is not None


@functools.lru_cache(maxsize=1)
def _modules():
    try:
        import jax
        import jax.numpy as jnp
        from jax import lax
    except Exception:  # pragma: no cover - environment without jax
        return None
    return jax, jnp, lax


def _require():
    mods = _modules()
    if mods is None:  # pragma: no cover - environment without jax
        raise RuntimeError(
            "VirtualCluster backend 'jax' requested but jax is not "
            "importable; install jax or use backend='numpy' "
            "(REPRO_VC_BACKEND=numpy)."
        )
    return mods


def _bucket(n: int) -> int:
    """Padded buffer width for ``n`` live jobs: next power of two up to
    1024, then the next multiple of 1024 (pow2 padding wastes up to 2x
    work exactly where width dominates cost — 5000 jobs pad to 5120, not
    8192)."""
    if n <= 8:
        return 8
    if n <= 1024:
        return 1 << (n - 1).bit_length()
    return -(-n // 1024) * 1024


def _pad1(a: np.ndarray, width: int, fill: float = 0.0) -> np.ndarray:
    out = np.full(width, fill, dtype=np.float64)
    out[: len(a)] = a
    return out


# ---------------------------------------------------------------------------
# Kernels (traced under jit; see docs/vcluster.md for the math)
# ---------------------------------------------------------------------------
def _water_fill_masked(caps, ws, slots, live):
    """Weighted water-fill over the ``live`` entries, as a bounded
    ``lax.while_loop``: fill proportionally to weight, clamp at cap,
    redistribute — the exact fixed-point path of the numpy reference
    (``vcluster._water_fill``), one fused XLA round per cap level.

    A sort-based closed form is asymptotically prettier but loses badly on
    CPU: one 8k-wide f64 ``argsort`` costs ~2.5 ms under XLA while real
    trace demands converge in 1-3 redistribute rounds of cheap fused
    element-wise work.  Mirroring the reference round-for-round also keeps
    the floating-point trajectories of the two backends within a few ulp,
    which is what lets the conformance suite demand bit-identical
    *schedules*.
    """
    _, jnp, lax = _modules()
    w = jnp.where(live, ws, 0.0)
    c = jnp.where(live, caps, 0.0)

    def cond(state):
        _, active, free = state
        return (free > 1e-12) & jnp.any(active)

    def body(state):
        alloc, active, free = state
        w_act = jnp.where(active, w, 0.0)
        total_w = jnp.sum(w_act)
        w_ok = total_w > 0.0
        share = jnp.where(
            active, free * w_act / jnp.where(w_ok, total_w, 1.0), 0.0
        )
        headroom = c - alloc
        capped = active & (share >= headroom - 1e-12)
        cont = w_ok & jnp.any(capped)
        grant_capped = jnp.where(capped, headroom, 0.0)
        # Terminal round (nobody capped): everyone keeps their share and
        # the loop ends; zero total weight grants nothing (numpy's break).
        grant = jnp.where(
            cont, grant_capped, jnp.where(w_ok, share, 0.0)
        )
        alloc2 = alloc + grant
        free2 = jnp.where(cont, free - jnp.sum(grant_capped), free)
        active2 = jnp.where(cont, active & ~capped, jnp.zeros_like(active))
        return alloc2, active2, free2

    alloc0 = jnp.zeros(caps.shape, caps.dtype)
    active0 = live & (c > 0.0)
    state = (alloc0, active0, jnp.asarray(slots, caps.dtype))
    return lax.while_loop(cond, body, state)[0]


def _project_kernel(rem0, caps, ws, present, slots, now, lam0, floor):
    """PS forward simulation, mirroring ``vcluster.project_finish_times``
    event for event: at each iteration the minimal remaining/allocation
    job finishes, its slots redistribute, repeat.  At least one job
    leaves ``live`` per iteration, so the loop is bounded by the job
    count.

    Two structural exploits make this beat the numpy loop at trace scale:

    * **warm-started water level.**  Within one projection, caps and
      weights are fixed and jobs only *leave*, so the water level
      ``lam`` (allocation = ``min(cap, lam * w)``) is monotonically
      non-decreasing across events.  Each event therefore resumes the
      level fixpoint from the previous event's ``lam`` instead of
      redistributing from scratch — typically a single masked-sum
      iteration instead of a full O(#cap-levels) refill;
    * **early-stop floor.**  The loop also exits once the live count
      drops to ``floor``, letting the host wrapper compact survivors
      into a smaller padded bucket (see :func:`project_finish_times`) —
      the fixed-shape analogue of numpy's shrinking fancy-indexing.

    Returns the full carry ``(t, rem, fin, live, lam, n_live, run)``;
    ``run`` distinguishes "stopped at the floor" (True) from "drained or
    only infinite-size jobs left" (False).
    """
    _, jnp, lax = _modules()
    live0 = present & (rem0 > 0.0) & (caps > 0.0)
    pos = ws > 0.0
    fin0 = jnp.where(live0, jnp.inf, now)
    n0 = jnp.sum(live0)
    lam_init = jnp.asarray(lam0, rem0.dtype)
    capped0 = live0 & pos & (caps <= lam_init * ws + 1e-12)

    def level_step(lam_c, capped_c, part):
        cap_sum = jnp.sum(jnp.where(capped_c, caps, 0.0))
        w_unc = jnp.sum(jnp.where(part & ~capped_c, ws, 0.0))
        lam2 = jnp.where(
            w_unc > 0.0,
            (slots - cap_sum) / jnp.where(w_unc > 0.0, w_unc, 1.0),
            jnp.inf,
        )
        return jnp.maximum(lam2, lam_c)  # monotone; guards fp wobble

    def cond(state):
        return state[7] & (state[6] > floor)

    def body(state):
        t, rem, fin, live, lam, capped, n_live, _ = state
        part = live & pos
        # `capped` is maintained as a subset of `live` by the return below
        # (finished jobs leave the capped set), so no re-masking here.
        # Advance the water level from the carried state: one masked-sum
        # step, then grow the capped set only if the raised level crossed
        # a new cap/weight ratio (rare — the fixpoint loop usually skips).
        lam1 = level_step(lam, capped, part)

        def lcond(s):
            return s[2]

        def lbody(s):
            lam_c, capped_c, _ = s
            capped2 = capped_c | (part & (caps <= lam_c * ws + 1e-12))
            lam2 = level_step(lam_c, capped2, part)
            more = jnp.any(
                part & ~capped2 & (caps <= lam2 * ws + 1e-12)
            )
            return lam2, capped2, more

        more0 = jnp.any(part & ~capped & (caps <= lam1 * ws + 1e-12))
        lam_f, capped_f, _ = lax.while_loop(
            lcond, lbody, (lam1, capped, more0)
        )
        alloc = jnp.where(
            part,
            jnp.where(capped_f, caps, jnp.minimum(caps, lam_f * ws)),
            0.0,
        )
        # Raw division is safe: the mask discards the /0 lanes, and for
        # alloc > 0 the numpy reference's max(alloc, 1e-300) is a no-op.
        dt = jnp.where(live & (alloc > 0.0), rem / alloc, jnp.inf)
        dt_min = jnp.min(dt)
        finite = jnp.isfinite(dt_min)
        # Only infinite-size jobs remain: commit nothing, stop (they never
        # finish under PS, exactly like the numpy loop's break).
        t2 = jnp.where(finite, t + dt_min, t)
        rem2 = jnp.where(live, jnp.maximum(rem - alloc * dt_min, 0.0), rem)
        done = live & (dt <= dt_min + 1e-12)
        fin2 = jnp.where(done, t2, fin)
        live2 = live & ~done
        n2 = n_live - jnp.sum(done)
        return (
            t2,
            jnp.where(finite, rem2, rem),
            jnp.where(finite, fin2, fin),
            jnp.where(finite, live2, live),
            jnp.where(finite, lam_f, lam),
            jnp.where(finite, capped_f & live2, capped),
            jnp.where(finite, n2, n_live),
            finite & (n2 > 0),
        )

    state = (
        jnp.asarray(now, rem0.dtype),
        rem0,
        fin0,
        live0,
        lam_init,
        capped0,
        n0,
        n0 > 0,
    )
    return lax.while_loop(cond, body, state)


@functools.lru_cache(maxsize=1)
def _jitted():
    """Compile-once entry points (cached per padded bucket by jit)."""
    jax, _, _ = _modules()
    return {
        "fill": jax.jit(_water_fill_masked),
        "fill_batch": jax.jit(
            jax.vmap(_water_fill_masked, in_axes=(0, 0, 0, 0))
        ),
        "project": jax.jit(_project_kernel),
        "project_batch": jax.jit(
            jax.vmap(
                lambda rem, caps, ws, present, slots, now: _project_kernel(
                    rem, caps, ws, present, slots, now, 0.0, 0
                )[2],
                in_axes=(0, 0, 0, 0, 0, 0),
            )
        ),
    }


# ---------------------------------------------------------------------------
# Public numpy-in / numpy-out API
# ---------------------------------------------------------------------------
def water_fill(caps: np.ndarray, ws: np.ndarray, slots: float) -> np.ndarray:
    """Weighted max-min allocation; drop-in for ``vcluster._water_fill``."""
    jax, jnp, _ = _require()
    n = len(caps)
    if n == 0:
        return np.zeros(0)
    width = _bucket(n)
    live = np.zeros(width, dtype=bool)
    live[:n] = True
    with jax.experimental.enable_x64():
        out = _jitted()["fill"](
            _pad1(np.asarray(caps, np.float64), width),
            _pad1(np.asarray(ws, np.float64), width),
            jnp.asarray(float(slots), jnp.float64),
            live,
        )
    return np.asarray(out)[:n]


def water_fill_batch(
    caps_b: np.ndarray, ws_b: np.ndarray, slots
) -> np.ndarray:
    """B water-fills in one vmapped dispatch.

    ``caps_b``/``ws_b`` are (B, N) scenario matrices (candidate demand
    sets); ``slots`` is a scalar or a (B,) vector.  Replaces B sequential
    ``_water_fill`` Python loops with a single kernel launch — the
    batched-what-if fast path measured by
    ``benchmarks/bench_sched_overhead.py``.
    """
    jax, jnp, _ = _require()
    caps_b = np.asarray(caps_b, np.float64)
    if caps_b.ndim != 2:
        raise ValueError("caps_b must be (B, N)")
    b, n = caps_b.shape
    if b == 0 or n == 0:
        return np.zeros((b, n))
    width = _bucket(n)
    pad = ((0, 0), (0, width - n))
    live = np.zeros((b, width), dtype=bool)
    live[:, :n] = True
    slots_b = np.broadcast_to(np.asarray(slots, np.float64), (b,)).copy()
    with jax.experimental.enable_x64():
        out = _jitted()["fill_batch"](
            np.pad(caps_b, pad),
            np.pad(np.asarray(ws_b, np.float64), pad),
            slots_b,
            live,
        )
    return np.asarray(out)[:, :n]


def project_finish_times(
    rem: np.ndarray, caps: np.ndarray, ws: np.ndarray, slots: float, now: float
) -> np.ndarray:
    """PS finish times; drop-in for ``vcluster.project_finish_times``
    (array-shaped: callers keep their own id <-> index mapping).

    Segmented: the kernel stops when the live count falls to half the
    padded width, survivors are compacted into the next-smaller bucket,
    and the simulation resumes with the carried clock and water level.  Total work is geometric in the shrinking width instead of
    (#jobs x full width) — the fixed-shape counterpart of the numpy
    loop's shrinking ``caps[live]`` fancy-indexing.  Small widths
    (< 1024) run in a single segment; compaction round trips there would
    cost more than they save.
    """
    jax, jnp, _ = _require()
    n = len(rem)
    if n == 0:
        return np.zeros(0)
    rem = np.asarray(rem, np.float64)
    caps = np.asarray(caps, np.float64)
    ws = np.asarray(ws, np.float64)
    fin_out = np.empty(n)
    idx = np.arange(n)
    t = float(now)
    lam = 0.0
    while True:
        m = len(idx)
        width = _bucket(m)
        present = np.zeros(width, dtype=bool)
        present[:m] = True
        floor = width // 2 if width >= 1024 else 0
        with jax.experimental.enable_x64():
            state = _jitted()["project"](
                _pad1(rem, width),
                _pad1(caps, width),
                _pad1(ws, width),
                present,
                jnp.asarray(float(slots), jnp.float64),
                jnp.asarray(t, jnp.float64),
                jnp.asarray(lam, jnp.float64),
                floor,
            )
        t2, rem2, fin, live, lam2, _capped, n_live, run = (
            np.asarray(x) for x in state
        )
        fin_out[idx] = fin[:m]
        if int(n_live) == 0 or not bool(run) or floor == 0:
            return fin_out
        alive = np.flatnonzero(live[:m])
        idx = idx[alive]
        rem = rem2[:m][alive]
        caps = caps[alive]
        ws = ws[alive]
        t = float(t2)
        lam = float(lam2)


def project_finish_times_batch(
    rem_b: np.ndarray,
    caps_b: np.ndarray,
    ws_b: np.ndarray,
    slots,
    now,
    n_valid=None,
) -> np.ndarray:
    """Batched what-if projections: one dispatch for B scenarios.

    ``rem_b``/``caps_b``/``ws_b`` are (B, N) scenario matrices; ``slots``
    and ``now`` are scalars or (B,) vectors (so MAP and REDUCE — or
    scenarios at different virtual times — can share a batch).
    ``n_valid`` optionally gives the per-row live-prefix length (defaults
    to N for every row).  Returns a (B, N) matrix of absolute finish
    times; entries beyond a row's ``n_valid`` are meaningless.
    """
    jax, jnp, _ = _require()
    rem_b = np.asarray(rem_b, np.float64)
    if rem_b.ndim != 2:
        raise ValueError("rem_b must be (B, N)")
    b, n = rem_b.shape
    if b == 0 or n == 0:
        return np.zeros((b, n))
    width = _bucket(n)
    pad = ((0, 0), (0, width - n))
    rem_p = np.pad(rem_b, pad)
    caps_p = np.pad(np.asarray(caps_b, np.float64), pad)
    ws_p = np.pad(np.asarray(ws_b, np.float64), pad)
    present = np.zeros((b, width), dtype=bool)
    if n_valid is None:
        present[:, :n] = True
    else:
        for i, nv in enumerate(np.broadcast_to(n_valid, (b,))):
            present[i, : int(nv)] = True
    slots_b = np.broadcast_to(np.asarray(slots, np.float64), (b,)).copy()
    now_b = np.broadcast_to(np.asarray(now, np.float64), (b,)).copy()
    with jax.experimental.enable_x64():
        out = _jitted()["project_batch"](
            rem_p, caps_p, ws_p, present, slots_b, now_b
        )
    return np.asarray(out)[:, :n]
