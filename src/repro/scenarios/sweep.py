"""Parallel sweep engine with a resumable on-disk result store.

``run_sweep`` expands a :class:`~repro.scenarios.spec.SweepSpec` into its
scenario cells and fans them out across worker *processes* (the simulator
is pure Python — process pools are the only way to use multiple cores).
Results stream into a :class:`ResultStore` (append-only JSONL) as cells
finish, keyed by ``(cell_id, spec_hash)``:

* **resume** — a re-run of an interrupted sweep skips every cell whose
  (cell_id, spec_hash) pair is already stored, recomputing nothing;
* **staleness** — editing a preset changes the affected cells'
  ``spec_hash``, so stale stored results are ignored (and recomputed)
  instead of being silently reused;
* **determinism** — a cell's result is a pure function of its spec (all
  RNG seeds are spec fields), so parallel/serial execution and any
  resume order produce identical stores up to line order.

Workers use the ``spawn`` start method: the parent may hold jax state
(the vcluster jax backend), which does not survive ``fork``.
"""

from __future__ import annotations

import json
import os
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from pathlib import Path

from repro.scenarios.runner import run_scenario
from repro.scenarios.spec import ScenarioSpec, SweepSpec


class ResultStore:
    """Append-only JSONL store of finished sweep cells.

    One line per finished cell::

        {"cell_id": ..., "spec_hash": ..., "result": {scenario_report}}

    Append-only + line-granular means a crash mid-write loses at most the
    last line (a torn trailing line is detected and ignored on load).
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)

    def load(self) -> dict[tuple[str, str], dict]:
        """{(cell_id, spec_hash): result} for every intact stored line."""
        out: dict[tuple[str, str], dict] = {}
        if not self.path.exists():
            return out
        with self.path.open() as f:
            for ln in f:
                ln = ln.strip()
                if not ln:
                    continue
                try:
                    rec = json.loads(ln)
                except json.JSONDecodeError:
                    continue  # torn trailing line from an interrupted run
                out[(rec["cell_id"], rec["spec_hash"])] = rec["result"]
        return out

    def append(self, cell_id: str, spec_hash: str, result: dict) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        rec = {"cell_id": cell_id, "spec_hash": spec_hash, "result": result}
        with self.path.open("a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())


def _run_cell(payload: tuple[str, dict]) -> tuple[str, dict]:
    """Worker entry point (must be importable for spawn)."""
    cid, spec_dict = payload
    return cid, run_scenario(ScenarioSpec.from_dict(spec_dict))


def run_sweep(
    sweep: SweepSpec,
    store: ResultStore | str | Path | None = None,
    workers: int = 0,
    max_cells: int | None = None,
    progress=None,
) -> dict[str, dict]:
    """Run (or resume) a sweep; returns {cell_id: scenario_report}.

    ``workers=0`` runs inline (deterministic single-process order,
    used by tests and small presets); ``workers=N`` fans cells out over N
    spawn-based processes.  ``max_cells`` bounds how many *new* cells are
    computed this call — the hook tests use it to interrupt a sweep
    mid-grid and assert resume semantics.  ``progress`` is an optional
    ``f(cell_id, result)`` callback invoked as each cell finishes.
    """
    if store is not None and not isinstance(store, ResultStore):
        store = ResultStore(store)
    cells = sweep.expand()
    done = store.load() if store is not None else {}

    results: dict[str, dict] = {}
    todo: list[tuple[str, ScenarioSpec]] = []
    for cid, spec in cells:
        prior = done.get((cid, spec.spec_hash()))
        if prior is not None:
            results[cid] = prior
        else:
            todo.append((cid, spec))
    if max_cells is not None:
        todo = todo[:max_cells]

    def finish(cid: str, spec: ScenarioSpec, result: dict) -> None:
        results[cid] = result
        if store is not None:
            store.append(cid, spec.spec_hash(), result)
        if progress is not None:
            progress(cid, result)

    if workers <= 1:
        for cid, spec in todo:
            finish(cid, spec, run_scenario(spec))
        return results

    spec_of = dict(todo)
    import multiprocessing

    ctx = multiprocessing.get_context("spawn")
    failures: dict[str, BaseException] = {}
    with ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as pool:
        cid_of_future = {
            pool.submit(_run_cell, (cid, spec.to_dict())): cid
            for cid, spec in todo
        }
        pending = set(cid_of_future)
        while pending:
            finished, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in finished:
                # A failing cell must not discard its siblings' finished
                # work: store everything that succeeded, raise at the end
                # (resume then recomputes only the failed cells).
                try:
                    cid, result = fut.result()
                except Exception as e:  # noqa: BLE001 - reported below
                    failures[cid_of_future[fut]] = e
                    continue
                finish(cid, spec_of[cid], result)
    if failures:
        detail = "; ".join(f"{cid}: {e!r}" for cid, e in sorted(failures.items()))
        raise RuntimeError(
            f"{len(failures)} sweep cell(s) failed ({detail}); "
            f"{len(results)} finished cells were stored"
        )
    return results
