"""The Hadoop Fair Sojourn Protocol scheduler (Sect. 3).

HFSP is a *hierarchical* scheduler (Sect. 3.1.1):

* the **top-level scheduler** balances slots between the Training module
  (job size estimation, Sect. 3.2) and the job scheduler;
* the **job scheduler** ranks jobs by their projected finish time under a
  simulated max-min-fair processor-sharing discipline (the *virtual
  cluster*, Sect. 3.1) and focuses real cluster resources on the jobs that
  would finish first, preempting jobs that would finish later;
* **preemption** (Sect. 3.3) is EAGER (suspend/resume), WAIT (drain) or
  KILL, with a hysteresis fallback EAGER->WAIT when too much task state is
  suspended ("Finite machine resources").

Interaction rules between delay scheduling and preemption (these matter —
naive composition causes suspend/resume thrash):

* a job that *voluntarily declined* free slots this pass (delay
  scheduling, hoping for data locality) must NOT preempt other jobs in the
  same pass — preemption is for jobs that genuinely cannot be served;
* slots freed *by* preemption are assigned immediately, bypassing the
  delay-scheduling wait (locality was already forfeited by deciding to
  preempt).

The scheduler is pure decision logic: it runs unmodified under the
discrete-event simulator (:mod:`repro.core.simulator`, the paper's Mumak
analogue) and under the JAX gang runtime (:mod:`repro.runtime`).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

from repro.core.estimator import (
    FirstOrderEstimator,
    TaskTimeEstimator,
    TrainingModule,
)
from repro.core.scheduler import (
    Action,
    ClusterView,
    Kill,
    Resume,
    Scheduler,
    SchedulerConfig,
    Suspend,
)
from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    Preemption,
    SlotKey,
    TaskAttempt,
    TaskState,
)
from repro.core.vcluster import VirtualCluster


@dataclass
class HFSPConfig(SchedulerConfig):
    """Paper defaults (Sect. 4.1): sample set 5, Delta = 60 s, xi = 1,
    Training module may use the whole cluster, eager preemption on."""

    preemption: Preemption = Preemption.EAGER
    sample_set_size: int = 5
    delta: float = 60.0
    xi: float = 1.0
    # Max slots the top-level scheduler grants the Training module (Sect.
    # 3.2: bounded "to avoid starvation in the job scheduler, for workloads
    # with bursty arrivals").  None = all slots (the paper's configuration).
    max_training_slots: int | None = None
    estimator_factory: Callable[[], TaskTimeEstimator] = FirstOrderEstimator
    # Multiplicative error injected into finalized size estimates, used by
    # the Fig. 6 robustness experiment: a wrong estimate is drawn uniformly
    # in [size*(1-alpha), size*(1+alpha)].
    error_alpha: float = 0.0
    error_seed: int = 0


class HFSPScheduler(Scheduler):
    name = "hfsp"

    def __init__(self, cluster: ClusterSpec, config: HFSPConfig | None = None):
        cfg = config or HFSPConfig()
        super().__init__(cluster, cfg)
        self.config: HFSPConfig = cfg
        self.training = TrainingModule(
            sample_set_size=cfg.sample_set_size,
            delta=cfg.delta,
            xi=cfg.xi,
            estimator=cfg.estimator_factory(),
        )
        self.vc: dict[Phase, VirtualCluster] = {
            p: VirtualCluster(phase=p, slots=cluster.slots(p))
            for p in (Phase.MAP, Phase.REDUCE)
        }
        self._clock = 0.0
        self._eager_enabled = True  # hysteresis state (Sect. 3.3)
        if cfg.error_alpha > 0:
            import numpy as _np

            self._err_rng = _np.random.default_rng(cfg.error_seed)
        else:
            self._err_rng = None

    # ------------------------------------------------------------------
    # Aging (Sect. 3.1): each event distributes elapsed time as progress
    # to every allocated virtual task.
    # ------------------------------------------------------------------
    def _advance(self, now: float) -> None:
        dt = now - self._clock
        if dt > 0:
            for vc in self.vc.values():
                vc.age(dt)
            self._clock = now

    # ------------------------------------------------------------------
    # Events
    # ------------------------------------------------------------------
    def on_job_arrival(self, spec: JobSpec, now: float) -> JobState:
        self._advance(now)
        js = super().on_job_arrival(spec, now)
        self._start_phase(js, Phase.MAP)
        self._maybe_unlock_reduce(js)
        return js

    def _perturb(self, est: float) -> float:
        """Fig. 6 error injection on *finalized* estimates."""
        if self._err_rng is None or not math.isfinite(est):
            return est
        a = self.config.error_alpha
        return float(est * self._err_rng.uniform(1.0 - a, 1.0 + a))

    def _start_phase(self, js: JobState, phase: Phase) -> None:
        tasks = js.spec.tasks(phase)
        est = self.training.start_phase(js, phase)
        js.est_size[phase] = est
        if tasks:
            self.vc[phase].add_job(
                js.spec.job_id, est, len(tasks), weight=js.spec.weight
            )

    def _maybe_unlock_reduce(self, js: JobState) -> None:
        if (
            js.spec.reduce_tasks
            and js.spec.job_id not in self.vc[Phase.REDUCE]
            and Phase.REDUCE not in js.est_size
            and js.reduce_unlocked()
        ):
            self._start_phase(js, Phase.REDUCE)

    def on_task_complete(self, job_id: int, key: tuple, now: float) -> None:
        self._advance(now)
        js = self.jobs.get(job_id)
        if js is None:
            return
        phase = Phase(key[1])
        att = js.tasks[key]
        new_est = self.training.observe_completion(
            js, phase, key, att.spec.duration
        )
        vc = self.vc[phase]
        if new_est is not None:
            new_est = self._perturb(new_est)
            js.est_size[phase] = new_est
            vc.set_size(job_id, new_est)
        if js.n_unfinished(phase) == 0:
            vc.remove_job(job_id)
        # NOTE: real task completions do NOT shrink the virtual cap — the
        # virtual cluster is a pure PS simulation (see vcluster docstring).
        if phase is Phase.MAP:
            self._maybe_unlock_reduce(js)

    def on_task_progress(
        self, job_id: int, key: tuple, fraction: float, elapsed: float, now: float
    ) -> None:
        """REDUCE-style early size estimation: sigma = Delta / p (Sect. 3.2.1)."""
        self._advance(now)
        js = self.jobs.get(job_id)
        if js is None:
            return
        phase = Phase(key[1])
        new_est = self.training.observe_progress(js, phase, key, fraction, elapsed)
        if new_est is not None:
            new_est = self._perturb(new_est)
            js.est_size[phase] = new_est
            self.vc[phase].set_size(job_id, new_est)

    def on_job_complete(self, job_id: int, now: float) -> None:
        self._advance(now)
        super().on_job_complete(job_id, now)
        for vc in self.vc.values():
            vc.remove_job(job_id)
        self._skip_counts.pop(job_id, None)

    def on_tick(self, now: float) -> None:
        self._advance(now)

    # ------------------------------------------------------------------
    # Scheduling
    # ------------------------------------------------------------------
    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        self._advance(now)
        self._begin_pass()
        self._update_hysteresis(view)
        actions: list[Action] = []
        for phase in (Phase.MAP, Phase.REDUCE):
            actions.extend(self._phase_schedule(view, phase, now))
        return actions

    def _update_hysteresis(self, view: ClusterView) -> None:
        """EAGER -> WAIT fallback on suspended-state pressure (Sect. 3.3)."""
        total = view.total_suspended_bytes()
        if self._eager_enabled and total > self.cluster.suspend_bytes_hi:
            self._eager_enabled = False
            self.stats.hysteresis_fallbacks += 1
        elif not self._eager_enabled and total < self.cluster.suspend_bytes_lo:
            self._eager_enabled = True

    def _phase_schedule(
        self, view: ClusterView, phase: Phase, now: float
    ) -> list[Action]:
        actions: list[Action] = []
        live = {js.spec.job_id: js for js in self.live_jobs(phase)}
        if not live:
            return actions
        free = list(view.free_slots(phase))
        # Jobs ranked by projected PS finish time (Sect. 3.1).  Jobs whose
        # phase is live but unknown to the virtual cluster (zero tasks)
        # cannot appear here; jobs with infinite estimates sort last.
        order = [j for j in self.vc[phase].schedule_order(now) if j in live]
        pos_of = {j: i for i, j in enumerate(order)}

        # Pass-wide victim indices (running tasks of live jobs), built
        # LAZILY — most passes never preempt, and building the indices is
        # the single most expensive part of a pass.
        # run_by_machine[m] = [(pos, att)] sorted ascending by pos — victims
        # are taken from the END (largest projected finish first, which the
        # paper phrases as "jobs sorted in decreasing order of their size").
        slot_of: dict[tuple, SlotKey] = {}
        run_by_machine: dict[int, list[tuple[int, TaskAttempt]]] = {}
        run_by_job: dict[int, list[TaskAttempt]] = {}
        indices_built = False

        def ensure_indices() -> None:
            nonlocal indices_built
            if indices_built:
                return
            indices_built = True
            for slot, att in view.occupied_slots(phase).items():
                slot_of[att.spec.key] = slot
                p = pos_of.get(att.spec.job_id)
                if p is None:
                    continue  # job not live in this phase (shouldn't happen)
                run_by_machine.setdefault(slot.machine, []).append((p, att))
                run_by_job.setdefault(att.spec.job_id, []).append(att)
            for lst in run_by_machine.values():
                lst.sort(key=lambda t: t[0])

        eager_ok = (
            self.config.preemption is Preemption.EAGER and self._eager_enabled
        )
        protected = self._protected_keys(live, phase)

        # -- 1. Top-level scheduler: Training-module slots first.  "The
        # top-level scheduler responds to the arrival of a new job by
        # allocating a given set of resources to the Training module"
        # (Sect. 3.1.1) — under full load that requires preempting up to
        # the training job's fair share.
        acts, free = self._schedule_training(
            live, order, phase, free, now,
            ensure_indices, run_by_job, slot_of, eager_ok, protected,
        )
        actions.extend(acts)

        # -- 2. Job scheduler: focus resources in projected-finish order ---
        for pos, jid in enumerate(order):
            js = live[jid]
            # Resume suspended tasks in place (Sect. 3.3 locality), possibly
            # suspending tasks of *later-ordered* jobs on the same machine.
            if js.n_suspended(phase):
                ensure_indices()
                acts, free = self._resume_with_preemption(
                    js, pos, phase, free, run_by_machine, slot_of, eager_ok,
                    protected,
                )
                actions.extend(acts)
            # Start pending tasks on free slots (delay scheduling inside).
            n_delayed_before = self.stats.delay_sched_waits
            acts, free = self._assign_pending(js, phase, free, len(free), now)
            actions.extend(acts)
            delayed = self.stats.delay_sched_waits > n_delayed_before
            # Preempt later jobs for remaining unmet demand — but never on
            # behalf of a job that just declined slots to wait for locality.
            unmet = self._unclaimed_pending(js, phase)
            if unmet > 0 and not free and not delayed:
                ensure_indices()
                acts, freed = self._preempt_for(
                    js, pos, phase, unmet, order, run_by_job, slot_of,
                    eager_ok, protected,
                )
                actions.extend(acts)
                if freed:
                    # Bypass delay scheduling: locality was forfeited when we
                    # chose to preempt.
                    saved = self.config.locality_enabled
                    self.config.locality_enabled = False
                    try:
                        acts, left = self._assign_pending(
                            js, phase, freed, len(freed), now
                        )
                    finally:
                        self.config.locality_enabled = saved
                    actions.extend(acts)
                    free.extend(left)
        return actions

    # -- training module (Sect. 3.2) -----------------------------------
    def _schedule_training(
        self,
        live: dict[int, JobState],
        order: list[int],
        phase: Phase,
        free: list[SlotKey],
        now: float,
        ensure_indices,
        run_by_job: dict,
        slot_of: dict,
        eager_ok: bool,
        protected: set,
    ) -> tuple[list[Action], list[SlotKey]]:
        actions: list[Action] = []
        training_jobs = [
            live[j] for j in live if self.training.is_training(j, phase)
        ]
        if not training_jobs:
            return actions, free
        # "Execution slots are assigned according to a 'fewer remaining
        # tasks' discipline, which implies short jobs are given priority."
        training_jobs.sort(
            key=lambda js: (js.n_unfinished(phase), js.spec.arrival_time)
        )
        budget = self._training_budget(live, phase)
        fair = max(1, self.cluster.slots(phase) // max(len(live), 1))
        mode = self.config.preemption
        can_preempt = not (
            mode is Preemption.WAIT
            or (mode is Preemption.EAGER and not eager_ok)
        )
        for js in training_jobs:
            wanted = self.training.wanted_sample_tasks(js, phase)
            if not wanted:
                continue
            quota = min(len(wanted), fair)
            # Free-slot assignments consume the global training budget;
            # preemption below merely SUBSTITUTES one training slot for
            # another, so it is not budget-gated.
            acts, free = self._assign_pending(
                js, phase, free, min(quota, max(budget, 0)), now,
                only_keys=wanted,
            )
            self.stats.training_tasks += len(acts)
            budget -= len(acts)
            quota -= len(acts)
            actions.extend(acts)
            # In-flight sample tasks count toward the fair share already
            # granted; only preempt for the genuinely unmet remainder.
            running_samples = sum(
                1
                for k in self.training.sample_keys(js.spec.job_id, phase)
                if js.tasks[k].state is TaskState.RUNNING
            )
            unmet = min(quota, max(0, fair - running_samples))
            if unmet > 0 and not free and can_preempt:
                ensure_indices()
                # Victims: last-ordered (largest) jobs first, never self.
                pos_self = order.index(js.spec.job_id)
                acts, freed = self._preempt_for(
                    js, -1, phase, unmet,
                    [j for j in order if j != js.spec.job_id],
                    run_by_job, slot_of, eager_ok, protected,
                )
                actions.extend(acts)
                if freed:
                    saved = self.config.locality_enabled
                    self.config.locality_enabled = False
                    try:
                        a2, left = self._assign_pending(
                            js, phase, freed, len(freed), now,
                            only_keys=self.training.wanted_sample_tasks(js, phase),
                        )
                    finally:
                        self.config.locality_enabled = saved
                    self.stats.training_tasks += len(a2)
                    budget -= len(a2)
                    actions.extend(a2)
                    free.extend(left)
        return actions, free

    def _training_budget(self, live: dict[int, JobState], phase: Phase) -> int:
        cap = self.config.max_training_slots
        if cap is None:
            cap = self.cluster.slots(phase)
        # Slots currently held by still-training sample tasks count against
        # the budget (sample sets are <= 5 keys: check task state directly).
        in_flight = 0
        for js in live.values():
            if not self.training.is_training(js.spec.job_id, phase):
                continue
            for k in self.training.sample_keys(js.spec.job_id, phase):
                if js.tasks[k].state is TaskState.RUNNING:
                    in_flight += 1
        return max(0, cap - in_flight)

    # -- preemption (Sect. 3.3) ------------------------------------------
    def _protected_keys(self, live: dict, phase: Phase) -> set:
        """Running sample tasks shielded from preemption.  The Training
        module holds "at least a fair share" (Sect. 3.1.1) — a QUOTA of
        slots/num_jobs per training job, NOT blanket immunity (protecting
        every sample task would let one big in-training job starve a tiny
        arrival for a full task length)."""
        # Integer fair share, floored at 1: a running sample task is ALWAYS
        # shielded — two in-training jobs may otherwise kill each other's
        # samples every pass (progress resets under KILL => livelock).
        quota = max(1, self.cluster.slots(phase) // max(len(live), 1))
        out: set = set()
        for jid, js in live.items():
            if not self.training.is_training(jid, phase):
                continue
            shielded = 0
            for key in self.training.sample_keys(jid, phase):
                if shielded >= quota:
                    break
                if js.tasks[key].state is TaskState.RUNNING:
                    out.add(key)
                    shielded += 1
        return out

    def _preempt_for(
        self,
        js: JobState,
        pos: int,
        phase: Phase,
        unmet: int,
        order: list[int],
        run_by_job: dict[int, list[TaskAttempt]],
        slot_of: dict[tuple, SlotKey],
        eager_ok: bool,
        protected: set,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Free up to ``unmet`` slots held by later-ordered jobs, walking the
        order from the back (largest projected finish / size first)."""
        actions: list[Action] = []
        freed: list[SlotKey] = []
        mode = self.config.preemption
        wait_mode = mode is Preemption.WAIT or (
            mode is Preemption.EAGER and not eager_ok
        )
        for vjid in reversed(order[pos + 1 :]):
            if unmet <= 0:
                break
            victims = run_by_job.get(vjid, ())
            if victims and self.training.is_training(vjid, phase):
                # Prefer non-sample tasks: suspending a sample silently
                # cancels its runtime observation and stalls estimation.
                sample = set(self.training.sample_keys(vjid, phase))
                victims = sorted(
                    victims, key=lambda a: a.spec.key in sample
                )
            for att in victims:
                if unmet <= 0:
                    break
                key = att.spec.key
                if (
                    key in self._claimed
                    or att.state is not TaskState.RUNNING
                    or key in protected
                ):
                    continue
                if wait_mode:
                    self.stats.waits += 1
                    unmet -= 1  # we *would* preempt; count and move on
                    continue
                slot = slot_of.get(key)
                if slot is None:
                    continue
                self._claimed.add(key)
                if mode is Preemption.EAGER:
                    actions.append(Suspend(att))
                    self.stats.suspensions += 1
                else:  # KILL
                    actions.append(Kill(att))
                    self.stats.kills += 1
                freed.append(slot)
                unmet -= 1
        return actions, freed

    def _resume_with_preemption(
        self,
        js: JobState,
        pos: int,
        phase: Phase,
        free: list[SlotKey],
        run_by_machine: dict[int, list[tuple[int, TaskAttempt]]],
        slot_of: dict[tuple, SlotKey],
        eager_ok: bool,
        protected: set,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Resume suspended tasks *on the machine that holds their state*
        (Sect. 3.3 "Impact on data locality"): free slot if available, else
        suspend a later-ordered job's task on that machine, else wait."""
        actions: list[Action] = []
        if not js.n_suspended(phase):
            return actions, free
        free = list(free)
        for att in js.suspended(phase):
            if att.spec.key in self._claimed:
                continue
            m = att.machine if att.machine is not None else -1
            slot = next((s for s in free if s.machine == m), None)
            if slot is not None:
                free.remove(slot)
                self._claimed.add(att.spec.key)
                actions.append(Resume(att, slot))
                self.stats.resumes += 1
                continue
            if not eager_ok:
                continue
            # Largest-position (latest-finishing) victim on this machine.
            entries = run_by_machine.get(m, [])
            for vpos, victim in reversed(entries):
                if vpos <= pos:
                    break  # all remaining victims are earlier-ordered: wait
                vkey = victim.spec.key
                if (
                    vkey in self._claimed
                    or victim.state is not TaskState.RUNNING
                    or vkey in protected
                ):
                    continue
                vslot = slot_of.get(vkey)
                if vslot is None:
                    continue
                self._claimed.add(vkey)
                actions.append(Suspend(victim))
                self.stats.suspensions += 1
                self._claimed.add(att.spec.key)
                actions.append(Resume(att, vslot))
                self.stats.resumes += 1
                break
        return actions, free
