#!/usr/bin/env python
"""Print the paper-faults@quick goodput/sojourn summary.

Runs the reduced-scale fault-robustness matrix (scheduling under machine
crashes, task failures, stragglers, and estimation-sample loss — see
docs/faults.md) and prints one line per cell: mean sojourn next to
goodput, retries, and speculation wins.  Exits non-zero if any cell lost
a job — fault recovery must always complete the workload.

scripts/check.sh runs this after the perf-trajectory gate; the
determinism and robustness properties themselves are pinned by
tests/test_faults.py, this output is the human-readable trend line.

Usage:
  PYTHONPATH=src python scripts/faults_summary.py [--workers N]
"""

from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    from repro.scenarios import get_preset, quick_sweep, run_sweep

    sweep = quick_sweep(get_preset("paper-faults"))
    results = run_sweep(sweep, workers=args.workers)
    lost = 0
    for cid in sorted(results, key=lambda c: results[c]["mean_sojourn_s"]):
        r = results[cid]
        f = r["faults"]
        lost += r["jobs_lost"]
        print(
            f"{cid}: mean_sojourn {r['mean_sojourn_s']:7.1f}s  "
            f"goodput {f['goodput']:.3f}  retries {f['retries']:4d}  "
            f"spec_wins {f['speculative_wins']:3d}"
        )
    print(f"jobs lost across {len(results)} faulted cells: {lost}")
    if lost:
        print("faults_summary: fault recovery lost jobs", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
