from repro.workload.fb import (
    FB_CLASSES,
    WorkloadSpec,
    fb_cluster,
    fb_dataset,
    fb_scaled_dataset,
    job_class,
    ml_dataset,
)

__all__ = [
    "FB_CLASSES",
    "WorkloadSpec",
    "fb_cluster",
    "fb_dataset",
    "fb_scaled_dataset",
    "job_class",
    "ml_dataset",
]
