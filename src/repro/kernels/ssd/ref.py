"""Pure-jnp oracle for the ssd kernel: the sequential scan from
repro.models.ssd in the kernel's (b, h, t, p) layout."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssd import ssd_scan_ref


def ssd_ref(x, dt, a, B, C, s0):
    """(b,h,t,p) layout -> (y, final_state), fp32."""
    to_bt = lambda v: jnp.moveaxis(v, 1, 2)   # (b,h,t,*) -> (b,t,h,*)
    y, s = ssd_scan_ref(
        to_bt(x).astype(jnp.float32),
        to_bt(dt).astype(jnp.float32),
        to_bt(a).astype(jnp.float32),
        B.astype(jnp.float32),
        C.astype(jnp.float32),
        s0.astype(jnp.float32),
    )
    return jnp.moveaxis(y, 2, 1), s
