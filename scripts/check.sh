#!/usr/bin/env bash
# One-command gate: tier-1 tests (including the fault-injection
# determinism/robustness suite, tests/test_faults.py) + the quick
# scheduler benchmark (which includes the paper-fb@quick scenario smoke
# sweep, the sparse-demand 5000x1000 decision-latency cell, and the
# epsilon-window coalescing sweep) + the perf-trajectory gate (appends
# BENCH_sched.json to BENCH_history.jsonl and fails on a >25% hfsp
# wall-clock regression OR a >25% sparse-demand decision-latency
# regression (0.3ms noise floor) OR a >10% per-scenario mean-sojourn
# regression — policy-level quality, not just speed — vs the previous
# entry) + a paper-faults@quick goodput/sojourn summary (scheduling
# under machine/task failures; informational, the properties themselves
# are pinned by tests/test_faults.py) + the live-service smoke + the
# distributed-sweep smoke (2 workers, 1 SIGKILLed; exactly-once
# convergence with a reclaimed lease).
#
#   scripts/check.sh            # tests + quick bench + trajectory gate
#   scripts/check.sh --no-bench # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
  echo
  echo "== quick scheduler benchmark =="
  python -m benchmarks.run --quick --json BENCH_sched.json
  echo
  echo "== perf trajectory gate =="
  python scripts/bench_gate.py --json BENCH_sched.json \
    --history BENCH_history.jsonl --threshold 0.25
  echo
  echo "== epsilon-window pass-count delta =="
  python - <<'PY'
import json
rec = json.load(open("BENCH_sched.json"))
sweep = rec.get("eps_sweep", {})
# Ratios use passes_per_event: rows that hit the sweep's wall-clock
# safety cap processed fewer events, so raw pass counts don't compare.
base = sweep.get("0.0", {}).get("passes_per_event")
for eps in sorted(sweep, key=float):
    row = sweep[eps]
    delta = (
        f" ({row['passes_per_event'] / base:.1%} of eps=0 passes/event)"
        if base and float(eps) > 0 else ""
    )
    print(
        f"eps={eps}: {row['passes']} passes / {row['events']} events"
        f"{delta}"
    )
PY
  echo
  echo "== paper-faults@quick goodput/sojourn =="
  python scripts/faults_summary.py --workers 4

  echo
  echo "== live service smoke (twin fingerprint + p99 decision latency) =="
  # Master + 2 in-process workers, 50-job burst, one worker killed
  # mid-workload; fails if the journal's Simulator replay diverges from
  # the live run or p99 decision latency blows past the bound.
  python scripts/service_smoke.py --jobs 50 --p99-ms 250

  echo
  echo "== distributed sweep smoke (2 workers, 1 SIGKILLed mid-cell) =="
  # Two CLI workers share a store on paper-fb@quick; the one holding a
  # lease is SIGKILLed mid-cell.  Fails unless the survivor reclaims
  # the lease (reissues >= 1) and the sweep converges exactly-once with
  # zero quarantines.
  python scripts/dist_sweep_smoke.py
fi
