"""Discipline API tests (repro.core.disciplines).

Four contracts:

* **registry** — register / override / unknown-name errors, and the
  scenario layer's lazy policy validation (specs accept any name;
  resolution errors list what is registered);
* **rank policies in isolation** — SRPT / LAS / arrival keys and the
  KeyedRankPolicy order cache on hand-built job states;
* **routing equivalence** — fifo/fair/hfsp/hfsp-kill built through
  ``disciplines.build_scheduler`` are bit-identical to direct
  construction on the golden traces (the acceptance bar for re-routing
  the legacy schedulers through the registry);
* **new-discipline goldens** — srpt/las/psbs on the golden traces:
  bit-identical across vcluster backends (numpy / jax / auto),
  demand-indexed vs legacy-walk passes, eps=0 vs the default loop, and
  reproducible at eps=0.5.
"""

import pytest

from conformance import (
    DISCIPLINE_SCHEDULERS,
    GOLDEN_SEEDS,
    TRACE_SCHEDULERS,
    assert_traces_equal,
    run_trace,
)
from repro.core import disciplines
from repro.core.disciplines import (
    ArrivalRank,
    Discipline,
    DisciplineRegistry,
    LASRank,
    SRPTRank,
    StabilityHysteresis,
)
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.types import ClusterSpec, JobSpec, Phase, TaskSpec


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def _noop_discipline(name: str) -> Discipline:
    return Discipline(name=name, build=lambda cluster, **kw: None)


def test_registry_register_get_names():
    reg = DisciplineRegistry()
    d = reg.register("x", _noop_discipline("x"))
    assert reg.get("x") is d
    assert reg.names() == ["x"]


def test_registry_duplicate_requires_override():
    reg = DisciplineRegistry()
    reg.register("x", _noop_discipline("x"))
    with pytest.raises(ValueError, match="already registered"):
        reg.register("x", _noop_discipline("x2"))
    d2 = reg.register("x", _noop_discipline("x2"), override=True)
    assert reg.get("x") is d2


def test_registry_unknown_name_lists_registered():
    reg = DisciplineRegistry()
    reg.register("aaa", _noop_discipline("aaa"))
    reg.register("bbb", _noop_discipline("bbb"))
    with pytest.raises(KeyError, match="aaa, bbb"):
        reg.get("nope")


def test_builtin_disciplines_registered():
    assert {"fifo", "fair", "hfsp", "srpt", "las", "psbs"} <= set(
        disciplines.names()
    )


def test_legacy_schedulers_declare_their_rank_assembly():
    """The fifo/fair classes are assemblies of the discipline ranks:
    the class attribute is the linkage (and the queue key IS the
    ArrivalRank key), matching what the registry metadata reports."""
    from repro.core.disciplines import FairDeficitRank
    from repro.core.fair import FairScheduler
    from repro.core.fifo import FIFOScheduler, job_sort_key_fifo

    assert FIFOScheduler.rank_policy is ArrivalRank
    assert job_sort_key_fifo is ArrivalRank.key_of
    assert FairScheduler.rank_policy is FairDeficitRank
    assert disciplines.get("fifo").rank == ArrivalRank.name
    assert disciplines.get("fair").rank == FairDeficitRank.name


def test_scheduler_axis_accepts_unregistered_policy_lazily():
    """Satellite: specs are plain data — an unknown policy constructs
    (and round-trips) fine; the error comes at resolve time and names
    the registered disciplines."""
    from repro.scenarios.runner import build_scheduler
    from repro.scenarios.spec import ScenarioSpec, SchedulerAxis

    spec = ScenarioSpec(scheduler=SchedulerAxis(policy="not-registered"))
    assert ScenarioSpec.from_dict(spec.to_dict()) == spec  # still just data
    with pytest.raises(KeyError, match="hfsp"):
        build_scheduler(spec, ClusterSpec(num_machines=2))


def test_user_registered_discipline_resolves_through_scenario_runner():
    """Third-party registration is enough to make a policy sweepable."""
    from repro.scenarios.runner import simulate
    from repro.scenarios.spec import (
        ClusterAxis,
        ScenarioSpec,
        SchedulerAxis,
        WorkloadAxis,
    )

    name = "test-preemptive-fifo"
    disciplines.register(
        name,
        disciplines.engine_discipline(
            name, ArrivalRank, description="test-only"
        ),
        override=True,
    )
    try:
        spec = ScenarioSpec(
            name="custom",
            workload=WorkloadAxis(kind="fb", seed=0, num_jobs=8),
            cluster=ClusterAxis(num_machines=4),
            scheduler=SchedulerAxis(policy=name),
        )
        res, _, sch = simulate(spec)
        assert sch.name == name
        assert sch.rank.name == ArrivalRank.name
        assert len(res.completion) == 8
    finally:
        del disciplines.REGISTRY._disciplines[name]


# ---------------------------------------------------------------------------
# Rank policies in isolation
# ---------------------------------------------------------------------------
class _StubEngine(Scheduler):
    """Minimal concrete Scheduler: real demand indexes + attained
    counters, no decision logic."""

    def schedule(self, view, now):  # pragma: no cover - never scheduled
        return []


def _job(jid: int, n_tasks: int = 2, dur: float = 10.0, arrival: float = 0.0,
         weight: float = 1.0) -> JobSpec:
    return JobSpec(
        job_id=jid,
        arrival_time=arrival,
        map_tasks=tuple(
            TaskSpec(jid, Phase.MAP, i, dur) for i in range(n_tasks)
        ),
        reduce_tasks=(),
        weight=weight,
    )


def _stub_with_jobs(specs) -> _StubEngine:
    eng = _StubEngine(ClusterSpec(num_machines=2), SchedulerConfig())
    for spec in specs:
        eng.on_job_arrival(spec, spec.arrival_time)
    return eng


def test_srpt_rank_orders_by_estimated_remaining():
    eng = _stub_with_jobs([_job(1), _job(2), _job(3), _job(4)])
    rank = SRPTRank()
    eng.jobs[1].est_size[Phase.MAP] = 100.0
    eng.jobs[2].est_size[Phase.MAP] = 50.0
    eng.jobs[3].est_size[Phase.MAP] = 100.0
    # job 4 has no estimate -> infinite remaining -> sorts last
    eng._attained[(3, Phase.MAP.value)] = 70.0  # remaining 30: best
    order, pos = rank.order_and_pos(eng, Phase.MAP, 0.0)
    assert order == [3, 2, 1, 4]
    assert pos == {3: 0, 2: 1, 1: 2, 4: 3}


def test_srpt_rank_clamps_underestimates_to_zero():
    # attained > estimate (underestimated job): remaining clamps to 0 and
    # ties break by arrival -> the SRPT error-fragility the preset shows.
    eng = _stub_with_jobs([_job(1, arrival=5.0), _job(2, arrival=1.0)])
    eng.jobs[1].est_size[Phase.MAP] = 10.0
    eng.jobs[2].est_size[Phase.MAP] = 10.0
    eng._attained[(1, Phase.MAP.value)] = 99.0
    eng._attained[(2, Phase.MAP.value)] = 50.0
    order, _ = SRPTRank().order_and_pos(eng, Phase.MAP, 0.0)
    assert order == [2, 1]  # both clamp to 0; earlier arrival first


def test_las_rank_orders_by_attained_service():
    eng = _stub_with_jobs([_job(1), _job(2), _job(3)])
    eng._attained[(1, Phase.MAP.value)] = 40.0
    eng._attained[(2, Phase.MAP.value)] = 5.0
    order, _ = LASRank().order_and_pos(eng, Phase.MAP, 0.0)
    assert order == [3, 2, 1]  # untouched job (0 attained) first


def test_arrival_rank_matches_fifo_key():
    eng = _stub_with_jobs([
        _job(1, arrival=2.0), _job(2, arrival=1.0),
        _job(3, arrival=5.0, weight=2.0),  # higher weight wins
    ])
    order, _ = ArrivalRank().order_and_pos(eng, Phase.MAP, 0.0)
    assert order == [3, 2, 1]


def test_keyed_rank_cache_and_invalidate():
    eng = _stub_with_jobs([_job(1), _job(2)])
    rank = LASRank()
    order1, _ = rank.order_and_pos(eng, Phase.MAP, 0.0)
    assert order1 == [1, 2]
    eng._attained[(1, Phase.MAP.value)] = 100.0
    # Cached: the stale order survives until the engine invalidates.
    assert rank.order_and_pos(eng, Phase.MAP, 0.0)[0] == [1, 2]
    rank.invalidate(Phase.MAP)
    assert rank.order_and_pos(eng, Phase.MAP, 0.0)[0] == [2, 1]
    # invalidate() with no phase drops both phases.
    rank.invalidate()
    assert rank._order[Phase.MAP.value] is None


def test_attained_service_counters_follow_task_lifecycle():
    from repro.core.types import SlotKey, TaskState

    eng = _stub_with_jobs([_job(1, n_tasks=2, dur=10.0)])
    js = eng.jobs[1]
    atts = js.pending(Phase.MAP)
    slot = SlotKey(0, Phase.MAP, 0)
    # start -> no attained yet (progress not materialized)
    js.transition(atts[0], TaskState.RUNNING)
    eng.on_task_started(atts[0], slot)
    assert eng.attained_service(1, Phase.MAP) == 0.0
    # suspend at progress 4 -> materializes 4s
    atts[0].progress = 4.0
    js.transition(atts[0], TaskState.SUSPENDED)
    eng.on_task_suspended(atts[0])
    assert eng.attained_service(1, Phase.MAP) == 4.0
    # resume with DMA rollback to 3 -> counter follows down
    atts[0].progress = 3.0
    js.transition(atts[0], TaskState.RUNNING)
    eng.on_task_resumed(atts[0], slot)
    assert eng.attained_service(1, Phase.MAP) == 3.0
    # completion folds in the full duration exactly once
    atts[0].progress = 10.0
    js.transition(atts[0], TaskState.DONE)
    eng.on_task_complete(1, atts[0].spec.key, 10.0)
    assert eng.attained_service(1, Phase.MAP) == 10.0
    # kill discards the second task's counted service
    js.transition(atts[1], TaskState.RUNNING)
    eng.on_task_started(atts[1], slot)
    atts[1].progress = 6.0
    js.transition(atts[1], TaskState.SUSPENDED)
    eng.on_task_suspended(atts[1])
    assert eng.attained_service(1, Phase.MAP) == 16.0
    atts[1].progress = 2.0
    js.transition(atts[1], TaskState.RUNNING)
    eng.on_task_resumed(atts[1], slot)
    atts[1].progress = 0.0
    js.transition(atts[1], TaskState.PENDING)
    eng.on_task_killed(atts[1])
    assert eng.attained_service(1, Phase.MAP) == 10.0


# ---------------------------------------------------------------------------
# PSBS parts: late-job detection + stability hysteresis
# ---------------------------------------------------------------------------
def test_vcluster_virtually_done_and_horizon_gating():
    from repro.core.vcluster import VirtualCluster

    vc = VirtualCluster(phase=Phase.MAP, slots=4, backend="numpy")
    vc.add_job(1, est_size=100.0, num_tasks=2)   # earliest possible: 50s
    vc.add_job(2, est_size=1000.0, num_tasks=2)
    assert vc.virtually_done() == []
    vc.age(10.0)
    # 10 < 50: the horizon proves no job can have finished -> the lazy
    # aging queue must be untouched (O(1) steady-state reads).
    assert vc.virtually_done() == []
    assert vc._pending_dts, "horizon gate should not have materialized"
    vc.age(100.0)  # cumulative 110 > 50: job 1 (2 slots of 4) is done
    assert vc.virtually_done() == [1]
    # re-injecting virtual work (the PSBS bump) clears the late flag
    vc.set_remaining(1, 25.0)
    assert vc.virtually_done() == []
    vc.remove_job(1)
    assert 1 not in vc


def test_psbs_late_aging_bumps_late_jobs():
    """An underestimated job goes virtually-done long before its real
    tasks finish; PSBS re-injects virtual work and counts the bump."""
    from repro.core import Simulator
    from repro.workload import fb_cluster, fb_dataset

    cluster = fb_cluster(num_machines=20)
    jobs, _ = fb_dataset(seed=0, num_jobs=30)
    sch = disciplines.build_scheduler("psbs", cluster)
    out = Simulator(cluster, sch, jobs).run()
    assert out.stats.late_job_bumps > 0
    assert out.stats.rank_stability_checks > 0
    diag = sch.whatif_diagnostics()
    assert diag["discipline"] == "psbs"
    assert diag["late_job_bumps"] == out.stats.late_job_bumps


def test_stability_hysteresis_vetoes_and_caches():
    class _FakeTraining:
        def is_training(self, jid, phase):
            return True

        def n_observations(self, jid, phase):
            return 2

    class _FakeEngine:
        training = _FakeTraining()

        def __init__(self, positions):
            self.positions = positions
            self.calls = 0
            self.noted = []

        def rank_stability(self, jid, phase, now):
            self.calls += 1
            return self.positions

        def note_rank_stability(self, spread, vetoed):
            self.noted.append((spread, vetoed))

    class _JS:
        class spec:
            job_id = 7

    pol = StabilityHysteresis(max_spread=0)
    eng = _FakeEngine([0, 3, 1])
    assert pol.may_preempt(eng, _JS, Phase.MAP, 0.0) is False
    assert eng.noted[-1] == (3, True)
    # Cached per (job, phase, observation count): no second projection.
    assert pol.may_preempt(eng, _JS, Phase.MAP, 1.0) is False
    assert eng.calls == 1
    # A settled job passes (fresh policy: the verdict cache is keyed by
    # (job, phase, observation count), which the previous engine shares).
    eng2 = _FakeEngine([2, 2, 2])
    assert StabilityHysteresis(max_spread=0).may_preempt(
        eng2, _JS, Phase.MAP, 0.0
    ) is True
    assert eng2.noted[-1] == (0, False)


def test_stability_hysteresis_spread_reachable():
    """rank_stability must be able to report a nonzero spread (else the
    hysteresis hook could never fire): an in-training job with wildly
    different sample observations straddles settled jobs."""
    from repro.core import HFSPConfig, HFSPScheduler

    cluster = ClusterSpec(num_machines=2, map_slots_per_machine=2,
                          reduce_slots_per_machine=1)
    sch = HFSPScheduler(cluster, HFSPConfig(sample_set_size=3))
    for jid, dur in ((1, 10.0), (2, 11.0), (3, 12.0)):
        sch.on_job_arrival(_job(jid, n_tasks=4, dur=dur), 0.0)
        sch.vc[Phase.MAP].set_size(jid, 4 * dur)
    js = sch.on_job_arrival(_job(4, n_tasks=10, dur=10.0), 0.0)
    st = sch.training._training[(4, Phase.MAP)]
    st.observed[st.sample_keys[0]] = 1.0
    st.observed[st.sample_keys[1]] = 30.0
    pos = sch.rank_stability(4, Phase.MAP, 0.0)
    assert pos and max(pos) - min(pos) > 0


def test_rank_stability_batch_matches_per_job_calls():
    """The fused multi-job projection must return, per job, exactly the
    positions the per-job rank_stability call computes (scenario rows
    are independent — this is what makes the batched on_pass prefetch
    decision-neutral)."""
    from repro.core import HFSPConfig, HFSPScheduler

    cluster = ClusterSpec(num_machines=2, map_slots_per_machine=2,
                          reduce_slots_per_machine=1)
    sch = HFSPScheduler(cluster, HFSPConfig(sample_set_size=3))
    for jid, dur in ((1, 10.0), (2, 11.0), (3, 12.0)):
        sch.on_job_arrival(_job(jid, n_tasks=4, dur=dur), 0.0)
        sch.vc[Phase.MAP].set_size(jid, 4 * dur)
    # Two in-training jobs with spread-y observations.
    for jid, obs in ((4, (1.0, 30.0)), (5, (2.0, 25.0))):
        sch.on_job_arrival(_job(jid, n_tasks=10, dur=10.0), 0.0)
        st = sch.training._training[(jid, Phase.MAP)]
        st.observed[st.sample_keys[0]] = obs[0]
        st.observed[st.sample_keys[1]] = obs[1]
    want = {
        jid: sch.rank_stability(jid, Phase.MAP, 0.0) for jid in (4, 5, 99)
    }
    got = sch.rank_stability_batch(Phase.MAP, [4, 5, 99], 0.0)
    assert got == want
    assert got[4] and got[5] and got[99] == []
    assert sch.stats.rank_stability_batched == 2


@pytest.mark.parametrize("seed", GOLDEN_SEEDS)
def test_psbs_batched_prefetch_is_decision_neutral(seed, monkeypatch):
    """A psbs run with the batched on_pass prefetch disabled (forced
    back to the lazy per-job path) reproduces the default run bit for
    bit — completions, stats, pass counts."""
    lazy = None

    def _disable(self, engine, phase, now, have_free):
        return None

    with monkeypatch.context() as m:
        m.setattr(StabilityHysteresis, "on_pass", _disable)
        lazy = run_trace("psbs", seed)
    batched = run_trace("psbs", seed)
    assert_traces_equal(lazy, batched)


# ---------------------------------------------------------------------------
# Routing equivalence: legacy schedulers through the registry
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", GOLDEN_SEEDS)
@pytest.mark.parametrize("name", TRACE_SCHEDULERS)
def test_registry_routing_bit_identical(name, seed):
    direct = run_trace(name, seed)
    routed = run_trace(name, seed, via_registry=True)
    assert_traces_equal(direct, routed)


@pytest.mark.parametrize("name", ("hfsp", "hfsp-kill"))
def test_registry_routing_bit_identical_jax_backend(name):
    pytest.importorskip("jax")
    direct = run_trace(name, 0, vc_backend="jax")
    routed = run_trace(name, 0, vc_backend="jax", via_registry=True)
    assert_traces_equal(direct, routed)


# ---------------------------------------------------------------------------
# New-discipline golden traces
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", GOLDEN_SEEDS)
@pytest.mark.parametrize("name", DISCIPLINE_SCHEDULERS)
def test_discipline_backend_conformance(name, seed):
    """numpy / jax / auto vcluster backends are bit-identical for the
    new disciplines (psbs exercises the kernels on every pass; srpt/las
    pin that the knob is inert where it should be)."""
    pytest.importorskip("jax")
    ref = run_trace(name, seed, vc_backend="numpy")
    assert_traces_equal(ref, run_trace(name, seed, vc_backend="jax"))
    assert_traces_equal(
        ref, run_trace(name, seed, vc_backend="auto", vc_auto_threshold=5)
    )


@pytest.mark.parametrize("name", DISCIPLINE_SCHEDULERS)
def test_discipline_completes_and_orders_distinctly(name):
    res = run_trace(name, 0)
    assert len(res["completion"]) == 30


def test_disciplines_produce_distinct_schedules():
    """srpt / las / psbs are genuinely different disciplines on the
    golden trace — not three names for one schedule."""
    runs = {n: run_trace(n, 0) for n in DISCIPLINE_SCHEDULERS}
    comps = {n: tuple(sorted(r["completion"].items())) for n, r in runs.items()}
    assert comps["srpt"] != comps["las"]
    assert comps["srpt"] != comps["psbs"]
    assert comps["las"] != comps["psbs"]


@pytest.mark.parametrize("name", DISCIPLINE_SCHEDULERS)
def test_discipline_demand_index_equivalence(name):
    """The demand-indexed pass and the legacy full walk agree for the
    new disciplines too (the KeyedRankPolicy order is shared state, like
    the vcluster caches; paranoid mode cross-checks the indexes)."""
    indexed = run_trace(name, 0, paranoid=True)
    legacy = run_trace(name, 0, demand_indexed=False)
    assert_traces_equal(indexed, legacy)


@pytest.mark.parametrize("name", DISCIPLINE_SCHEDULERS)
def test_discipline_eps_zero_bit_identical(name):
    ref = run_trace(name, 0)
    assert_traces_equal(ref, run_trace(name, 0, event_epsilon=0.0))


@pytest.mark.parametrize("name", DISCIPLINE_SCHEDULERS)
def test_discipline_eps_half_reproducible(name):
    a = run_trace(name, 0, event_epsilon=0.5)
    b = run_trace(name, 0, event_epsilon=0.5)
    assert_traces_equal(a, b)
    assert len(a["completion"]) == 30


# ---------------------------------------------------------------------------
# Wall-clock refresh (the first on_wall_tick consumer)
# ---------------------------------------------------------------------------
def test_wall_tick_gated_by_refresh_interval():
    """on_wall_tick fires at most once per ``wall_refresh_every`` wall
    seconds, and a non-positive interval disables it entirely."""
    from repro.workload import fb_cluster

    sch = disciplines.build_scheduler("psbs", fb_cluster(num_machines=4))
    assert sch.config.wall_refresh_every == 10.0
    sch.on_wall_tick(100.0, 0.0)
    assert sch.stats.wall_refreshes == 1
    sch.on_wall_tick(105.0, 0.0)  # inside the interval: gated
    assert sch.stats.wall_refreshes == 1
    sch.on_wall_tick(110.0, 0.0)
    assert sch.stats.wall_refreshes == 2

    sch.config.wall_refresh_every = 0.0
    sch.on_wall_tick(1000.0, 0.0)
    assert sch.stats.wall_refreshes == 2  # disabled


def test_wall_refresh_reprices_stale_verdicts():
    """A wall refresh drains the hysteresis policy's dirty set: stale
    verdicts are re-priced through the batched projection and the cached
    verdict matches what the lazy may_preempt path computes."""
    from repro.core import HFSPConfig, HFSPScheduler

    cluster = ClusterSpec(num_machines=2, map_slots_per_machine=2,
                          reduce_slots_per_machine=1)

    def build():
        sch = HFSPScheduler(
            cluster,
            HFSPConfig(sample_set_size=3),
            preemption_policy=StabilityHysteresis(max_spread=0),
        )
        for jid, dur in ((1, 10.0), (2, 11.0), (3, 12.0)):
            sch.on_job_arrival(_job(jid, n_tasks=4, dur=dur), 0.0)
            sch.vc[Phase.MAP].set_size(jid, 4 * dur)
        sch.on_job_arrival(_job(4, n_tasks=10, dur=10.0), 0.0)
        st = sch.training._training[(4, Phase.MAP)]
        st.observed[st.sample_keys[0]] = 1.0
        st.observed[st.sample_keys[1]] = 30.0
        return sch

    # Eager path: mark the verdict stale, drain it via on_wall_tick.
    sch = build()
    pol = sch.preemption_policy
    pol.on_estimate(sch, 4, Phase.MAP)
    sch.on_wall_tick(50.0, 0.0)
    assert sch.stats.wall_refreshes == 1
    assert sch.stats.wall_refreshed_verdicts == 1
    assert not pol._dirty[Phase.MAP.value]
    cached = pol._cache[(4, Phase.MAP.value)]

    # Lazy path on an identical engine: may_preempt must agree with the
    # refreshed cache bit-for-bit (decision neutrality).
    sch2 = build()
    pol2 = sch2.preemption_policy
    js = sch2.jobs[4]
    verdict = pol2.may_preempt(sch2, js, Phase.MAP, 0.0)
    assert pol2._cache[(4, Phase.MAP.value)] == cached
    assert verdict is (not cached[2])


def test_wall_tick_preserves_sim_purity():
    """Completion times are bit-identical whether or not wall ticks
    interleave the simulation — the refresh hook is decision-neutral, so
    the service's replay twin (which never ticks) stays faithful."""
    from repro.core import Simulator
    from repro.workload import fb_cluster, fb_dataset

    cluster = fb_cluster(num_machines=10)

    def run(tick: bool):
        jobs, _ = fb_dataset(seed=0, num_jobs=20)
        sch = disciplines.build_scheduler("psbs", cluster)
        sim = Simulator(cluster, sch, jobs)
        if not tick:
            return sim.run(), sch
        res, wall, t = None, 0.0, 0.0
        while True:
            t += 25.0
            res = sim.run(until=t)
            wall += 11.0  # one refresh interval per slice
            sch.on_wall_tick(wall, t)
            if not sim._heap:
                return sim.run(), sch

    ticked, sch_t = run(tick=True)
    plain, _ = run(tick=False)
    assert sch_t.stats.wall_refreshes > 0
    assert sorted(ticked.completion.items()) == sorted(plain.completion.items())
