"""Kernel-layer benchmark: wall time of the chunked-parallel forms vs the
sequential reference scans (CPU, jit-compiled jnp paths; the Pallas kernels
themselves are validated in interpret mode — timing them interpreted is
meaningless, so this measures the algorithmic win of the chunked forms,
which is the same restructuring the TPU kernels implement)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import CsvOut


def _timeit(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e3  # ms


def main(out=None) -> dict:
    from repro.models.rwkv import rwkv_scan_chunked, rwkv_scan_ref
    from repro.models.ssd import ssd_scan_chunked, ssd_scan_ref

    table = CsvOut("kernels", ["kernel", "path", "ms_per_call", "speedup"])
    results = {}

    b, t, h, d = 2, 2048, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, t, h, d))
    v = jax.random.normal(ks[2], (b, t, h, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, d)) - 2))
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    ref = jax.jit(lambda *a: rwkv_scan_ref(*a)[0])
    chk = jax.jit(lambda *a: rwkv_scan_chunked(*a)[0])
    t_ref = _timeit(ref, r, k, v, w, u, s0)
    t_chk = _timeit(chk, r, k, v, w, u, s0)
    table.add("rwkv6_wkv", "sequential_ref", round(t_ref, 1), 1.0)
    table.add("rwkv6_wkv", "chunked", round(t_chk, 1), round(t_ref / t_chk, 2))
    results["rwkv6"] = t_ref / t_chk

    p, n = 64, 64
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a = jnp.exp(-jnp.exp(jax.random.normal(ks[2], (b, t, h)) - 1) * dt)
    B = jax.random.normal(ks[3], (b, t, n))
    C = jax.random.normal(ks[4], (b, t, n))
    s0 = jnp.zeros((b, h, p, n))
    ref = jax.jit(lambda *a_: ssd_scan_ref(*a_)[0])
    chk = jax.jit(lambda *a_: ssd_scan_chunked(*a_)[0])
    t_ref = _timeit(ref, x, dt, a, B, C, s0)
    t_chk = _timeit(chk, x, dt, a, B, C, s0)
    table.add("ssd", "sequential_ref", round(t_ref, 1), 1.0)
    table.add("ssd", "chunked", round(t_chk, 1), round(t_ref / t_chk, 2))
    results["ssd"] = t_ref / t_chk

    # Attention: q-chunked (flash-style blocking) vs dense materialization.
    from repro.kernels.flash_attention.ref import attention_ref
    import dataclasses
    from repro.configs import get_smoke
    from repro.models.attention import mha

    cfg = dataclasses.replace(get_smoke("olmo_1b"), attn_chunk=256)
    bq, hq, sq, hd = 1, 4, 2048, 64
    q = jax.random.normal(ks[0], (bq, sq, hq, hd), jnp.float32)
    kk = jax.random.normal(ks[1], (bq, sq, hq, hd), jnp.float32)
    vv = jax.random.normal(ks[2], (bq, sq, hq, hd), jnp.float32)
    mask = jnp.tril(jnp.ones((sq, sq), dtype=bool))
    chunked = jax.jit(lambda q_, k_, v_: mha(cfg, q_, k_, v_, mask))
    dense_cfg = dataclasses.replace(cfg, attn_chunk=sq)
    dense = jax.jit(lambda q_, k_, v_: mha(dense_cfg, q_, k_, v_, mask))
    t_dense = _timeit(dense, q, kk, vv)
    t_chunk = _timeit(chunked, q, kk, vv)
    table.add("attention_2k", "dense", round(t_dense, 1), 1.0)
    table.add("attention_2k", "q_chunked", round(t_chunk, 1),
              round(t_dense / t_chunk, 2))
    results["attention"] = t_dense / t_chunk
    table.emit(out)
    print(f"# kernels: chunked-vs-ref speedups rwkv6={results['rwkv6']:.1f}x "
          f"ssd={results['ssd']:.1f}x attn_chunked/dense={results['attention']:.2f}x")
    return results


if __name__ == "__main__":
    main()
