"""Coordinator view over a distributed sweep's shared store.

``sweep_status`` is pure observation — it writes nothing, so it is safe
to run while workers are live.  It classifies every cell of the sweep
(done / quarantined / leased / pending), surfaces expired leases and
stale results (stored under an outdated ``spec_hash``), reports
per-worker liveness from heartbeats, and echoes the store's
coordination counters (claims / reissues / duplicates / ...).

There is deliberately no *active* reaper process: reclaim is passive
(any worker's ``claim`` takes over an expired lease, see
:mod:`repro.scenarios.lease`), so a sweep with dead workers still
converges as long as one worker survives — the coordinator only makes
that degradation visible.
"""

from __future__ import annotations

import time

from repro.scenarios.spec import SweepSpec
from repro.scenarios.store import SweepStore, open_store

#: A worker whose last heartbeat is older than this is reported dead.
DEFAULT_DEAD_AFTER = 60.0


def sweep_status(
    sweep: SweepSpec,
    store: SweepStore | str,
    *,
    now: float | None = None,
    dead_after: float = DEFAULT_DEAD_AFTER,
) -> dict:
    """Snapshot of a sweep's progress against a shared store.

    Keys: ``cells`` (total), ``done``/``quarantined`` (cell id lists),
    ``leased`` ({cid: {worker, expires_in_s}} for live leases),
    ``expired_leases`` (cells whose lease TTL passed without release —
    reclaimable), ``pending`` (claimable now: never leased or lease
    expired), ``stale`` (a result exists for the cell id but under a
    different spec_hash — the spec changed since it was stored),
    ``workers`` ({worker: {last_seen_s, live, info}}), ``stats``
    (store coordination counters), ``converged`` (bool).
    """
    store = open_store(store)
    t = time.time() if now is None else now
    cells = sweep.expand()
    stored = store.load()
    held = store.leases()
    stored_cids = {cid for cid, _ in stored}

    done: list[str] = []
    quarantined: list[str] = []
    leased: dict[str, dict] = {}
    expired_leases: list[str] = []
    pending: list[str] = []
    stale: list[str] = []
    for cid, spec in cells:
        h = spec.spec_hash()
        rec = stored.get((cid, h))
        if rec is not None:
            (quarantined if rec.get("quarantined") else done).append(cid)
            continue
        if cid in stored_cids:
            stale.append(cid)
        lease = held.get((cid, h))
        if lease is not None and not lease.expired(t):
            leased[cid] = {
                "worker": lease.worker,
                "expires_in_s": round(lease.remaining(t), 3),
            }
        elif lease is not None:
            expired_leases.append(cid)
            pending.append(cid)  # expired lease = claimable now
        else:
            pending.append(cid)

    workers = {}
    for w, rec in sorted(store.workers().items()):
        age = t - rec["last_seen"]
        workers[w] = {
            "last_seen_s": round(age, 3),
            "live": age <= dead_after,
            "info": rec["info"],
        }

    return {
        "sweep": sweep.name,
        "cells": len(cells),
        "done": sorted(done),
        "quarantined": sorted(quarantined),
        "leased": leased,
        "expired_leases": sorted(expired_leases),
        "pending": sorted(pending),
        "stale": sorted(stale),
        "workers": workers,
        "stats": store.stats(),
        "converged": len(done) + len(quarantined) == len(cells),
    }


def format_status(status: dict) -> str:
    """Human-readable rendering of a ``sweep_status`` snapshot."""
    lines = [
        f"sweep {status['sweep']}: "
        f"{len(status['done'])}/{status['cells']} done"
        f", {len(status['quarantined'])} quarantined"
        f", {len(status['leased'])} leased"
        f", {len(status['pending'])} pending"
        + (" — converged" if status["converged"] else ""),
    ]
    for cid, lease in sorted(status["leased"].items()):
        lines.append(
            f"  leased  {cid}  -> {lease['worker']} "
            f"(expires in {lease['expires_in_s']}s)"
        )
    for cid in status["expired_leases"]:
        lines.append(f"  expired {cid}  (lease lapsed; reclaimable)")
    for cid in status["quarantined"]:
        lines.append(f"  quarantined {cid}")
    for cid in status["stale"]:
        lines.append(f"  stale   {cid}  (stored under an outdated spec_hash)")
    for w, rec in status["workers"].items():
        state = "live" if rec["live"] else "DEAD"
        lines.append(
            f"  worker  {w}  {state} (last seen {rec['last_seen_s']}s ago, "
            f"info {rec['info']})"
        )
    stats = status["stats"]
    lines.append(
        "  stats   "
        + ", ".join(f"{k}={stats[k]}" for k in sorted(stats))
    )
    return "\n".join(lines)
