"""Scheduler-overhead benchmark: decision latency and event throughput vs
cluster scale.

The paper's practicality claim (Sect. 4) rests on the scheduler's own
decision cost staying negligible as jobs x machines grow.  This bench
drives each scheduler through the trace-scale FB workload
(:func:`repro.workload.fb_scaled_dataset`) over a #jobs x #machines grid
and reports, per cell:

* **decision latency** — mean and p99 wall-clock of one ``schedule()``
  pass (the incremental engine targets O(changed-tasks + actions));
* **events/sec** — simulator events processed per wall-clock second;
* **passes** and **events** actually executed (each cell runs a bounded
  event budget so the big cells stay fast; the workload is oversized
  relative to the budget, so every cell measures the scheduler under
  full queue pressure, not the drain tail).

A second CSV block (``waterfill_micro``) characterizes the virtual-cluster
water-fill kernels themselves — ROADMAP's "numpy loops recomputed on every
structural event" — numpy reference vs the jitted JAX backend
(:mod:`repro.core.vcluster_jax`), per job-grid cell:

* **fill**: one weighted max-min water-fill over the cell's demands;
* **proj**: one PS finish-time projection (the water-fill driven in a
  loop, one round per job completion — HFSP's schedule-order kernel and
  the dominant per-structural-event cost at trace scale);
* **waterfill_speedup**: numpy/jax projection-loop ratio, the headline
  column recorded into BENCH_sched.json by ``benchmarks/run.py --quick``.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_sched_overhead \
      [--schedulers hfsp,fair,fifo] [--jobs 50,500,5000] \
      [--machines 20,200,1000] [--events 20000] [--seed 0]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import SCHEDULERS, CsvOut
from repro.core import Simulator
from repro.core.simulator import EventLimitReached
from repro.core.types import ClusterSpec
from repro.workload import fb_scaled_dataset

JOB_GRID = (50, 500, 5000)
MACHINE_GRID = (20, 200, 1000)


def waterfill_cell(
    n_jobs: int, *, seed: int = 0, reps: int = 5, machines: int = 1000
) -> dict:
    """Water-fill kernel microbenchmark at one job-count cell.

    Demands come from the scaled FB trace (heavy-tailed task counts);
    remaining work is task-count x a plausible per-task time, weights are
    1.0 and slots mirror the grid's 1000-machine MAP capacity — the state
    the virtual cluster actually feeds these kernels at this scale.
    Best-of-``reps`` timings (min is the standard noise-robust estimator
    for microbenches); jit warmup/compile happens before timing.
    """
    from repro.core.vcluster import _project_array, _water_fill

    jobs, _ = fb_scaled_dataset(
        seed=seed, num_jobs=n_jobs, num_machines=machines
    )
    caps = np.array([len(j.map_tasks) for j in jobs], dtype=np.float64)
    rng = np.random.default_rng(seed)
    # The scaled trace can return slightly fewer jobs than requested;
    # size everything off the demands actually produced.
    rem = caps * rng.uniform(5.0, 50.0, len(caps))
    ws = np.ones(len(caps))
    slots = float(4 * machines)  # map_slots_per_machine=4, as in run_cell

    def best(fn) -> float:
        out = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            out.append(time.perf_counter() - t0)
        return min(out) * 1e3

    cell = {
        "jobs": n_jobs,
        "fill_numpy_ms": best(lambda: _water_fill(caps, ws, slots)),
        "proj_numpy_ms": best(
            lambda: _project_array(rem.copy(), caps, ws, slots, 0.0)
        ),
        "fill_jax_ms": None,
        "proj_jax_ms": None,
        "waterfill_speedup": None,
    }
    try:
        from repro.core import vcluster_jax

        if not vcluster_jax.have_jax():
            return cell
    except Exception:
        return cell
    vcluster_jax.water_fill(caps, ws, slots)  # compile
    vcluster_jax.project_finish_times(rem, caps, ws, slots, 0.0)
    cell["fill_jax_ms"] = best(
        lambda: vcluster_jax.water_fill(caps, ws, slots)
    )
    cell["proj_jax_ms"] = best(
        lambda: vcluster_jax.project_finish_times(rem, caps, ws, slots, 0.0)
    )
    cell["waterfill_speedup"] = cell["proj_numpy_ms"] / cell["proj_jax_ms"]
    return cell


def run_waterfill_micro(job_grid=JOB_GRID, *, seed: int = 0) -> list[dict]:
    out = CsvOut(
        "waterfill_micro",
        ["jobs", "fill_numpy_ms", "fill_jax_ms", "proj_numpy_ms",
         "proj_jax_ms", "waterfill_speedup"],
    )
    cells = []
    for nj in job_grid:
        cell = waterfill_cell(nj, seed=seed)
        cells.append(cell)
        fmt = lambda v, nd=3: round(v, nd) if v is not None else ""
        out.add(
            cell["jobs"], fmt(cell["fill_numpy_ms"]),
            fmt(cell["fill_jax_ms"]), fmt(cell["proj_numpy_ms"]),
            fmt(cell["proj_jax_ms"]), fmt(cell["waterfill_speedup"], 2),
        )
        speed = cell["waterfill_speedup"]
        print(
            f"# waterfill jobs={nj}: proj numpy "
            f"{cell['proj_numpy_ms']:.2f}ms vs jax "
            + (f"{cell['proj_jax_ms']:.2f}ms ({speed:.1f}x)"
               if speed is not None else "n/a (jax unavailable)"),
            flush=True,
        )
    out.emit()
    return cells


class _TimedScheduler:
    """Wraps a scheduler, timing every schedule() pass."""

    def __init__(self, inner):
        self._inner = inner
        self.pass_times: list[float] = []

    def schedule(self, view, now):
        t0 = time.perf_counter()
        actions = self._inner.schedule(view, now)
        self.pass_times.append(time.perf_counter() - t0)
        return actions

    def __getattr__(self, name):
        return getattr(self._inner, name)


def run_cell(
    sched_name: str,
    num_jobs: int,
    num_machines: int,
    *,
    seed: int = 0,
    max_events: int = 20_000,
    max_seconds: float = 45.0,
    chunk: int = 250,
) -> dict:
    """One (scheduler, #jobs, #machines) cell.

    Bounded two ways so pathological cells (e.g. 5000 jobs jammed onto 20
    machines) cannot stall the grid: an event budget AND a wall-clock cap.
    The simulator supports incremental continuation, so the cell runs in
    ``chunk``-event slices and stops at whichever bound hits first; the
    row reports the events actually processed (no silent truncation).
    """
    jobs, _ = fb_scaled_dataset(
        seed=seed, num_jobs=num_jobs, num_machines=num_machines
    )
    cluster = ClusterSpec(
        num_machines=num_machines,
        map_slots_per_machine=4,
        reduce_slots_per_machine=2,
    )
    sch = _TimedScheduler(SCHEDULERS[sched_name](cluster))
    sim = Simulator(cluster, sch, jobs)
    t0 = time.perf_counter()
    while (
        sim.events_processed < max_events
        and time.perf_counter() - t0 < max_seconds
    ):
        try:
            sim.run(max_events=min(chunk, max_events - sim.events_processed))
            break  # drained the whole workload inside the budget
        except EventLimitReached:
            continue  # slice exhausted; loop re-checks both bounds
    wall = time.perf_counter() - t0
    events = sim.events_processed
    times = sorted(sch.pass_times)
    n = len(times)
    mean_ms = 1e3 * sum(times) / n if n else 0.0
    p99_ms = 1e3 * times[min(n - 1, int(0.99 * n))] if n else 0.0
    return {
        "passes": n,
        "events": events,
        "sim_t": sim._now,
        "wall_s": wall,
        "mean_pass_ms": mean_ms,
        "p99_pass_ms": p99_ms,
        "events_per_s": events / wall if wall > 0 else 0.0,
        "sched_frac": sum(times) / wall if wall > 0 else 0.0,
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--schedulers", default="fifo,fair,hfsp")
    ap.add_argument("--jobs", default=",".join(map(str, JOB_GRID)))
    ap.add_argument("--machines", default=",".join(map(str, MACHINE_GRID)))
    ap.add_argument("--events", type=int, default=20_000,
                    help="event budget per cell")
    ap.add_argument("--max-cell-seconds", type=float, default=45.0,
                    help="wall-clock cap per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-waterfill", action="store_true",
                    help="skip the water-fill kernel microbenchmark")
    args = ap.parse_args(argv)

    out = CsvOut(
        "sched_overhead",
        ["scheduler", "jobs", "machines", "passes", "events", "sim_t",
         "wall_s", "mean_pass_ms", "p99_pass_ms", "events_per_s",
         "sched_frac"],
    )
    for name in args.schedulers.split(","):
        for nj in (int(x) for x in args.jobs.split(",")):
            for nm in (int(x) for x in args.machines.split(",")):
                cell = run_cell(
                    name, nj, nm, seed=args.seed, max_events=args.events,
                    max_seconds=args.max_cell_seconds,
                )
                out.add(
                    name, nj, nm, cell["passes"], cell["events"],
                    round(cell["sim_t"], 1),
                    round(cell["wall_s"], 3),
                    round(cell["mean_pass_ms"], 4),
                    round(cell["p99_pass_ms"], 4),
                    round(cell["events_per_s"], 1),
                    round(cell["sched_frac"], 3),
                )
                print(
                    f"# {name} jobs={nj} machines={nm}: "
                    f"{cell['wall_s']:.2f}s wall, "
                    f"{cell['mean_pass_ms']:.3f}ms/pass (p99 "
                    f"{cell['p99_pass_ms']:.3f}), "
                    f"{cell['events_per_s']:.0f} events/s",
                    flush=True,
                )
    out.emit()
    if not args.no_waterfill:
        run_waterfill_micro(
            tuple(int(x) for x in args.jobs.split(",")), seed=args.seed
        )


if __name__ == "__main__":
    main()
