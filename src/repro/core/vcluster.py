"""The virtual cluster (Sect. 3.1).

HFSP ranks jobs by the time at which they *would* finish if the cluster were
running a max-min-fair processor-sharing (PS) discipline.  The virtual
cluster simulates exactly that: it mirrors the real cluster's slot counts,
allocates virtual slots to jobs with max-min fairness (round-robin, starting
from the smallest jobs), and *ages* jobs between scheduler events by
subtracting `dt x allocated_slots` from their serialized remaining work.

Job size is serialized (sum of task runtimes on one slot), so aging is
independent of the real cluster's state — the paper's trick for tolerating
failures and elastic width (DESIGN.md §2, §7).

One VirtualCluster instance exists per phase (MAP and REDUCE are scheduled
independently, Sect. 3.1).

Performance notes (the scheduler runs on every executor event):

* the discrete max-min allocation depends only on (caps, weights, slots) —
  NOT on remaining work — so it is recomputed lazily, only after
  membership/cap changes;
* the projected-finish ORDER is invariant under aging (in continuous PS all
  jobs age exactly at their allocated rate, so absolute projected finish
  times are constant between structural events); the order is therefore
  cached and recomputed only on job add/remove and size re-estimates.
  Cap changes (task completions) can only *accelerate* the affected job's
  PS finish; we accept the momentarily stale order until the next
  structural event, which in practice arrives within one heartbeat;
* **aging is lazy**: ``age(dt)`` appends ``dt`` to a pending queue in O(1)
  and per-job ``remaining``/``done`` are materialized only when a query or
  a structural change (add/remove/re-estimate) needs them.  On the steady-
  state event path — where the schedule-order cache is hot and no
  estimates change — an event therefore costs O(1) instead of O(jobs).
  Materialization *replays* the deferred increments one event-dt at a
  time under the allocation in force at that point (re-checking effective
  caps after every step, exactly like the old eager loop), so the
  resulting floating-point state is bit-identical to eager aging.

Numeric backends: the water-fill and finish-time-projection kernels exist
in two interchangeable implementations — the numpy reference in this
module and a jitted, padded-fixed-shape JAX version in
:mod:`repro.core.vcluster_jax` (selected per instance via
``VirtualCluster(backend="numpy"|"jax")`` or globally via the
``REPRO_VC_BACKEND`` environment variable; the conformance suite in
``tests/test_conformance.py`` pins their behavioral equivalence).  See
docs/vcluster.md for the math and the jit/recompile contract.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field

import numpy as np

from repro.core.types import Phase

#: Recognized numeric kernel backends for water-fill / projection.
#: "numpy" is the scalar reference; "jax" is the jitted fixed-shape
#: implementation in :mod:`repro.core.vcluster_jax` (see docs/vcluster.md).
BACKENDS = ("numpy", "jax")

#: Selectable backend choices: the kernel backends plus "auto", which
#: starts on numpy and latches to jax once the live-job count crosses
#: :data:`AUTO_JAX_THRESHOLD` (the jitted kernels win only at scale —
#: below it, dispatch overhead dominates; see bench_sched_overhead's
#: waterfill_micro).  The switch is behavior-neutral: the backends are
#: conformance-tested bit-identical (tests/test_conformance.py).
BACKEND_CHOICES = BACKENDS + ("auto",)

#: Environment override for the default backend (documented in ROADMAP.md).
BACKEND_ENV = "REPRO_VC_BACKEND"

#: Live jobs (per phase) above which an "auto" cluster switches its
#: kernels to jax.  ~500 is where the jitted projection pulls >5x ahead
#: of the numpy loop on the scheduler-overhead grid (ROADMAP, PR 2).
AUTO_JAX_THRESHOLD = 500


def resolve_backend(backend: str | None = None) -> str:
    """Pick the backend: explicit arg > $REPRO_VC_BACKEND > auto.

    Returns one of :data:`BACKEND_CHOICES`.  "jax" raises if jax is not
    importable (an explicit request must not silently degrade); "auto"
    never raises — without jax it simply stays on numpy.
    """
    b = backend or os.environ.get(BACKEND_ENV) or "auto"
    if b not in BACKEND_CHOICES:
        raise ValueError(
            f"unknown vcluster backend {b!r}; expected one of {BACKEND_CHOICES}"
        )
    if b == "jax":
        from repro.core import vcluster_jax

        if not vcluster_jax.have_jax():
            raise RuntimeError(
                "vcluster backend 'jax' requested but jax is not importable; "
                f"install jax or set {BACKEND_ENV}=numpy"
            )
    return b


@dataclass
class _VJob:
    job_id: int
    remaining: float          # serialized seconds (estimated)
    cap: int                  # parallelism at arrival = task count
    weight: float = 1.0       # GPS weight (Sect. 5)
    size_rank: int = 0        # number of tasks at arrival; round-robin order
    done: float = 0.0         # virtual work already aged away (for estimate updates)
    task_time: float = 1.0    # estimated serialized seconds per task
    # Owning cluster (lazy aging): public queries materialize deferred
    # aging first so external readers never observe stale state.
    owner: "VirtualCluster | None" = field(default=None, repr=False, compare=False)

    def effective_cap(self) -> int:
        """Virtual parallelism: the number of *virtual* tasks still
        unfinished.  The virtual cluster is a pure PS simulation — its
        parallelism shrinks as virtual work depletes (the job's "tail"),
        NOT as real tasks complete.  Coupling it to real completions makes
        a focused job's projected PS finish time rise while it runs, which
        flips the schedule order and causes preemption thrash."""
        if self.owner is not None:
            self.owner._materialize()
        return self._ecap()

    def _ecap(self) -> int:
        """`effective_cap` without the lazy-aging flush (internal use,
        after the owner has already materialized)."""
        if math.isinf(self.remaining):
            return self.cap
        if self.task_time <= 0:
            return self.cap
        return max(1, min(self.cap, int(math.ceil(self.remaining / self.task_time - 1e-9))))


def max_min_allocation(
    demands: dict[int, tuple[float, float]], slots: float
) -> dict[int, float]:
    """Weighted max-min fair (water-filling) allocation.

    ``demands`` maps job_id -> (cap, weight).  Returns continuous slot
    shares summing to at most ``slots`` (less if total cap is smaller).
    """
    ids = list(demands)
    caps = np.array([demands[j][0] for j in ids], dtype=np.float64)
    ws = np.array([demands[j][1] for j in ids], dtype=np.float64)
    alloc = _water_fill(caps, ws, float(slots))
    return {j: float(a) for j, a in zip(ids, alloc)}


def _water_fill(caps: np.ndarray, ws: np.ndarray, slots: float) -> np.ndarray:
    """Vectorized weighted water-filling: fill proportionally to weight,
    clamp at cap, redistribute, repeat.  O(#cap-levels) rounds."""
    n = len(caps)
    alloc = np.zeros(n)
    active = caps > 0
    free = float(slots)
    while free > 1e-12 and active.any():
        total_w = ws[active].sum()
        if total_w <= 0:
            break
        share = np.zeros(n)
        share[active] = free * ws[active] / total_w
        headroom = caps - alloc
        capped = active & (share >= headroom - 1e-12)
        if not capped.any():
            alloc[active] += share[active]
            break
        grant = np.where(capped, headroom, 0.0)
        alloc += grant
        free -= float(grant.sum())
        active &= ~capped
    return alloc


def discrete_allocation(
    demands: dict[int, tuple[float, float]],
    slots: int,
    size_rank: dict[int, int],
    backend: str = "numpy",
) -> dict[int, int]:
    """Integer max-min allocation via round-robin, small jobs first.

    "Max-min fairness is achieved using a round-robin mechanism that starts
    allocating virtual cluster resources to small jobs (in terms of their
    number of tasks)." (Sect. 3.1)

    Implemented as floor(water-fill) + leftover slots granted in cyclic
    small-job-first rounds among jobs with headroom.  The leftover pass is
    vectorized: whole rounds are granted with one clipped-minimum per
    round-batch, and the final partial round goes one slot each to the
    first eligible jobs in order — exactly the one-slot-at-a-time
    round-robin outcome, without the per-slot Python loop.
    """
    ids = sorted(demands, key=lambda j: (size_rank.get(j, 0), j))
    caps = np.array([demands[j][0] for j in ids], dtype=np.float64)
    ws = np.array([demands[j][1] for j in ids], dtype=np.float64)
    if backend == "jax":
        from repro.core import vcluster_jax

        cont = vcluster_jax.water_fill(caps, ws, float(slots))
    else:
        cont = _water_fill(caps, ws, float(slots))
    base = np.minimum(np.floor(cont + 1e-9), caps).astype(np.int64)
    free = int(slots) - int(base.sum())
    headroom = (caps - base).astype(np.int64)
    while free > 0:
        elig = np.flatnonzero(headroom > 0)
        if elig.size == 0:
            break
        if free >= elig.size:
            # Grant as many whole rounds as currently fit; jobs capping
            # out release their share to the next while-iteration.
            cycles = free // elig.size
            grant = np.minimum(headroom[elig], cycles)
            base[elig] += grant
            headroom[elig] -= grant
            free -= int(grant.sum())
        else:
            # Final partial round: first `free` eligible jobs in
            # small-first order get one slot each.
            take = elig[:free]
            base[take] += 1
            headroom[take] -= 1
            free = 0
    return {j: int(b) for j, b in zip(ids, base)}


def project_finish_times(
    jobs: dict[int, tuple[float, float, float]], slots: float, now: float
) -> dict[int, float]:
    """Forward-simulate weighted max-min PS; return absolute finish times.

    ``jobs`` maps job_id -> (remaining_serialized, cap, weight).  Piecewise
    constant allocations: at each step the job with the minimal
    remaining/allocation finishes, its slots are redistributed, repeat.
    Jobs with infinite remaining (xi = inf initial estimates, Sect. 3.1.1)
    get finish time +inf and therefore sort last.
    """
    ids = list(jobs)
    rem = np.array([jobs[j][0] for j in ids], dtype=np.float64)
    caps = np.array([jobs[j][1] for j in ids], dtype=np.float64)
    ws = np.array([jobs[j][2] for j in ids], dtype=np.float64)
    fin = _project_array(rem, caps, ws, slots, now)
    return {j: float(f) for j, f in zip(ids, fin)}


def _project_array(
    rem: np.ndarray, caps: np.ndarray, ws: np.ndarray, slots: float, now: float
) -> np.ndarray:
    """Array-shaped core of :func:`project_finish_times` (shared with the
    numpy path of :meth:`VirtualCluster.projected_finish_batch`)."""
    rem = rem.copy()
    fin = np.full(len(rem), np.inf)
    live = (rem > 0) & (caps > 0)
    fin[~live] = now
    t = now
    while live.any():
        alloc = np.zeros(len(rem))
        alloc[live] = _water_fill(caps[live], ws[live], float(slots))
        with np.errstate(divide="ignore", invalid="ignore"):
            dt = np.where(live & (alloc > 0), rem / np.maximum(alloc, 1e-300), np.inf)
        dt_min = dt.min()
        if not np.isfinite(dt_min):
            break  # only infinite-size jobs left -> they never finish in PS
        t += float(dt_min)
        rem = np.where(live, np.maximum(rem - alloc * dt_min, 0.0), rem)
        done = live & (dt <= dt_min + 1e-12)
        fin[done] = t
        live &= ~done
    return fin


class VirtualCluster:
    """Mirror of the real cluster for one phase (Sect. 3.1)."""

    def __init__(
        self,
        phase: Phase,
        slots: int,
        backend: str | None = None,
        auto_threshold: int = AUTO_JAX_THRESHOLD,
    ):
        self.phase = phase
        self.slots = slots
        choice = resolve_backend(backend)
        #: Numeric backend for water-fill/projection kernels ("numpy" or
        #: "jax").  With choice "auto" this starts as "numpy" and latches
        #: to "jax" the first time the live-job count reaches
        #: ``auto_threshold`` (see _maybe_auto_upgrade) — latched, not
        #: hysteretic, so one crossing cannot thrash jit recompiles.
        if choice == "auto":
            self.backend = "numpy"
            # Whether jax is importable is probed lazily, at the first
            # threshold crossing — small clusters that never reach it
            # must not pay the (multi-second, per-process) jax import.
            self._auto_jax = True
        else:
            self.backend = choice
            self._auto_jax = False
        self.auto_threshold = auto_threshold
        self._jobs: dict[int, _VJob] = {}
        self._alloc_cache: dict[int, int] | None = None
        # Allocated (vjob, slots) pairs with slots > 0 — the only jobs
        # aging touches; rebuilt together with the allocation.
        self._allocated_cache: list[tuple[_VJob, int]] | None = None
        self._order_cache: list[int] | None = None
        # {job_id: position in _order_cache}, derived lazily from the
        # order cache (same invalidation) — the demand-indexed scheduler
        # sorts only actionable jobs by position instead of walking the
        # whole order list every pass.
        self._pos_cache: dict[int, int] | None = None
        # Lazy aging: deferred per-event dt increments, replayed in order
        # by _materialize() (see module docstring).
        self._pending_dts: list[float] = []
        # -- virtually-done tracking (PSBS late-job aging) ------------------
        # Jobs whose virtual remaining hit 0 while still members ("late"
        # jobs: virtually finished, really unfinished).  Maintained by the
        # aging replay and the size setters; read by
        # ``virtually_done()``, which gates materialization on a
        # conservative horizon (min remaining/cap over live jobs = the
        # earliest any job could virtually finish) so steady-state reads
        # are O(1).  ``_pending_total`` mirrors sum(_pending_dts) in O(1).
        self._vdone: set[int] = set()
        self._pending_total = 0.0
        self._vdone_horizon: float | None = None

    @property
    def jobs(self) -> dict[int, _VJob]:
        """Live job table.  Materializes deferred aging so callers always
        see up-to-date ``remaining``/``done``."""
        self._materialize()
        return self._jobs

    # -- cache control --------------------------------------------------------
    def _invalidate_alloc(self) -> None:
        self._alloc_cache = None
        self._allocated_cache = None

    def _invalidate_order(self) -> None:
        self._order_cache = None
        self._pos_cache = None

    def set_slots(self, n: int) -> None:
        """Resize the virtual capacity (fault layer: machines leaving or
        rejoining the cluster).  Pending lazy aging is replayed first —
        it accrued under the old capacity."""
        if n == self.slots:
            return
        self._materialize()
        self.slots = n
        self._invalidate_alloc()
        self._invalidate_order()

    # -- membership ---------------------------------------------------------
    def add_job(
        self,
        job_id: int,
        est_size: float,
        num_tasks: int,
        weight: float = 1.0,
    ) -> None:
        self._materialize()  # pending aging belongs to the old membership
        tt = est_size / num_tasks if (num_tasks and math.isfinite(est_size)) else 1.0
        self._jobs[job_id] = _VJob(
            job_id=job_id,
            remaining=est_size,
            cap=num_tasks,
            weight=weight,
            size_rank=num_tasks,
            task_time=max(tt, 1e-9),
            owner=self,
        )
        self._sync_vdone(job_id)
        self._maybe_auto_upgrade()
        self._invalidate_alloc()
        self._invalidate_order()

    def _maybe_auto_upgrade(self) -> None:
        """auto mode: latch numpy -> jax once live jobs reach the
        threshold.  Membership growth is the only path that can cross it,
        so this is checked on add_job only.  Behavior-neutral by the
        backend conformance contract (bit-identical kernels).  Without
        jax the first crossing disarms auto mode and the cluster stays
        on numpy (auto never raises — only an explicit "jax" request
        does)."""
        if self._auto_jax and len(self._jobs) >= self.auto_threshold:
            self._auto_jax = False
            from repro.core import vcluster_jax

            if vcluster_jax.have_jax():
                self.backend = "jax"

    def remove_job(self, job_id: int) -> None:
        self._materialize()
        if self._jobs.pop(job_id, None) is not None:
            self._vdone.discard(job_id)
            self._invalidate_alloc()
            self._invalidate_order()

    def __contains__(self, job_id: int) -> bool:
        return job_id in self._jobs

    # -- estimate updates (Training module, Sect. 3.2) ----------------------
    def set_remaining(self, job_id: int, remaining: float) -> None:
        if job_id in self._jobs:
            self._materialize()
            self._jobs[job_id].remaining = remaining
            self._sync_vdone(job_id)
            # The virtual parallelism (_ecap) is derived from `remaining`,
            # so a stale discrete allocation must not survive this update:
            # a lazily-timed rebuild would otherwise make the *timing* of
            # cache rebuilds observable in later aging (non-determinism
            # caught by test_schedule_order_deterministic_under_lazy_aging).
            self._invalidate_alloc()
            self._invalidate_order()

    def set_size(self, job_id: int, size: float) -> None:
        """Re-estimate total size: 'the job scheduler *updates* the remaining
        amount of work to be done for the job' (Sect. 3.1.1) — the virtual
        work already done is preserved."""
        if job_id in self._jobs:
            self._materialize()  # bring `done` up to date first
            v = self._jobs[job_id]
            v.remaining = max(0.0, size - v.done)
            self._sync_vdone(job_id)
            if v.cap and math.isfinite(size):
                v.task_time = max(size / v.cap, 1e-9)
            self._invalidate_alloc()
            self._invalidate_order()

    def set_cap(self, job_id: int, cap: int) -> None:
        if job_id in self._jobs and self._jobs[job_id].cap != cap:
            self._materialize()
            self._jobs[job_id].cap = cap
            self._invalidate_alloc()
            # Order kept: a cap drop only accelerates this job's PS finish
            # (see module docstring); next structural event refreshes it.

    def remaining(self, job_id: int) -> float:
        self._materialize()
        return self._jobs[job_id].remaining if job_id in self._jobs else 0.0

    def _sync_vdone(self, job_id: int) -> None:
        v = self._jobs[job_id]
        if not math.isinf(v.remaining) and v.remaining <= 0.0:
            self._vdone.add(job_id)
        else:
            self._vdone.discard(job_id)
        self._vdone_horizon = None

    def virtually_done(self) -> list[int]:
        """Job ids whose *virtual* remaining work is exhausted while they
        are still members (real tasks unfinished) — PSBS's "late" jobs
        (:class:`repro.core.disciplines.PSBSLateAging`).

        Horizon-gated: queued lazy aging is only replayed when its
        cumulative dt could actually have finished a job (``min
        remaining/cap`` over live jobs — cap bounds any job's virtual
        service rate, so this is a conservative earliest-completion
        bound).  Steady-state calls with an unreachable horizon are O(1)
        and leave the lazy-aging queue untouched."""
        if self._pending_dts and (
            self._vdone_horizon is None
            or self._pending_total >= self._vdone_horizon - 1e-9
        ):
            self._materialize()
        if self._vdone_horizon is None:
            h = math.inf
            for v in self._jobs.values():
                if (
                    not math.isinf(v.remaining)
                    and v.remaining > 0.0
                    and v.cap > 0
                ):
                    d = v.remaining / v.cap
                    if d < h:
                        h = d
            self._vdone_horizon = h
        return sorted(self._vdone)

    # -- aging (Sect. 3.1, "Job aging") --------------------------------------
    def age(self, dt: float) -> None:
        """Distribute ``dt`` of progress to every allocated virtual task.

        O(1): the increment is queued and replayed by the next query or
        structural change."""
        if dt <= 0 or not self._jobs:
            return
        self._pending_dts.append(dt)
        self._pending_total += dt

    def _materialize(self) -> None:
        """Replay deferred aging increments, one event-dt at a time.

        Each step uses the allocation in force at that step and re-checks
        effective caps afterwards (a shrinking virtual tail redistributes
        slots), reproducing eager per-event aging bit for bit."""
        if not self._pending_dts:
            return
        pending, self._pending_dts = self._pending_dts, []
        self._pending_total = 0.0
        for dt in pending:
            self._age_step(dt)
        # Remaining work shrank: the virtual-completion horizon is stale
        # (recomputed lazily by the next virtually_done() query).
        self._vdone_horizon = None

    def _age_step(self, dt: float) -> None:
        cap_changed = False
        for vjob, a in self._allocated():
            before = vjob._ecap()
            vjob.done += a * dt
            if not math.isinf(vjob.remaining):
                vjob.remaining = max(0.0, vjob.remaining - a * dt)
                if vjob.remaining <= 0.0:
                    self._vdone.add(vjob.job_id)
            if vjob._ecap() != before:
                cap_changed = True
        if cap_changed:
            # A virtual tail shrank below its allocation: redistribute.
            self._invalidate_alloc()
        # Aging preserves the projected finish ORDER (continuous-PS
        # invariance): the order cache stays valid.

    # -- queries --------------------------------------------------------------
    def _allocated(self) -> list[tuple[_VJob, int]]:
        """(vjob, allocated-slots) pairs with a positive allocation —
        assumes deferred aging is already materialized (or mid-replay)."""
        if self._alloc_cache is None:
            demands = {
                j: (v._ecap(), v.weight) for j, v in self._jobs.items()
            }
            rank = {j: v.size_rank for j, v in self._jobs.items()}
            self._alloc_cache = discrete_allocation(
                demands, self.slots, rank, backend=self.backend
            )
            self._allocated_cache = [
                (self._jobs[j], a)
                for j, a in self._alloc_cache.items()
                if a > 0
            ]
        return self._allocated_cache

    def allocation(self) -> dict[int, int]:
        self._materialize()
        self._allocated()
        return self._alloc_cache

    def _state_arrays(self) -> tuple[list[int], np.ndarray, np.ndarray, np.ndarray]:
        """(ids, remaining, effective caps, weights) of the live jobs —
        assumes deferred aging is already materialized."""
        ids = list(self._jobs)
        rem = np.array([self._jobs[j].remaining for j in ids], dtype=np.float64)
        caps = np.array(
            [float(self._jobs[j]._ecap()) for j in ids], dtype=np.float64
        )
        ws = np.array([self._jobs[j].weight for j in ids], dtype=np.float64)
        return ids, rem, caps, ws

    def projected_finish(self, now: float) -> dict[int, float]:
        """Absolute PS finish time per job — HFSP's sort key (Sect. 3.1)."""
        self._materialize()
        if self.backend == "jax":
            from repro.core import vcluster_jax

            ids, rem, caps, ws = self._state_arrays()
            fin = vcluster_jax.project_finish_times(
                rem, caps, ws, float(self.slots), float(now)
            )
            return {j: float(f) for j, f in zip(ids, fin)}
        return project_finish_times(
            {
                j: (v.remaining, v._ecap(), v.weight)
                for j, v in self._jobs.items()
            },
            self.slots,
            now,
        )

    def projected_finish_batch(
        self,
        scenarios: list[dict[int, float]],
        now: float,
        as_sizes: bool = False,
    ) -> list[dict[int, float]]:
        """What-if PS finish times for many hypothetical job sizes at once.

        Each scenario maps job_id -> a hypothetical override for that job
        (jobs not named keep their current state).  With the default
        ``as_sizes=False`` the override is the *remaining* serialized
        work, priced exactly as if ``set_remaining`` had been applied
        (virtual parallelism re-derived from the job's current task_time).
        With ``as_sizes=True`` the override is a hypothetical *total*
        phase size, priced exactly as if ``set_size`` had been applied:
        remaining becomes ``max(0, size - done)`` and the per-task time —
        hence the virtual tail — is re-derived from the new size.  On the
        jax backend all scenarios price in a single vmapped dispatch; the
        numpy backend loops, so both backends return identical values and
        this method is safe to use from policy code regardless of
        configuration.
        """
        self._materialize()
        ids, rem, caps, ws = self._state_arrays()
        if not scenarios:
            return []
        if not ids:
            return [{} for _ in scenarios]
        idx = {j: i for i, j in enumerate(ids)}
        b = len(scenarios)
        rem_b = np.tile(rem, (b, 1))
        caps_b = np.tile(caps, (b, 1))
        for s, overrides in enumerate(scenarios):
            for j, val in overrides.items():
                i = idx.get(j)
                if i is None:
                    continue
                v = self._jobs[j]
                if as_sizes:
                    r = max(0.0, val - v.done)
                    tt = (
                        max(val / v.cap, 1e-9)
                        if v.cap and math.isfinite(val)
                        else v.task_time
                    )
                else:
                    r = val
                    tt = v.task_time
                rem_b[s, i] = r
                caps_b[s, i] = float(self._whatif_ecap(v, r, tt))
        if self.backend == "jax":
            from repro.core import vcluster_jax

            fin_b = vcluster_jax.project_finish_times_batch(
                rem_b, caps_b, np.tile(ws, (b, 1)), float(self.slots), float(now)
            )
        else:
            fin_b = np.stack(
                [
                    _project_array(rem_b[s], caps_b[s], ws, self.slots, now)
                    for s in range(b)
                ]
            )
        return [
            {j: float(f) for j, f in zip(ids, row)} for row in fin_b
        ]

    @staticmethod
    def _whatif_ecap(v: _VJob, remaining: float, task_time: float) -> int:
        """Effective cap a job WOULD have at a hypothetical remaining
        (and, for size-override scenarios, a re-derived task_time)."""
        if math.isinf(remaining) or task_time <= 0:
            return v.cap
        return max(
            1, min(v.cap, int(math.ceil(remaining / task_time - 1e-9)))
        )

    def _order_from_fin(self, fin: dict[int, float]) -> list[int]:
        return sorted(fin, key=lambda j: (fin[j], self._jobs[j].size_rank, j))

    def order_cache_cold(self) -> bool:
        """True when the next schedule_order() must run a projection."""
        return self._order_cache is None and bool(self._jobs)

    def warm_order_cache(self, fin: dict[int, float]) -> None:
        """Install a schedule order from an externally computed projection
        (the scheduler's batched cross-phase warm).  ``fin`` must be this
        cluster's own projected finish map at the current virtual time."""
        self._order_cache = self._order_from_fin(fin)
        self._pos_cache = None

    def schedule_order(self, now: float) -> list[int]:
        """Job ids sorted by projected finish time, ties by id (FIFO-ish).

        Served from cache without materializing deferred aging: aging
        preserves the projected-finish order, so a valid cache stays
        correct no matter how much un-replayed aging is queued."""
        if self._order_cache is None:
            self._order_cache = self._order_from_fin(self.projected_finish(now))
            self._pos_cache = None
        return self._order_cache

    def schedule_pos(self, now: float) -> dict[int, int]:
        """{job_id: position in schedule_order(now)} — cached together
        with the order, so steady-state passes pay O(1) for position
        lookups instead of rebuilding the map per pass."""
        if self._pos_cache is None:
            self._pos_cache = {
                j: i for i, j in enumerate(self.schedule_order(now))
            }
        return self._pos_cache
