"""Fig. 6 — robustness to job-size estimation errors.

A "wrong" estimate is drawn uniformly in [s*(1-a), s*(1+a)] for
a in [0.1, 1.0]; the paper uses a MAP-only variant of the FB-dataset and
finds mean sojourn nearly flat in a (HFSP is robust), with FAIR as the
error-independent reference."""

from __future__ import annotations

import numpy as np

from benchmarks.common import CsvOut, run_fb
from repro.workload import WorkloadSpec


def _map_only_spec():
    return WorkloadSpec()


def main(out=None, seeds: int = 5) -> dict:
    import dataclasses

    from repro.workload import fb_dataset

    # MAP-only FB variant (paper Sect. 4.3): strip reduce tasks.
    alphas = [0.0, 0.1, 0.25, 0.5, 0.75, 1.0]
    table = CsvOut("fig6_estimation_error", [
        "alpha", "mean_sojourn_s", "std_over_seeds",
    ])

    def run_alpha(alpha: float) -> list[float]:
        from repro.core import HFSPConfig, HFSPScheduler, Simulator
        from repro.workload import fb_cluster

        means = []
        for seed in range(seeds):
            cluster = fb_cluster(100)
            jobs, _ = fb_dataset(seed=0)
            jobs = [
                dataclasses.replace(j, reduce_tasks=()) for j in jobs
            ]
            sch = HFSPScheduler(
                cluster, HFSPConfig(error_alpha=alpha, error_seed=seed)
            )
            res = Simulator(cluster, sch, jobs).run()
            means.append(res.mean_sojourn())
        return means

    results = {}
    for a in alphas:
        ms = run_alpha(a)
        results[a] = float(np.mean(ms))
        table.add(a, round(float(np.mean(ms)), 1), round(float(np.std(ms)), 1))

    # FAIR reference (error-independent).
    from repro.core import FairScheduler, Simulator
    from repro.workload import fb_cluster, fb_dataset as fbd

    cluster = fb_cluster(100)
    jobs, _ = fbd(seed=0)
    jobs = [dataclasses.replace(j, reduce_tasks=()) for j in jobs]
    fair = Simulator(cluster, FairScheduler(cluster), jobs).run().mean_sojourn()
    table.add("fair-ref", round(fair, 1), 0.0)
    table.emit(out)

    degradation = results[1.0] / results[0.0]
    print(f"# fig6: mean sojourn at alpha=0: {results[0.0]:.0f}s, at "
          f"alpha=1: {results[1.0]:.0f}s ({degradation:.2f}x) — "
          f"FAIR ref {fair:.0f}s; HFSP stays below FAIR for all alpha: "
          f"{all(results[a] < fair for a in alphas)}")
    return {"results": results, "fair": fair}


if __name__ == "__main__":
    main()
