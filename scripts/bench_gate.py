#!/usr/bin/env python
"""Scheduler-overhead trajectory gate (ROADMAP: "wire BENCH_sched.json
into a history file across PRs so perf regressions are caught
automatically").

Reads the record ``benchmarks/run.py --quick --json`` just wrote, appends
it (timestamped, with its verdict) to a JSONL history file, and fails
when the hfsp wall-clock regressed more than ``--threshold`` (default
25%) versus the baseline.  The baseline is the most recent entry that
did NOT itself fail the gate — a regressed run is recorded for the
trajectory but never becomes the baseline, so re-running the gate after
a failure cannot silently ratchet the regression in.

Usage (scripts/check.sh runs this after the quick bench):
  python scripts/bench_gate.py [--json BENCH_sched.json] \
      [--history BENCH_history.jsonl] [--threshold 0.25] [--key hfsp]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def gate(
    json_path: str = "BENCH_sched.json",
    history_path: str = "BENCH_history.jsonl",
    threshold: float = 0.25,
    key: str = "hfsp",
) -> int:
    record = dict(json.loads(Path(json_path).read_text()))
    history = Path(history_path)
    # Baseline = newest entry that did not itself fail the gate (entries
    # from before the gate field existed count as passing).
    baseline = None
    if history.exists():
        for ln in reversed(history.read_text().splitlines()):
            if not ln.strip():
                continue
            entry = json.loads(ln)
            if entry.get("gate", "ok") == "ok":
                baseline = entry
                break

    new_wall = record["schedulers"][key]["wall_s"]
    record["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    if baseline is None:
        record["gate"] = "ok"
        with history.open("a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        print(f"bench_gate: first history entry ({key} {new_wall:.3f}s); "
              f"nothing to compare")
        return 0
    old_wall = baseline["schedulers"][key]["wall_s"]
    limit = old_wall * (1.0 + threshold)
    verdict = "OK" if new_wall <= limit else "REGRESSION"
    record["gate"] = verdict.lower()
    with history.open("a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    print(
        f"bench_gate: {key} wall {old_wall:.3f}s -> {new_wall:.3f}s "
        f"(limit {limit:.3f}s, +{threshold:.0%}): {verdict}"
    )
    if verdict != "OK":
        print(
            f"bench_gate: {key} wall-clock regressed "
            f"{new_wall / old_wall - 1.0:+.1%} vs the previous entry in "
            f"{history_path}; investigate before merging (or delete the "
            f"stale entry if the machine changed)."
        )
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_sched.json")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--key", default="hfsp")
    args = ap.parse_args()
    sys.exit(gate(args.json, args.history, args.threshold, args.key))


if __name__ == "__main__":
    main()
