import os

# Smoke tests and benchmarks must see the REAL device count (the dry-run
# alone forces 512 host devices, in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Deterministic jax/XLA numerics for the vcluster backend-conformance
# suite: a fixed single-threaded CPU reduction order makes kernel outputs
# reproducible across CI machines and laptops (threaded reductions may
# reassociate float sums).  setdefault only — an externally configured
# XLA_FLAGS (e.g. the dry-run's forced device count) wins.
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1",
)

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    import jax

    return jax.random.PRNGKey(0)
