"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — the "pod" axis is
pure data parallelism across the inter-pod (DCN/optical) links; "model"
stays inside a pod where ICI bandwidth lives.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
