"""Fig. 4 — per-job sojourn difference, FAIR minus HFSP.

Paper claim: at most ~1 of 100 jobs is (slightly) better off under FAIR —
the experimental support for the FSP dominance conjecture."""

from __future__ import annotations

import numpy as np

from benchmarks.common import CsvOut, run_fb
from repro.core.metrics import per_job_delta


def main(out=None) -> dict:
    res_fair, class_of, _, _ = run_fb("fair", seed=0)
    res_hfsp, _, _, _ = run_fb("hfsp", seed=0)
    delta = per_job_delta(res_fair, res_hfsp)  # fair - hfsp (>0: hfsp wins)
    vals = np.asarray(sorted(delta.values()))
    worse = [(j, d) for j, d in delta.items() if d < -1.0]

    table = CsvOut("fig4_delta", ["stat", "value"])
    table.add("jobs", len(delta))
    table.add("hfsp_better_or_equal", int((vals >= -1.0).sum()))
    table.add("hfsp_worse_by_1s_plus", len(worse))
    table.add("max_gain_s", round(float(vals.max()), 1))
    table.add("max_loss_s", round(float(-vals.min()), 1))
    table.add("median_delta_s", round(float(np.median(vals)), 1))
    table.emit(out)
    print(f"# fig4: {int((vals >= -1.0).sum())}/{len(delta)} jobs no worse "
          f"under HFSP (dominance conjecture); worst regression "
          f"{-float(vals.min()):.0f}s, best gain {float(vals.max()):.0f}s")
    return {"frac_no_worse": float((vals >= -1.0).mean())}


if __name__ == "__main__":
    main()
