"""Epsilon-window event coalescing: determinism and conformance.

The simulator's ``event_epsilon`` knob batches near-timestamp events into
one scheduling pass (arXiv 1306.6023's design).  The determinism contract
(docs/scheduler_internals.md):

* ``eps=0`` is bit-identical to the legacy pass-per-event loop — same
  completions, stats, AND pass counts — for every scheduler and
  virtual-cluster backend (this is why eps=0 stays the default);
* any ``eps>0`` run is a pure function of the event stream: repeated
  in-process runs and fresh-process runs produce identical schedules
  (template: the lazy-aging determinism suite in test_vcluster_jax.py);
* coalescing cuts pass counts on bursty traces (the overhead win the
  epsilon sweep in bench_sched_overhead quantifies).
"""

import json
import os
import subprocess
import sys

import pytest

from conformance import (
    GOLDEN_SEEDS,
    TRACE_SCHEDULERS,
    assert_traces_equal,
    run_trace,
)

def _backend_params():
    """Virtual-cluster backends crossed with the eps=0 conformance rows:
    numpy (reference), jax (jitted kernels), auto (mid-trace latch) —
    the jax-dependent ones skip when jax is unavailable."""
    out = ["numpy"]
    try:
        import jax  # noqa: F401

        out.extend(["jax", "auto"])
    except Exception:
        out.extend(
            pytest.param(b, marks=pytest.mark.skip(reason="no jax"))
            for b in ("jax", "auto")
        )
    return out


@pytest.mark.parametrize("seed", GOLDEN_SEEDS)
@pytest.mark.parametrize("name", ("fifo", "fair", "hfsp"))
def test_eps_zero_bit_identical_to_seed(name, seed):
    """An explicit eps=0 run must equal the default run bit for bit,
    including the pass count (the conformance floor for the new loop)."""
    ref = run_trace(name, seed)
    eps0 = run_trace(name, seed, event_epsilon=0.0)
    assert_traces_equal(ref, eps0)


@pytest.mark.parametrize("backend", _backend_params())
@pytest.mark.parametrize("name", ("hfsp", "hfsp-kill"))
def test_eps_zero_bit_identical_across_backends(name, backend):
    """eps=0 conformance holds on every virtual-cluster backend."""
    ref = run_trace(name, 0, vc_backend=backend)
    eps0 = run_trace(name, 0, vc_backend=backend, event_epsilon=0.0)
    assert_traces_equal(ref, eps0)


@pytest.mark.parametrize("eps", (0.5, 2.0))
@pytest.mark.parametrize("name", TRACE_SCHEDULERS)
def test_eps_runs_reproducible_in_process(name, eps):
    """Two fresh simulations at the same eps must agree exactly —
    completions, stats, and pass counts."""
    a = run_trace(name, 0, event_epsilon=eps)
    b = run_trace(name, 0, event_epsilon=eps)
    assert_traces_equal(a, b)


def _trace_fingerprint(summary: dict) -> list:
    return [
        sorted(summary["completion"].items()),
        summary["preemption"],
        summary["locality"],
        summary["delay"],
        summary["training"],
        summary["passes"],
    ]


def test_eps_run_reproducible_across_process_restart():
    """An eps>0 schedule is a pure function of the event stream: a fresh
    interpreter must reproduce it exactly (no process-lifetime state —
    set ordering, hash seeds, jit caches — may leak into the schedule)."""
    here = run_trace("hfsp", 0, num_jobs=15, num_machines=10,
                     event_epsilon=1.5)
    prog = (
        "import sys, json; sys.path[:0] = [{src!r}, {tests!r}]\n"
        "from conformance import run_trace\n"
        "s = run_trace('hfsp', 0, num_jobs=15, num_machines=10, "
        "event_epsilon=1.5)\n"
        "s['completion'] = sorted(s['completion'].items())\n"
        "print(json.dumps(s))"
    ).format(
        src=os.path.join(os.path.dirname(__file__), "..", "src"),
        tests=os.path.dirname(__file__),
    )
    env = dict(os.environ, PYTHONHASHSEED="42")  # differ on purpose
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, env=env, check=True,
    )
    remote = json.loads(out.stdout)
    assert remote["completion"] == [
        [k, v] for k, v in sorted(here["completion"].items())
    ]
    for key in ("locality", "preemption", "delay", "training", "passes"):
        got = remote[key]
        want = here[key]
        if isinstance(want, tuple):
            want = list(want)
        assert got == want, f"{key}: {got} != {want}"


def test_eps_cuts_pass_count_on_bursty_trace():
    """Coalescing must measurably reduce passes at equal workload (every
    run drains the same 30-job trace to completion)."""
    base = run_trace("hfsp", 0, event_epsilon=0.0)
    half = run_trace("hfsp", 0, event_epsilon=0.5)
    wide = run_trace("hfsp", 0, event_epsilon=5.0)
    assert set(half["completion"]) == set(base["completion"])
    assert set(wide["completion"]) == set(base["completion"])
    assert half["passes"] < base["passes"]
    assert wide["passes"] < half["passes"]


def test_until_is_a_window_barrier_and_max_events_is_not():
    """run(until=T) flushes the pending pass at the barrier (callers see
    fully-scheduled state), while max_events slicing preserves the open
    window and replays the unsliced schedule exactly."""
    from repro.core import ClusterSpec, FIFOScheduler, Simulator
    from repro.core.simulator import EventLimitReached
    from repro.core.types import JobSpec, Phase, TaskSpec

    cluster = ClusterSpec(num_machines=1, map_slots_per_machine=2,
                          reduce_slots_per_machine=0)

    def jobs():
        return [
            JobSpec(0, 4.9, (TaskSpec(0, Phase.MAP, 0, 5.0),), ()),
            JobSpec(1, 5.5, (TaskSpec(1, Phase.MAP, 0, 5.0),), ()),
        ]

    # Unsliced: both arrivals share one eps=2 window -> both start at 5.5.
    ref = Simulator(cluster, FIFOScheduler(cluster), jobs(),
                    event_epsilon=2.0).run()
    assert ref.completion == {0: 10.5, 1: 10.5}

    # until=5.0 barrier: the t=4.9 arrival's pass flushes at the barrier
    # (job 0 starts at 4.9), then the t=5.5 arrival anchors a new window.
    sliced = Simulator(cluster, FIFOScheduler(cluster), jobs(),
                       event_epsilon=2.0)
    sliced.run(until=5.0)
    assert sliced._window_end is None  # no window left open at a barrier
    res = sliced.run()
    assert res.completion == {0: 9.9, 1: 10.5}

    # max_events slicing: window survives the budget exception and the
    # continued run reproduces the unsliced schedule bit for bit.
    chunked = Simulator(cluster, FIFOScheduler(cluster), jobs(),
                        event_epsilon=2.0)
    while True:
        try:
            res = chunked.run(max_events=1)
            break
        except EventLimitReached:
            continue
    assert res.completion == ref.completion
    assert chunked.passes == ref.passes


def test_until_barrier_flushes_window_left_open_by_event_budget():
    """A max_events slice can raise with a window open; a following
    run(until=T) whose barrier lands before the window's next event must
    still flush the deferred pass before returning (the caller observes
    fully-scheduled state at the barrier)."""
    from repro.core import ClusterSpec, FIFOScheduler, Simulator
    from repro.core.simulator import EventLimitReached
    from repro.core.types import JobSpec, Phase, TaskSpec

    cluster = ClusterSpec(num_machines=1, map_slots_per_machine=2,
                          reduce_slots_per_machine=0)
    jobs = [
        JobSpec(0, 4.9, (TaskSpec(0, Phase.MAP, 0, 5.0),), ()),
        JobSpec(1, 5.5, (TaskSpec(1, Phase.MAP, 0, 5.0),), ()),
    ]
    sim = Simulator(cluster, FIFOScheduler(cluster), jobs,
                    event_epsilon=2.0)
    # Slice the first event: t=5.5 is inside the t=4.9+2.0 window, so the
    # budget exception leaves the window open...
    with pytest.raises(EventLimitReached):
        sim.run(max_events=1)
    assert sim._window_end is not None
    # ...and an until-barrier below the next event must flush the pass —
    # even under a minimal event budget: the barrier iteration processes
    # no event, so it cannot be preempted by EventLimitReached.
    sim.run(until=5.0, max_events=1)
    assert sim._window_end is None
    assert sim.scheduler.jobs[0].n_running(Phase.MAP) == 1
    res = sim.run()
    assert res.completion == {0: 9.9, 1: 10.5}


def test_simconfig_rejects_conflicting_kwargs():
    """config=SimConfig(...) replaces the individual executor knobs;
    passing both must raise instead of silently dropping one side."""
    from repro.core import ClusterSpec, FIFOScheduler, SimConfig, Simulator

    cluster = ClusterSpec(num_machines=1)
    sch = FIFOScheduler(cluster)
    with pytest.raises(ValueError, match="track_timeline"):
        Simulator(
            cluster, sch, [], track_timeline=True,
            config=SimConfig(event_epsilon=0.5),
        )
    # Config alone is fine and applies its knobs.
    sim = Simulator(
        cluster, sch, [], config=SimConfig(event_epsilon=0.5, heartbeat=7.0)
    )
    assert sim.event_epsilon == 0.5 and sim.heartbeat == 7.0


def test_eps_window_applies_mutations_at_own_timestamps():
    """Completion times recorded inside a window keep their own event
    timestamps — only the scheduling pass moves to the window end."""
    from repro.core import ClusterSpec, FIFOScheduler, Simulator
    from repro.core.types import JobSpec, Phase, TaskSpec

    cluster = ClusterSpec(num_machines=1, map_slots_per_machine=2,
                          reduce_slots_per_machine=0)
    # Two single-task jobs arriving 0.3s apart, durations chosen so the
    # completions land 0.3s apart too — inside one eps=1 window.
    jobs = [
        JobSpec(0, 0.0, (TaskSpec(0, Phase.MAP, 0, 5.0),), ()),
        JobSpec(1, 0.3, (TaskSpec(1, Phase.MAP, 0, 5.0),), ()),
    ]
    res0 = Simulator(cluster, FIFOScheduler(cluster), jobs).run()
    res1 = Simulator(
        cluster, FIFOScheduler(cluster), jobs, event_epsilon=1.0
    ).run()
    # Arrivals coalesce into one window ending at t=0.3, so BOTH tasks
    # start at 0.3 under eps=1 (vs 0.0/0.3 under eps=0) — and each
    # completion is then stamped at its own start+duration instant.
    assert res0.completion[0] == 5.0 and res0.completion[1] == 5.3
    assert res1.completion[0] == 5.3 and res1.completion[1] == 5.3
    assert res1.passes < res0.passes


# ---------------------------------------------------------------------------
# event_epsilon="auto": burstiness-derived window width (PR 8)
# ---------------------------------------------------------------------------
def test_auto_event_epsilon_smooth_stream_disables_batching():
    """Evenly spaced (CV=0) arrivals gain nothing from a window."""
    from repro.core.simulator import auto_event_epsilon

    assert auto_event_epsilon([float(i) for i in range(50)]) == 0.0


def test_auto_event_epsilon_bursty_stream_picks_median_gap():
    """Bursts of near-simultaneous arrivals separated by long idle gaps:
    the window covers the intra-burst gaps (median) but not the
    inter-burst ones."""
    from repro.core.simulator import auto_event_epsilon

    arrivals = []
    for burst in range(10):
        base = burst * 100.0
        arrivals += [base + 0.01 * k for k in range(8)]
    eps = auto_event_epsilon(arrivals, heartbeat=3.0)
    assert eps == pytest.approx(0.01)


def test_auto_event_epsilon_caps_at_heartbeat_and_degenerates_safely():
    from repro.core.simulator import auto_event_epsilon

    # All-simultaneous arrivals: mean gap 0 -> the full heartbeat.
    assert auto_event_epsilon([5.0] * 10, heartbeat=3.0) == 3.0
    # Fewer than 3 arrivals: one gap is not a distribution.
    assert auto_event_epsilon([], heartbeat=3.0) == 0.0
    assert auto_event_epsilon([1.0, 2.0], heartbeat=3.0) == 0.0
    # Bursty with a huge median gap still caps at the heartbeat.
    arrivals = [0.0, 0.0, 0.0, 1000.0, 1000.0, 1000.0, 5000.0]
    assert auto_event_epsilon(arrivals, heartbeat=3.0) <= 3.0


def test_simulator_accepts_auto_event_epsilon():
    """event_epsilon="auto" resolves at construction to the same width
    auto_event_epsilon reports for the job list, and the run is
    bit-identical to passing that width explicitly."""
    from repro.core import ClusterSpec, SimConfig, Simulator
    from repro.core.disciplines import build_scheduler
    from repro.core.simulator import auto_event_epsilon
    from repro.workload import fb_dataset, WorkloadSpec

    cluster = ClusterSpec(num_machines=10)
    jobs, _ = fb_dataset(
        seed=0, num_jobs=20, spec=WorkloadSpec(num_machines=10)
    )
    expect = auto_event_epsilon([j.arrival_time for j in jobs])
    sim = Simulator(
        cluster, build_scheduler("hfsp", cluster), jobs,
        config=SimConfig(event_epsilon="auto"),
    )
    assert sim.event_epsilon == expect
    res_auto = sim.run()
    res_expl = Simulator(
        cluster, build_scheduler("hfsp", cluster), jobs,
        config=SimConfig(event_epsilon=expect),
    ).run()
    assert res_auto.completion == res_expl.completion
    assert sim.passes == res_expl.passes

    with pytest.raises(ValueError, match="auto"):
        Simulator(
            cluster, build_scheduler("hfsp", cluster), jobs,
            config=SimConfig(event_epsilon="bogus"),
        )


def test_scenario_spec_accepts_auto_event_epsilon(tmp_path):
    """"auto" round-trips through the spec dict/JSON form and runs."""
    from repro.scenarios import run_scenario
    from repro.scenarios.spec import ScenarioSpec, WorkloadAxis, ClusterAxis

    spec = ScenarioSpec(
        name="auto-eps",
        workload=WorkloadAxis(kind="fb", num_jobs=10, num_hosts=5),
        cluster=ClusterAxis(num_machines=5),
        event_epsilon="auto",
    )
    again = ScenarioSpec.from_dict(spec.to_dict())
    assert again.event_epsilon == "auto"
    assert again.spec_hash() == spec.spec_hash()
    rep = run_scenario(spec)
    assert rep["jobs_completed"] == 10
