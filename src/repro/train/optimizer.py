"""AdamW from scratch (no optax in this environment), plus LR schedules,
global-norm clipping, and optional int8 gradient compression hooks.

The optimizer state is a plain pytree ``{"m": ..., "v": ..., "step": ...}``
— shardable with the same PartitionSpecs as the parameters, checkpointable
with repro.checkpoint.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine | linear | constant
    min_lr_ratio: float = 0.1
    # Beyond-paper distributed trick: quantize gradients to int8 (with a
    # per-leaf fp32 scale) before the DP all-reduce, dequantize after.
    grad_compression: str | None = None   # None | "int8"


def lr_at(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac)
            )
        else:  # linear
            decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    return cfg.lr * warm * decay


def init_opt_state(params) -> dict:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def compress_int8(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization (gradient compression)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _is_decayed(path: tuple) -> bool:
    """Weight decay applies to matmul weights only — not norms, biases,
    decay vectors or bonus terms."""
    last = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    no_decay = (
        "scale", "bias", "mu", "bonus_u", "A_log", "D", "dt_bias",
        "ln_x_scale", "norm_scale", "wd_bias",
    )
    return not any(last.startswith(n) or last.endswith(n) for n in no_decay)


def adamw_update(
    cfg: OptimizerConfig, params, grads, opt_state
) -> tuple[dict, dict, dict]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _is_decayed(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    gs = jax.tree.leaves(grads)
    ms = jax.tree.leaves(opt_state["m"])
    vs = jax.tree.leaves(opt_state["v"])
    outs = [upd(pth, p, g, m, v) for (pth, p), g, m, v in zip(flat, gs, ms, vs)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
