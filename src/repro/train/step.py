"""train_step / eval_step factories.

``make_train_step(cfg, opt_cfg)`` returns a pure ``(state, batch) ->
(state, metrics)`` function suitable for ``jax.jit`` with in/out shardings
from :mod:`repro.sharding`.  Features:

* mixed precision: bf16 activations, fp32 master weights & Adam moments
  (the cast policy lives in the model layer);
* activation rematerialisation: the whole per-layer scan body is
  checkpointed (``remat="block"``), the standard memory/compute trade for
  long-sequence training;
* gradient accumulation (microbatching) via ``lax.scan`` over microbatches;
* optional int8 gradient compression before the DP all-reduce
  (``opt_cfg.grad_compression='int8'``) — a beyond-paper distributed trick,
  measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    compress_int8,
    decompress_int8,
    init_opt_state,
)


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1          # gradient accumulation factor
    remat: str = "block"           # none | block
    use_flash: bool = False        # Pallas kernels on (TPU target)
    interpret: bool = False        # Pallas interpret mode (CPU tests)
    aux_weight: float = 0.01       # MoE load-balance loss weight


def make_loss(cfg: ModelConfig, tc: TrainConfig):
    if tc.remat == "block" and not cfg.remat:
        import dataclasses

        cfg = dataclasses.replace(cfg, remat=True)

    def _loss(params, batch):
        return loss_fn(
            cfg, params, batch,
            use_flash=tc.use_flash, interpret=tc.interpret,
            aux_weight=tc.aux_weight,
        )

    return _loss


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    tc: TrainConfig | None = None,
):
    tc = tc or TrainConfig()
    loss = make_loss(cfg, tc)

    def grad_of(params, batch):
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch
        )
        return grads, metrics

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt_state = state["params"], state["opt"]
        if tc.microbatches > 1:
            mb = tc.microbatches

            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                g_acc = carry
                g, m = grad_of(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return g_acc, m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, metrics = jax.lax.scan(acc_body, zeros, micro)
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            grads, metrics = grad_of(params, batch)

        if opt_cfg.grad_compression == "int8":
            # Quantize -> (implicit DP all-reduce on the quantized tree
            # under pjit) -> dequantize.  XLA fuses the pack/unpack.
            q = jax.tree.map(compress_int8, grads, is_leaf=lambda x: hasattr(x, "shape"))
            grads = jax.tree.map(
                lambda qs: decompress_int8(*qs),
                q,
                is_leaf=lambda x: isinstance(x, tuple),
            )

        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        metrics = {**metrics, **opt_metrics}
        return {"params": params, "opt": opt_state}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, tc: TrainConfig | None = None):
    tc = tc or TrainConfig()
    loss = make_loss(cfg, tc)

    def eval_step(params, batch):
        _, metrics = loss(params, batch)
        return metrics

    return eval_step


def init_train_state(cfg: ModelConfig, key) -> dict:
    from repro.models import init_model

    params = init_model(cfg, key)
    return {"params": params, "opt": init_opt_state(params)}
