"""Job size estimation — the Training module (Sect. 3.2).

Size-based scheduling needs job sizes, which are unknown a priori.  HFSP
estimates them online:

* the *initial estimate* of a phase is ``num_tasks x mean-recent-task-time
  x xi`` where xi in [1, inf) is the confidence parameter (Sect. 3.1.1);
* a *sample set* of ``s`` tasks (s=5 in the paper) is executed under a fair
  share granted by the top-level scheduler; their measured runtimes are fed
  to a *pluggable estimator* that fits a task-time CDF by least-squares
  regression against a reference distribution family (Sect. 3.2.1);
* REDUCE tasks can be orders of magnitude longer than MAP tasks, so their
  runtime is estimated *before completion* as ``sigma = Delta / p`` where
  ``p`` is the fraction of input processed after ``Delta`` seconds of
  execution (Delta = 60 s in the paper) — p embeds input-size skew.

Estimators return a full per-task duration *vector* (the paper's
``M_i = [sigma(m_1), sigma(m_2), ...]``); the phase size estimate is its sum.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Protocol

import numpy as np

from repro.core.types import JobState, Phase, TaskState


# ---------------------------------------------------------------------------
# Pluggable task-time distribution estimators (Sect. 3.2.1)
# ---------------------------------------------------------------------------
class TaskTimeEstimator(Protocol):
    """Fit a task-time distribution from sample runtimes and extrapolate."""

    def fit_vector(self, samples: list[float], num_tasks: int) -> list[float]:
        """Return estimated durations for all ``num_tasks`` tasks."""
        ...


class FirstOrderEstimator:
    """Mean-based estimator (what the paper's experiments use: 'first order
    statistic estimators that assume uniformly distributed task sizes')."""

    def fit_vector(self, samples: list[float], num_tasks: int) -> list[float]:
        if not samples:
            return [math.inf] * num_tasks
        mu = float(np.mean(samples))
        return [mu] * num_tasks


@dataclass
class DistributionFitEstimator:
    """Least-squares CDF regression against a reference family (Sect. 3.2.1).

    ``family`` picks the reference task-time distribution; parameters are
    fit by minimizing squared error between the model CDF and the empirical
    CDF of the samples.  The estimated CDF is then inverted at the
    mid-quantiles ``(k + 0.5)/n`` to produce the per-task duration vector.
    """

    family: str = "lognormal"  # uniform | exponential | lognormal | weibull

    def fit_vector(self, samples: list[float], num_tasks: int) -> list[float]:
        if not samples:
            return [math.inf] * num_tasks
        xs = np.sort(np.asarray(samples, dtype=np.float64))
        xs = np.maximum(xs, 1e-9)
        n = len(xs)
        # Empirical CDF at the sample points (Hazen plotting positions).
        ecdf = (np.arange(1, n + 1) - 0.5) / n
        q = (np.arange(num_tasks) + 0.5) / num_tasks
        if self.family == "uniform" or n == 1:
            # U(a, b): LS fit degenerates to moment matching on order stats.
            a, b = self._fit_uniform(xs, ecdf)
            vec = a + q * (b - a)
        elif self.family == "exponential":
            # F(x) = 1 - exp(-x/mu): -log(1-F) = x/mu -> LS through origin.
            y = -np.log1p(-np.clip(ecdf, 0, 1 - 1e-9))
            mu = float(np.dot(xs, y) / max(np.dot(y, y), 1e-30))
            vec = -mu * np.log1p(-np.clip(q, 0, 1 - 1e-12))
        elif self.family == "weibull":
            # log(-log(1-F)) = k log x - k log lam -> linear LS.
            y = np.log(-np.log1p(-np.clip(ecdf, 0, 1 - 1e-9)))
            k, c = np.polyfit(np.log(xs), y, 1)
            k = max(float(k), 1e-3)
            lam = math.exp(-float(c) / k)
            vec = lam * (-np.log1p(-np.clip(q, 0, 1 - 1e-12))) ** (1.0 / k)
        else:  # lognormal: Phi^-1(F) = (log x - m)/s -> linear LS.
            y = _norm_ppf(np.clip(ecdf, 1e-9, 1 - 1e-9))
            s, m = np.polyfit(y, np.log(xs), 1)
            vec = np.exp(m + s * _norm_ppf(np.clip(q, 1e-12, 1 - 1e-12)))
        vec = np.maximum(np.asarray(vec, dtype=np.float64), 1e-9)
        return [float(v) for v in vec]

    @staticmethod
    def _fit_uniform(xs: np.ndarray, ecdf: np.ndarray) -> tuple[float, float]:
        # LS fit of F(x) = (x-a)/(b-a) over the samples.
        slope, intercept = np.polyfit(xs, ecdf, 1) if len(xs) > 1 else (0.0, 0.0)
        if slope <= 1e-12:
            lo = hi = float(np.mean(xs))
            return lo, hi
        a = -intercept / slope
        b = a + 1.0 / slope
        return min(a, float(xs[0])), max(b, float(xs[-1]))


def _norm_ppf(p: np.ndarray) -> np.ndarray:
    """Acklam's rational approximation of the standard normal inverse CDF
    (numpy-only; scipy is not available in this environment)."""
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    p = np.asarray(p, dtype=np.float64)
    out = np.empty_like(p)
    plow, phigh = 0.02425, 1 - 0.02425
    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)
    if np.any(lo):
        qq = np.sqrt(-2 * np.log(p[lo]))
        out[lo] = (((((c[0] * qq + c[1]) * qq + c[2]) * qq + c[3]) * qq + c[4]) * qq + c[5]) / (
            (((d[0] * qq + d[1]) * qq + d[2]) * qq + d[3]) * qq + 1
        )
    if np.any(hi):
        qq = np.sqrt(-2 * np.log(1 - p[hi]))
        out[hi] = -(((((c[0] * qq + c[1]) * qq + c[2]) * qq + c[3]) * qq + c[4]) * qq + c[5]) / (
            (((d[0] * qq + d[1]) * qq + d[2]) * qq + d[3]) * qq + 1
        )
    if np.any(mid):
        qq = p[mid] - 0.5
        r = qq * qq
        out[mid] = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * qq / (
            ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1
        )
    return out


# ---------------------------------------------------------------------------
# Recent-task statistics (for the xi-weighted initial estimate, Sect. 3.1.1)
# ---------------------------------------------------------------------------
@dataclass
class RecentTaskStats:
    """Rolling mean of recently-completed task durations, per phase."""

    window: int = 50
    default: float = 30.0  # cold-start guess (seconds) before any completion
    _hist: dict[Phase, deque] = field(default_factory=dict)

    def observe(self, phase: Phase, duration: float) -> None:
        self._hist.setdefault(phase, deque(maxlen=self.window)).append(duration)

    def mean(self, phase: Phase) -> float:
        h = self._hist.get(phase)
        return float(np.mean(h)) if h else self.default


# ---------------------------------------------------------------------------
# The Training module (Sect. 3.2)
# ---------------------------------------------------------------------------
@dataclass
class _PhaseTraining:
    sample_keys: list[tuple] = field(default_factory=list)
    observed: dict[tuple, float] = field(default_factory=dict)
    done: bool = False


@dataclass
class TrainingModule:
    """Drives per-job size estimation; acts as a sub-scheduler fed slots by
    the top-level scheduler (Sect. 3.1.1).

    Parameters mirror the paper's Sect. 4.1 configuration: sample set size
    ``t`` = 5 for both phases, ``Delta`` = 60 s, confidence ``xi`` = 1.
    """

    sample_set_size: int = 5
    delta: float = 60.0
    xi: float = 1.0
    estimator: TaskTimeEstimator = field(default_factory=FirstOrderEstimator)
    recent: RecentTaskStats = field(default_factory=RecentTaskStats)
    _training: dict[tuple[int, Phase], _PhaseTraining] = field(default_factory=dict)
    # Insertion-ordered index of (job, phase) pairs still training — lets
    # the scheduler iterate only in-training jobs instead of probing every
    # live job each pass.  Entries leave when training finalizes.
    _active: dict[tuple[int, Phase], None] = field(default_factory=dict)
    # -- demand indexes (kept in lockstep with task-state events) -----------
    # Jobs with >=1 dispatchable sample task (PENDING and unobserved) —
    # exactly the jobs the training scheduler can act on this pass.
    _wanted: dict[Phase, dict[int, None]] = field(
        default_factory=lambda: {Phase.MAP: {}, Phase.REDUCE: {}}
    )
    # Per-job RUNNING sample keys (in sample-set order) for active jobs,
    # plus an O(1) total — feeds the training budget and the protected-key
    # quota without re-probing every active job's sample set each pass.
    _running: dict[Phase, dict[int, dict[tuple, None]]] = field(
        default_factory=lambda: {Phase.MAP: {}, Phase.REDUCE: {}}
    )
    _n_running: dict[Phase, int] = field(
        default_factory=lambda: {Phase.MAP: 0, Phase.REDUCE: 0}
    )

    # -- lifecycle -----------------------------------------------------------
    def start_phase(self, job: JobState, phase: Phase) -> float:
        """Begin training for a job phase; return the initial size estimate.

        Initial estimate = num_tasks x mean recent task duration x xi.
        xi = inf parks the job at the back of the queue until trained.
        """
        tasks = job.spec.tasks(phase)
        st = _PhaseTraining()
        st.sample_keys = [t.key for t in tasks[: self.sample_set_size]]
        if not tasks:
            st.done = True
        self._training[(job.spec.job_id, phase)] = st
        job.in_training[phase] = not st.done
        if not st.done:
            self._active[(job.spec.job_id, phase)] = None
            self.sync_job(job, phase)
        if not tasks:
            return 0.0
        if math.isinf(self.xi):
            return math.inf
        return len(tasks) * self.recent.mean(phase) * self.xi

    def is_training(self, job_id: int, phase: Phase) -> bool:
        return (job_id, phase) in self._active

    def active_jobs(self, phase: Phase) -> list[int]:
        """Job ids still training this phase, in training-start order."""
        return [j for (j, p) in self._active if p is phase]

    # -- demand-index queries (O(1) / O(result)) -----------------------------
    def wanted_jobs(self, phase: Phase) -> list[int]:
        """Training jobs with >=1 dispatchable sample task this phase —
        the only jobs the training scheduler can act on."""
        return list(self._wanted[phase])

    def n_running_samples(self, phase: Phase) -> int:
        """Total RUNNING sample tasks across active jobs (O(1))."""
        return self._n_running[phase]

    def running_sample_keys(self, job_id: int, phase: Phase) -> list[tuple]:
        """RUNNING sample keys of one active job, in sample-set order."""
        return list(self._running[phase].get(job_id, ()))

    def running_sample_jobs(self, phase: Phase) -> dict[int, dict[tuple, None]]:
        """{job_id: running-sample-key dict} for active jobs with >=1
        RUNNING sample (read-only view; the protected-key quota walks
        this instead of probing every active job)."""
        return self._running[phase]

    def check_indexes(self, phase: Phase, jobs: dict[int, "JobState"]) -> None:
        """Paranoid cross-check: rebuild the wanted/running-sample
        reference by probing every active job's sample states and assert
        the incremental indexes match (called from HFSP's paranoid pass
        alongside the scheduler-level demand-index check)."""
        ref_wanted: set[int] = set()
        ref_running: dict[int, list[tuple]] = {}
        for (jid, p), st in self._training.items():
            if p is not phase or st.done or (jid, p) not in self._active:
                continue
            job = jobs.get(jid)
            if job is None:
                continue
            for key in st.sample_keys:
                att = job.tasks[key]
                if att.state is TaskState.RUNNING:
                    ref_running.setdefault(jid, []).append(key)
                elif att.state is TaskState.PENDING and key not in st.observed:
                    ref_wanted.add(jid)
        assert set(self._wanted[phase]) == ref_wanted, (
            f"training wanted mismatch ({phase}): "
            f"{set(self._wanted[phase])} != {ref_wanted}"
        )
        got_running = {j: list(ks) for j, ks in self._running[phase].items()}
        assert got_running == ref_running, (
            f"training running-sample mismatch ({phase})"
        )
        assert self._n_running[phase] == sum(
            len(v) for v in ref_running.values()
        ), f"training running-sample count mismatch ({phase})"

    def sync_job(self, job: JobState, phase: Phase) -> None:
        """Recompute this job's demand-index entries from its (<= sample
        set size) sample-task states.  Called after every executor event
        that can change a sample task's state or observation status —
        O(sample set) per event, which keeps every per-pass training query
        O(actionable) instead of O(active jobs)."""
        jid = job.spec.job_id
        st = self._training.get((jid, phase))
        run_idx = self._running[phase]
        old = run_idx.get(jid)
        if st is None or st.done:
            self._wanted[phase].pop(jid, None)
            if old is not None:
                self._n_running[phase] -= len(old)
                del run_idx[jid]
            return
        wanted = False
        running: dict[tuple, None] = {}
        for key in st.sample_keys:
            att = job.tasks[key]
            if att.state is TaskState.RUNNING:
                running[key] = None
            elif att.state is TaskState.PENDING and key not in st.observed:
                wanted = True
        if wanted:
            self._wanted[phase][jid] = None
        else:
            self._wanted[phase].pop(jid, None)
        self._n_running[phase] += len(running) - (len(old) if old else 0)
        if running:
            run_idx[jid] = running
        elif old is not None:
            del run_idx[jid]

    def sample_keys(self, job_id: int, phase: Phase) -> list[tuple]:
        st = self._training.get((job_id, phase))
        return list(st.sample_keys) if st else []

    def n_observations(self, job_id: int, phase: Phase) -> int:
        """Sample observations recorded so far — the estimate's version
        number (rank-stability verdicts are cached per version)."""
        st = self._training.get((job_id, phase))
        return len(st.observed) if st else 0

    def wanted_sample_tasks(self, job: JobState, phase: Phase) -> list[tuple]:
        """Sample-set tasks not yet dispatched (the slots this module asks
        the top-level scheduler for)."""
        st = self._training.get((job.spec.job_id, phase))
        if st is None or st.done:
            return []
        out = []
        for key in st.sample_keys:
            att = job.tasks[key]
            if att.state is TaskState.PENDING and key not in st.observed:
                out.append(key)
        return out

    def candidate_sizes(self, job: JobState, phase: Phase) -> list[float]:
        """Hypothetical phase sizes consistent with the observations so far.

        While a job is still training, its size estimate is provisional —
        each new sample observation can move it.  This returns the full
        refit plus every leave-one-out refit of the current sample
        durations (<= sample_set_size + 1 candidates, deterministic), i.e.
        the spread of sizes the estimator could settle on.  Feed these to
        :meth:`VirtualCluster.projected_finish_batch` (via
        ``HFSPScheduler.rank_stability``) to price all what-if
        re-projections in one batched kernel call."""
        st = self._training.get((job.spec.job_id, phase))
        if st is None or not st.observed:
            return []
        obs = list(st.observed.values())
        n_tasks = len(job.spec.tasks(phase))
        sizes = [float(sum(self.estimator.fit_vector(obs, n_tasks)))]
        if len(obs) > 1:
            for i in range(len(obs)):
                sub = obs[:i] + obs[i + 1:]
                sizes.append(float(sum(self.estimator.fit_vector(sub, n_tasks))))
        return sizes

    def lose_sample(self, job: JobState, phase: Phase, key: tuple) -> None:
        """Fault layer: a completed sample task's duration observation was
        dropped in flight (repro.core.faults).  Re-request coherently:
        swap the lost key for a replacement task that can still run (so a
        real observation eventually arrives); when no replacement exists
        the sample set shrinks and :meth:`_maybe_finalize`'s threshold
        shrinks with it.  No-op if the key was already observed (e.g. an
        earlier sigma = Delta/p progress estimate survives the loss)."""
        st = self._training.get((job.spec.job_id, phase))
        if st is None or st.done or key not in st.sample_keys:
            return
        if key in st.observed:
            return
        idx = st.sample_keys.index(key)
        in_set = set(st.sample_keys)
        replacement = None
        for t in job.spec.tasks(phase):
            if t.key in in_set:
                continue
            if job.tasks[t.key].state is not TaskState.DONE:
                replacement = t.key
                break
        if replacement is not None:
            st.sample_keys[idx] = replacement
        else:
            del st.sample_keys[idx]
            if not st.sample_keys and not st.observed:
                # Every observation lost and nothing left to sample:
                # training can never complete — close it out; the phase
                # keeps its initial xi-weighted estimate.
                st.done = True
                job.in_training[phase] = False
                self._active.pop((job.spec.job_id, phase), None)
        self.sync_job(job, phase)

    # -- observations ----------------------------------------------------------
    def observe_completion(self, job: JobState, phase: Phase, key: tuple,
                           duration: float) -> float | None:
        """Record a finished task; returns the new phase-size estimate when
        the sample set completes, else None."""
        self.recent.observe(phase, duration)
        st = self._training.get((job.spec.job_id, phase))
        if st is None or st.done:
            return None
        if key in st.sample_keys:
            st.observed[key] = duration
        out = self._maybe_finalize(job, phase, st)
        self.sync_job(job, phase)
        return out

    def observe_progress(self, job: JobState, phase: Phase, key: tuple,
                         progress_fraction: float, elapsed: float) -> float | None:
        """REDUCE-style early estimate: sigma = Delta / p (Sect. 3.2.1).

        Called by the executor once a sample REDUCE task has run for
        ``Delta`` seconds; ``progress_fraction`` is the fraction of its
        input processed so far.
        """
        st = self._training.get((job.spec.job_id, phase))
        if st is None or st.done or key not in st.sample_keys:
            return None
        if key in st.observed:
            return None
        p = max(progress_fraction, 1e-9)
        st.observed[key] = elapsed / p
        out = self._maybe_finalize(job, phase, st)
        self.sync_job(job, phase)
        return out

    def _maybe_finalize(self, job: JobState, phase: Phase,
                        st: _PhaseTraining) -> float | None:
        """Refit the phase-size estimate after EVERY observation.

        Waiting for the full sample set before producing any estimate is
        fragile: if sample tasks get suspended under load, the job would
        keep a stale (often badly low) initial estimate, sort first
        forever, and preempt the very jobs that should run before it.
        Partial-sample estimates are provisional; training completes (and
        stops consuming Training-module slots) at ``sample_set_size``
        observations as in the paper."""
        n_needed = min(self.sample_set_size, len(job.spec.tasks(phase)))
        # Sample loss without a replacement shrinks the achievable set
        # (every observed key is a sample key, so len(sample_keys) bounds
        # the observations that can ever arrive).  Zero-fault runs always
        # have len(sample_keys) == n_needed — the min is inert there.
        n_needed = min(n_needed, len(st.sample_keys))
        if not st.observed:
            return None
        if len(st.observed) >= n_needed:
            st.done = True
            job.in_training[phase] = False
            self._active.pop((job.spec.job_id, phase), None)
        vec = self.estimator.fit_vector(
            list(st.observed.values()), len(job.spec.tasks(phase))
        )
        return float(sum(vec))
