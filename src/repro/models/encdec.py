"""Whisper-style encoder-decoder (whisper-base: 6+6 layers, d=512).

The audio frontend (log-mel + two convs) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (b, F=1500, d).  The
transformer backbone is exact: learned positional embeddings, pre-LN
blocks, GELU MLPs with biases, decoder cross-attention.

Decode keeps a self-attn KV cache per decoder layer plus the (fixed)
cross-attention K/V computed once from the encoder output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    _out,
    _qkv,
    decode_attention_block,
    init_attention,
    mha,
)
from repro.models.common import (
    apply_mlp,
    apply_norm,
    embed_init,
    embed_tokens,
    init_embed,
    init_mlp,
    init_norm,
    unembed,
)


def init_encdec(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    Le, Ld = cfg.enc_layers, cfg.dec_layers

    def stack_norm(L):
        base = init_norm(cfg)
        return {k: jnp.broadcast_to(v, (L, *v.shape)).copy() for k, v in base.items()}

    return {
        "embed": init_embed(cfg, keys[0]),
        "enc_pos": embed_init(keys[1], (cfg.num_frames, cfg.d_model), cfg.param_dtype),
        "dec_pos": embed_init(keys[2], (4096, cfg.d_model), cfg.param_dtype),
        "encoder": {
            "ln1": stack_norm(Le),
            "attn": init_attention(cfg, keys[3], layers=Le),
            "ln2": stack_norm(Le),
            "mlp": init_mlp(cfg, keys[4], layers=Le),
        },
        "decoder": {
            "ln1": stack_norm(Ld),
            "self_attn": init_attention(cfg, keys[5], layers=Ld),
            "ln_x": stack_norm(Ld),
            "cross_attn": init_attention(cfg, keys[6], layers=Ld),
            "ln2": stack_norm(Ld),
            "mlp": init_mlp(cfg, keys[7], layers=Ld),
        },
        "enc_final": init_norm(cfg),
        "final_norm": init_norm(cfg),
    }


def _self_attention(cfg, p, x, causal: bool):
    b, s, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    else:
        mask = jnp.ones((s, s), dtype=bool)
    return _out(cfg, p, mha(cfg, q, k, v, mask))


def _cross_attention(cfg, p, x, enc):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    mask = jnp.ones((x.shape[1], enc.shape[1]), dtype=bool)
    return _out(cfg, p, mha(cfg, q, k, v, mask))


def _layer_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _enc_layer(cfg, p_l, x):
    h = _self_attention(cfg, p_l["attn"], apply_norm(cfg, p_l["ln1"], x), False)
    x = x + h
    m = apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))
    return x + m


def encode(cfg: ModelConfig, params: dict, frame_embeds: jnp.ndarray) -> jnp.ndarray:
    x = frame_embeds + params["enc_pos"][None, : frame_embeds.shape[1]].astype(
        frame_embeds.dtype
    )
    layer = (lambda p_l, x: _enc_layer(cfg, p_l, x))
    if cfg.remat:
        layer = jax.checkpoint(layer)
    if not cfg.scan_layers:
        for i in range(cfg.enc_layers):
            x = layer(_layer_slice(params["encoder"], i), x)
    else:
        def body(x, p_l):
            return layer(p_l, x), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(cfg, params["enc_final"], x)


def encdec_forward(
    cfg: ModelConfig, params: dict, batch: dict,
    unembed_last_only: bool = False, **_unused
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """batch: {"tokens": (b, s), "frame_embeds": (b, F, d)}."""
    enc = encode(cfg, params, batch["frame_embeds"])
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params["embed"], tokens)
    s = tokens.shape[1]
    pos = params["dec_pos"]
    if s > pos.shape[0]:  # stress shapes (32k decoder prefill)
        reps = -(-s // pos.shape[0])
        pos = jnp.tile(pos, (reps, 1))
    x = x + pos[None, :s].astype(x.dtype)

    def dec_layer(p_l, x):
        h = _self_attention(
            cfg, p_l["self_attn"], apply_norm(cfg, p_l["ln1"], x), True
        )
        x = x + h
        h = _cross_attention(
            cfg, p_l["cross_attn"], apply_norm(cfg, p_l["ln_x"], x), enc
        )
        x = x + h
        m = apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))
        return x + m

    if cfg.remat:
        dec_layer = jax.checkpoint(dec_layer)
    if not cfg.scan_layers:
        for i in range(cfg.dec_layers):
            x = dec_layer(_layer_slice(params["decoder"], i), x)
    else:
        def body(x, p_l):
            return dec_layer(p_l, x), None

        x, _ = jax.lax.scan(body, x, params["decoder"])
    if unembed_last_only:
        x = x[:, -1:, :]
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params["embed"], x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_encdec_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    kvh, hs = cfg.kv_heads, cfg.head_size
    dt = cfg.activation_dtype()
    Ld = cfg.dec_layers
    return {
        "k": jnp.zeros((Ld, batch, max_seq, kvh, hs), dtype=dt),
        "v": jnp.zeros((Ld, batch, max_seq, kvh, hs), dtype=dt),
        # Cross K/V: computed once at prefill from the encoder output.
        "xk": jnp.zeros((Ld, batch, cfg.num_frames, kvh, hs), dtype=dt),
        "xv": jnp.zeros((Ld, batch, cfg.num_frames, kvh, hs), dtype=dt),
    }


def encdec_decode(
    cfg: ModelConfig,
    params: dict,
    tokens: jnp.ndarray,     # (b, 1)
    positions: jnp.ndarray,  # (b,)
    cache: dict,
) -> tuple[jnp.ndarray, dict]:
    x = embed_tokens(cfg, params["embed"], tokens)
    pos_table = params["dec_pos"]
    pos_emb = jnp.take(
        pos_table, jnp.mod(positions, pos_table.shape[0]), axis=0
    ).astype(x.dtype)
    x = x + pos_emb[:, None, :]

    def dec_layer(p_l, k_l, v_l, xk_l, xv_l, x):
        h = apply_norm(cfg, p_l["ln1"], x)
        h, k_l, v_l = decode_attention_block(
            cfg, p_l["self_attn"], h, positions, k_l, v_l
        )
        x = x + h
        # Cross-attention against the precomputed cross K/V.
        hq = apply_norm(cfg, p_l["ln_x"], x)
        dtype = hq.dtype
        q = jnp.einsum("bsd,dhk->bshk", hq, p_l["cross_attn"]["wq"].astype(dtype))
        if "bq" in p_l["cross_attn"]:
            q = q + p_l["cross_attn"]["bq"].astype(dtype)
        mask = jnp.ones((1, xk_l.shape[1]), dtype=bool)
        o = mha(cfg, q, xk_l, xv_l, mask)
        x = x + _out(cfg, p_l["cross_attn"], o)
        m = apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))
        return x + m, k_l, v_l

    if not cfg.scan_layers:
        ks_l, vs_l = [], []
        for i in range(cfg.dec_layers):
            x, k_l, v_l = dec_layer(
                _layer_slice(params["decoder"], i),
                cache["k"][i], cache["v"][i], cache["xk"][i], cache["xv"][i],
                x,
            )
            ks_l.append(k_l)
            vs_l.append(v_l)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    else:
        def body(x, layer):
            p_l, k_l, v_l, xk_l, xv_l = layer
            x, k_l, v_l = dec_layer(p_l, k_l, v_l, xk_l, xv_l, x)
            return x, (k_l, v_l)

        x, (ks, vs) = jax.lax.scan(
            body, x,
            (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        )
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
