#!/usr/bin/env bash
# One-command gate: tier-1 tests + the quick scheduler benchmark (which
# includes the paper-fb@quick scenario smoke sweep: all three schedulers
# on one reduced-scale FB trace) + the perf-trajectory gate (appends
# BENCH_sched.json to BENCH_history.jsonl and fails on a >25% hfsp
# wall-clock regression OR a >10% per-scenario mean-sojourn regression —
# policy-level quality, not just speed — vs the previous entry).
#
#   scripts/check.sh            # tests + quick bench + trajectory gate
#   scripts/check.sh --no-bench # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
  echo
  echo "== quick scheduler benchmark =="
  python -m benchmarks.run --quick --json BENCH_sched.json
  echo
  echo "== perf trajectory gate =="
  python scripts/bench_gate.py --json BENCH_sched.json \
    --history BENCH_history.jsonl --threshold 0.25
fi
