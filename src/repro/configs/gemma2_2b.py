"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
— local+global alternating attention, logit softcaps, sandwich norms
[arXiv:2408.00118; hf]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    act="gelu_glu",
    norm="rmsnorm",
    post_block_norm=True,        # gemma2 sandwich norms
    sliding_window=4096,
    local_global_period=2,       # local, global, local, global, ...
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=1.0 / 16.0,      # gemma2 scales by 1/sqrt(256)
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = reduced(CONFIG, head_dim=16, local_global_period=2)
