"""Scheduler framework.

A scheduler is *pure decision logic*: it is driven by events
(`on_job_arrival`, `on_task_complete`, ...) and, when asked, emits a list of
:class:`Action` that an executor applies to the physical cluster.  The same
scheduler object runs unmodified under

* :mod:`repro.core.simulator` — the discrete-event simulator (the paper's
  Mumak analogue), and
* :mod:`repro.runtime`       — the JAX gang-scheduling runtime (the paper's
  Amazon-cluster analogue).

The executor exposes the physical state through the read-only
:class:`ClusterView` protocol; schedulers keep their own per-job bookkeeping
in :class:`~repro.core.types.JobState`.

Every helper here is written to be cheap per scheduling pass: O(free slots
+ live jobs + emitted actions), never O(total tasks) — schedulers run on
every simulator event.

Incremental run-state engine
----------------------------
The base scheduler maintains live indexes of the cluster's RUNNING tasks —
``_slot_of`` (task key -> slot), ``_run_by_job`` ((job, phase) -> attempts)
and ``_run_by_machine`` ((machine, phase) -> attempts) — updated in O(1)
per event instead of being rebuilt from ``view.occupied_slots`` on every
scheduling pass.  Executors MUST report every applied action through the
``on_task_started`` / ``on_task_resumed`` / ``on_task_suspended`` /
``on_task_killed`` hooks (completions already flow through
``on_task_complete``).  Both bundled executors do.  The hooks are a hard
requirement for correctness: the cheap per-pass fallback
(`_maybe_resync_indexes`) only catches drift that changes the running-task
COUNT, so an executor that skips the hooks but happens to keep counts
balanced (e.g. applying a Suspend + Resume pair) runs on stale indexes
undetected.  Validate new executors with
``SchedulerConfig.paranoid_indexes``, which cross-checks content and order
every pass.

Index invariants (checked every pass under
``SchedulerConfig.paranoid_indexes``):

* the indexes contain exactly the RUNNING tasks, keyed consistently with
  the executor's occupied-slot map;
* within one (machine, phase) or (job, phase) bucket, insertion order
  equals the executor's slot-occupancy insertion order — preemption
  victim selection is order-sensitive, so this keeps incremental and
  rebuild-from-scratch schedules bit-identical;
* indexes never change during a pass (the executor applies actions only
  after ``schedule()`` returns), so a pass sees a consistent snapshot.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Iterable, Protocol

from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    SchedulerStats,
    SlotKey,
    TaskAttempt,
    TaskState,
)


# ---------------------------------------------------------------------------
# Executor-side view & actions
# ---------------------------------------------------------------------------
class ClusterView(Protocol):
    """Read-only physical cluster state, implemented by each executor."""

    spec: ClusterSpec

    def free_slots(self, phase: Phase) -> list[SlotKey]: ...
    def slot_occupant(self, slot: SlotKey) -> TaskAttempt | None: ...
    def occupied_slots(self, phase: Phase) -> dict[SlotKey, TaskAttempt]: ...
    def machine_suspended_count(self, machine: int) -> int: ...
    def machine_suspended_bytes(self, machine: int) -> int: ...
    def total_suspended_bytes(self) -> int: ...


@dataclass
class Action:
    pass


@dataclass
class Start(Action):
    attempt: TaskAttempt
    slot: SlotKey
    local: bool = True


@dataclass
class Resume(Action):
    attempt: TaskAttempt
    slot: SlotKey


@dataclass
class Suspend(Action):
    attempt: TaskAttempt


@dataclass
class Kill(Action):
    attempt: TaskAttempt


# ---------------------------------------------------------------------------
# Base scheduler
# ---------------------------------------------------------------------------
@dataclass
class SchedulerConfig:
    # Delay scheduling (Sect. 3.1 "Data locality"): how many scheduling
    # opportunities a job may skip waiting for a data-local MAP slot.
    locality_max_skips: int = 3
    locality_enabled: bool = True
    # Debug mode: rebuild the run-state indexes from the view on every pass
    # and assert they match the incrementally-maintained ones.  Slow; used
    # by the equivalence tests.
    paranoid_indexes: bool = False


class Scheduler(abc.ABC):
    """Common machinery: job registry, locality-aware slot matching."""

    name = "base"

    def __init__(self, cluster: ClusterSpec, config: SchedulerConfig | None = None):
        self.cluster = cluster
        self.config = config or SchedulerConfig()
        self.jobs: dict[int, JobState] = {}
        self.stats = SchedulerStats()
        self._skip_counts: dict[int, int] = {}
        self._skip_marked: dict[int, int] = {}  # job -> pass seq of last skip
        self._pass_seq = 0
        # Live-job index (jobs with completion_time None), kept incrementally.
        self._live: dict[int, JobState] = {}
        # Tasks already given an action in the *current* pass (the executor
        # has not applied the actions yet, so JobState still shows them as
        # PENDING/SUSPENDED — helpers must not hand them out twice).
        self._claimed: set[tuple] = set()
        # Per-(job, phase) count of claims that targeted PENDING tasks,
        # kept alongside _claimed so _unclaimed_pending is O(1) instead of
        # O(#claimed) per queried job.
        self._claimed_pending: dict[tuple[int, str], int] = {}
        # -- incremental run-state engine (see module docstring) ------------
        # Live views of RUNNING tasks, updated in O(1) by the executor
        # hooks below; read by preemption logic instead of rebuilding from
        # view.occupied_slots() every pass.
        self._slot_of: dict[tuple, SlotKey] = {}
        self._run_by_job: dict[tuple[int, str], dict[tuple, TaskAttempt]] = {}
        self._run_by_machine: dict[tuple[int, str], dict[tuple, TaskAttempt]] = {}
        self._n_running_idx: dict[str, int] = {
            Phase.MAP.value: 0, Phase.REDUCE.value: 0,
        }
        # Jobs with at least one RUNNING task, per phase — lets preemption
        # victim collection iterate O(running jobs) instead of O(live jobs).
        self._jobs_running: dict[str, set[int]] = {
            Phase.MAP.value: set(), Phase.REDUCE.value: set(),
        }

    def _begin_pass(self) -> None:
        self._claimed.clear()
        self._claimed_pending.clear()
        self._pass_seq += 1

    def _claim(self, att: TaskAttempt) -> None:
        """Mark a task as acted on this pass.  All claims must go through
        here so the per-(job, phase) pending-claim counters stay exact."""
        key = att.spec.key
        self._claimed.add(key)
        if att.state is TaskState.PENDING:
            jk = (key[0], key[1])
            self._claimed_pending[jk] = self._claimed_pending.get(jk, 0) + 1

    # -- events (executor -> scheduler) -------------------------------------
    def on_job_arrival(self, spec: JobSpec, now: float) -> JobState:
        js = JobState(spec=spec)
        self.jobs[spec.job_id] = js
        self._live[spec.job_id] = js
        return js

    def on_task_complete(self, job_id: int, key: tuple, now: float) -> None:
        self._index_remove(key)

    def on_task_progress(
        self, job_id: int, key: tuple, fraction: float, elapsed: float, now: float
    ) -> None:
        pass

    def on_job_complete(self, job_id: int, now: float) -> None:
        self._live.pop(job_id, None)
        # Prune the (empty-by-now) per-job run buckets.
        self._run_by_job.pop((job_id, Phase.MAP.value), None)
        self._run_by_job.pop((job_id, Phase.REDUCE.value), None)

    def on_tick(self, now: float) -> None:
        """Periodic heartbeat (executors call this every few sim-seconds)."""

    # -- run-state engine hooks (executor -> scheduler) ----------------------
    # Executors call these right after physically applying each action so
    # the indexes mirror the cluster without per-pass rebuilds.
    def on_task_started(self, att: TaskAttempt, slot: SlotKey) -> None:
        self._index_add(att, slot)

    def on_task_resumed(self, att: TaskAttempt, slot: SlotKey) -> None:
        self._index_add(att, slot)

    def on_task_suspended(self, att: TaskAttempt) -> None:
        self._index_remove(att.spec.key)

    def on_task_killed(self, att: TaskAttempt) -> None:
        self._index_remove(att.spec.key)

    def _index_add(self, att: TaskAttempt, slot: SlotKey) -> None:
        key = att.spec.key
        pv = slot.phase.value
        self._slot_of[key] = slot
        jk = (att.spec.job_id, pv)
        bucket = self._run_by_job.get(jk)
        if bucket is None:
            bucket = self._run_by_job[jk] = {}
        if not bucket:
            self._jobs_running[pv].add(att.spec.job_id)
        bucket[key] = att
        mk = (slot.machine, pv)
        bucket = self._run_by_machine.get(mk)
        if bucket is None:
            bucket = self._run_by_machine[mk] = {}
        bucket[key] = att
        self._n_running_idx[pv] += 1

    def _index_remove(self, key: tuple) -> None:
        slot = self._slot_of.pop(key, None)
        if slot is None:
            return
        pv = slot.phase.value
        bucket = self._run_by_job[(key[0], pv)]
        bucket.pop(key, None)
        if not bucket:
            self._jobs_running[pv].discard(key[0])
        self._run_by_machine[(slot.machine, pv)].pop(key, None)
        self._n_running_idx[pv] -= 1

    def _maybe_resync_indexes(self, view: ClusterView, phase: Phase) -> None:
        """Fallback for executors that do not call the run-state hooks:
        when the indexed running count disagrees with the view, rebuild
        this phase's indexes from scratch (the legacy per-pass path)."""
        occ = view.occupied_slots(phase)
        if self._n_running_idx[phase.value] == len(occ):
            return
        pv = phase.value
        for key in [k for k, s in self._slot_of.items() if s.phase is phase]:
            del self._slot_of[key]
        for mk in [k for k in self._run_by_machine if k[1] == pv]:
            del self._run_by_machine[mk]
        for jk in [k for k in self._run_by_job if k[1] == pv]:
            del self._run_by_job[jk]
        self._n_running_idx[pv] = 0
        self._jobs_running[pv].clear()
        for slot, att in occ.items():
            self._index_add(att, slot)

    def _paranoid_check(self, view: ClusterView, phase: Phase) -> None:
        """Rebuild reference indexes from the view and assert the
        incremental ones match — content AND per-bucket order (preemption
        victim selection is order-sensitive)."""
        pv = phase.value
        ref_slot_of: dict[tuple, SlotKey] = {}
        ref_by_machine: dict[int, list[tuple]] = {}
        ref_by_job: dict[int, list[tuple]] = {}
        for slot, att in view.occupied_slots(phase).items():
            ref_slot_of[att.spec.key] = slot
            ref_by_machine.setdefault(slot.machine, []).append(att.spec.key)
            ref_by_job.setdefault(att.spec.job_id, []).append(att.spec.key)
        got_slot_of = {k: s for k, s in self._slot_of.items() if s.phase is phase}
        assert got_slot_of == ref_slot_of, (
            f"slot_of mismatch ({phase}): {got_slot_of} != {ref_slot_of}"
        )
        got_by_machine = {
            mk[0]: list(bucket)
            for mk, bucket in self._run_by_machine.items()
            if mk[1] == pv and bucket
        }
        assert got_by_machine == ref_by_machine, (
            f"run_by_machine mismatch ({phase})"
        )
        got_by_job = {
            jk[0]: list(bucket)
            for jk, bucket in self._run_by_job.items()
            if jk[1] == pv and bucket
        }
        assert got_by_job == ref_by_job, f"run_by_job mismatch ({phase})"
        assert self._n_running_idx[pv] == len(ref_slot_of)
        assert self._jobs_running[pv] == set(ref_by_job), (
            f"jobs_running mismatch ({phase})"
        )

    # -- decisions -----------------------------------------------------------
    @abc.abstractmethod
    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        """Return the actions to apply given current physical state."""

    # -- shared helpers --------------------------------------------------------
    def live_jobs(self, phase: Phase) -> list[JobState]:
        out = []
        for js in self._live.values():
            if phase is Phase.REDUCE and not js.reduce_unlocked():
                continue
            if js.n_unfinished(phase):
                out.append(js)
        return out

    def _demand(self, js: JobState, phase: Phase) -> int:
        """Slots the job could use *right now* in this phase."""
        return js.n_pending(phase) + js.n_suspended(phase) + js.n_running(phase)

    def _unclaimed_pending(self, js: JobState, phase: Phase) -> int:
        """Pending tasks not yet claimed this pass.  O(1): `_claim` counts
        claims of PENDING tasks per (job, phase) as they happen (task
        states cannot change mid-pass, so the counter is exact)."""
        if not self._claimed_pending:
            return js.n_pending(phase)
        return js.n_pending(phase) - self._claimed_pending.get(
            (js.spec.job_id, phase.value), 0
        )

    # .. locality-aware assignment of pending tasks to free slots ...........
    def _assign_pending(
        self,
        js: JobState,
        phase: Phase,
        free: list[SlotKey],
        budget: int,
        now: float,
        only_keys: Iterable[tuple] | None = None,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Assign up to ``budget`` pending tasks of ``js`` to ``free`` slots.

        MAP tasks use delay scheduling: prefer slots on machines that hold
        the task's input; a job may skip ``locality_max_skips`` scheduling
        opportunities before accepting a non-local slot.  Returns the
        actions plus the still-free slots.  ``only_keys`` restricts the
        candidate tasks (used by the HFSP Training module to dispatch just
        the sample set).
        """
        actions: list[Action] = []
        if budget <= 0 or not free:
            return actions, free
        jid = js.spec.job_id
        restrict: set[tuple] | None = set(only_keys) if only_keys is not None else None

        def eligible(att: TaskAttempt) -> bool:
            k = att.spec.key
            if att.state is not TaskState.PENDING or k in self._claimed:
                return False
            return restrict is None or k in restrict

        if phase is Phase.MAP and self.config.locality_enabled:
            rest_slots: list[SlotKey] = []
            for slot in free:
                if budget <= 0:
                    rest_slots.append(slot)
                    continue
                att = next(
                    (a for a in js.local_pending(slot.machine) if eligible(a)),
                    None,
                )
                if att is not None:
                    self._claim(att)
                    actions.append(Start(att, slot, local=True))
                    js.locality_hits += 1
                    budget -= 1
                    self._skip_counts[jid] = 0
                else:
                    rest_slots.append(slot)
            free = rest_slots
            if budget > 0 and free:
                # Bounded scan: at most ``budget`` tasks can be assigned
                # from either group, so stop once both are full — O(budget)
                # per pass instead of O(pending) for wide jobs.
                no_host: list[TaskAttempt] = []
                remaining: list[TaskAttempt] = []
                for a in js.iter_pending(phase):
                    if not eligible(a):
                        continue
                    if a.spec.input_hosts:
                        if len(remaining) < budget:
                            remaining.append(a)
                    elif len(no_host) < budget:
                        no_host.append(a)
                    if len(remaining) >= budget and len(no_host) >= budget:
                        break
                # Tasks with no locality information cannot benefit from
                # waiting — assign them immediately (ML step quanta, or
                # jobs whose replicas are all dead).
                free = list(free)
                for att in no_host:
                    if budget <= 0 or not free:
                        break
                    slot = free.pop(0)
                    self._claim(att)
                    actions.append(Start(att, slot, local=True))
                    budget -= 1
                if remaining and budget > 0 and free:
                    skips = self._skip_counts.get(jid, 0)
                    if skips < self.config.locality_max_skips:
                        # Delay: skip this opportunity hoping for a local
                        # slot.  Counted at most once per scheduling pass
                        # (the Training module and the job scheduler may
                        # both consider the same job in one pass).
                        if self._skip_marked.get(jid) != self._pass_seq:
                            self._skip_counts[jid] = skips + 1
                            self._skip_marked[jid] = self._pass_seq
                            self.stats.delay_sched_waits += 1
                    else:
                        while remaining and budget > 0 and free:
                            att = remaining.pop(0)
                            slot = free.pop(0)
                            self._claim(att)
                            actions.append(Start(att, slot, local=False))
                            js.locality_misses += 1
                            budget -= 1
                        self._skip_counts[jid] = 0
        else:
            # REDUCE tasks (or locality disabled): any slot will do.
            free = list(free)
            for att in js.iter_pending(phase):
                if budget <= 0 or not free:
                    break
                if not eligible(att):
                    continue
                slot = free.pop(0)
                self._claim(att)
                actions.append(Start(att, slot, local=True))
                budget -= 1
        return actions, free

    def _resume_suspended(
        self,
        js: JobState,
        phase: Phase,
        free: list[SlotKey],
        budget: int,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Resume suspended tasks on their *own* machines (Sect. 3.3 —
        suspended state is materialized locally and must resume in place)."""
        actions: list[Action] = []
        if budget <= 0:
            return actions, free
        free_by_machine: dict[int, list[SlotKey]] = {}
        for s in free:
            free_by_machine.setdefault(s.machine, []).append(s)
        for att in js.suspended(phase):
            if budget <= 0:
                break
            if att.spec.key in self._claimed:
                continue
            slots = free_by_machine.get(att.machine if att.machine is not None else -1)
            if slots:
                slot = slots.pop(0)
                self._claim(att)
                actions.append(Resume(att, slot))
                budget -= 1
        used = {a.slot for a in actions if isinstance(a, Resume)}
        return actions, [s for s in free if s not in used]


def job_sort_key_fifo(js: JobState) -> tuple:
    return (-js.spec.weight, js.spec.arrival_time, js.spec.job_id)
