"""Benchmark driver: one benchmark per paper table/figure + the
beyond-paper ML-workload, kernel/roofline, and scheduler-overhead benches.
Emits CSV blocks.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig7] [--fast]
  PYTHONPATH=src python -m benchmarks.run --quick --json BENCH_sched.json

``--quick`` runs only the scheduler wall-clock smoke bench (one FB run per
scheduler) — the one-command perf gate used by scripts/check.sh.  With
``--json PATH`` the per-scheduler wall-clock (and result fingerprints) are
also written to ``PATH`` so successive PRs accumulate a perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback

from benchmarks import (
    bench_cluster_size,
    bench_estimation_error,
    bench_kernels,
    bench_locality,
    bench_ml_workload,
    bench_per_job_delta,
    bench_preemption,
    bench_roofline,
    bench_sched_overhead,
    bench_sojourn,
)

BENCHES = {
    "fig3": bench_sojourn.main,
    "fig4": bench_per_job_delta.main,
    "fig5": bench_cluster_size.main,
    "fig6": bench_estimation_error.main,
    "fig7": bench_preemption.main,
    "locality": bench_locality.main,
    "ml": bench_ml_workload.main,
    "kernels": bench_kernels.main,
    "roofline": bench_roofline.main,
    "sched_overhead": bench_sched_overhead.main,
}

FAST_SKIP = {"fig5", "fig6", "ml", "sched_overhead"}  # the long ones

QUICK_SCHEDULERS = ("fifo", "fair", "hfsp")


def quick_sched_wall(json_path: str | None = None, seed: int = 0) -> dict:
    """Wall-clock one FB run per scheduler; optionally dump JSON.

    The JSON records, per scheduler: wall-clock seconds, mean sojourn, and
    a completion fingerprint (so a perf regression AND a behaviour change
    are both visible in the trajectory file), plus the water-fill kernel
    microbenchmark at the 5000-job cell (numpy loop vs jitted jax backend,
    see benchmarks/bench_sched_overhead.py), plus the reduced-scale
    ``paper-fb`` scenario smoke sweep (all three schedulers on one small
    FB trace) whose per-scenario mean sojourns let scripts/bench_gate.py
    track *policy-level* regressions across PRs, not just wall-clock.
    """
    from benchmarks.common import CsvOut, run_fb

    out = CsvOut("sched_wall", ["scheduler", "wall_s", "mean_sojourn_s",
                                "completion_fingerprint"])
    record: dict = {
        "bench": "sched_wall",
        "seed": seed,
        "python": platform.python_version(),
        "schedulers": {},
    }
    from repro.scenarios.report import completion_fingerprint

    for name in QUICK_SCHEDULERS:
        res, _, _, wall = run_fb(name, seed=seed)
        fingerprint = completion_fingerprint(res)
        out.add(name, round(wall, 3), round(res.mean_sojourn(), 2), fingerprint)
        record["schedulers"][name] = {
            "wall_s": round(wall, 3),
            "mean_sojourn_s": round(res.mean_sojourn(), 2),
            "completion_fingerprint": fingerprint,
        }
        print(f"# {name}: {wall:.2f}s wall", flush=True)
    out.emit()
    cell = bench_sched_overhead.waterfill_cell(5000, seed=seed)
    record["waterfill_5000"] = {
        k: (round(v, 3) if isinstance(v, float) else v)
        for k, v in cell.items()
    }
    speed = cell["waterfill_speedup"]
    print(
        "# waterfill@5000: "
        + (f"{speed:.1f}x jax speedup" if speed is not None
           else "jax unavailable"),
        flush=True,
    )
    # Demand-indexed decision latency at the trace-scale sparse-demand
    # cell (5000 jobs x 1000 machines): the PR-4 tentpole gate cell —
    # bench_gate.py fails check.sh on a >25% regression of
    # decision_latency_ms, same policy as the hfsp wall gate.
    sparse = bench_sched_overhead.run_sparse_demand(cells=((5000, 1000),))[0]
    record["sched_sparse_5000x1000"] = {
        k: (round(v, 4) if isinstance(v, float) else v)
        for k, v in sparse.items()
    }
    # Per-discipline decision latency at the same cell (Discipline API
    # sanity bound: bench_gate.py fails any recorded discipline >2x the
    # hfsp latency above).  hfsp itself is covered by the sparse block,
    # so only the new registry disciplines re-measure here.
    disc_rows = bench_sched_overhead.run_discipline_latency(
        cells=((5000, 1000),), disciplines=("srpt", "las", "psbs"),
    )
    record["sched_disciplines_5000x1000"] = {
        r["discipline"]: {
            "decision_latency_ms": round(r["decision_latency_ms"], 4),
            "p99_pass_ms": round(r["p99_pass_ms"], 4),
        }
        for r in disc_rows
    }
    # Epsilon-window coalescing sweep: pass-count delta at equal event
    # progress (check.sh prints the delta from this block).
    eps_rows = bench_sched_overhead.run_eps_sweep(seed=seed)
    record["eps_sweep"] = {
        str(r["eps"]): {
            "passes": r["passes"],
            "events": r["events"],
            "passes_per_event": round(r["passes_per_event"], 4),
        }
        for r in eps_rows
    }
    # Compare events-normalized pass rates: a row that hit the sweep's
    # wall-clock safety cap processed fewer events, so raw pass counts
    # across rows would not be comparable.
    base = eps_rows[0]
    for r in eps_rows[1:]:
        ratio = r["passes_per_event"] / max(base["passes_per_event"], 1e-12)
        extra = (
            "" if r["events"] == base["events"]
            else f" [events {r['events']} vs {base['events']}]"
        )
        print(
            f"# eps sweep: eps={r['eps']} cuts passes/event "
            f"{base['passes_per_event']:.4f} -> {r['passes_per_event']:.4f} "
            f"({ratio:.1%} of eps=0){extra}",
            flush=True,
        )
    record["scenarios"] = scenario_smoke()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return record


def scenario_smoke() -> dict:
    """The fast scenario smoke sweep: ``paper-fb`` at reduced scale, all
    three schedulers on one trace.  Returns per-scenario mean sojourn +
    completion fingerprint, keyed ``paper-fb@quick/<policy>`` — the
    policy-level trajectory scripts/bench_gate.py gates on.
    """
    from repro.scenarios import get_preset, quick_sweep, run_sweep

    sweep = quick_sweep(get_preset("paper-fb"))
    results = run_sweep(sweep)
    out: dict = {}
    means: dict = {}
    for cid, rep in sorted(results.items()):
        policy = cid.split("=", 1)[1]
        means[policy] = rep["mean_sojourn_s"]
        out[f"{sweep.name}/{policy}"] = {
            "mean_sojourn_s": round(rep["mean_sojourn_s"], 2),
            "completion_fingerprint": rep["completion_fingerprint"],
            # Tail/fairness trajectory (bench_gate.py gates these the
            # same way as the mean: only when the baseline carries them).
            "p99_sojourn_s": round(rep["tails"]["sojourn"]["p99"], 2),
            "p999_sojourn_s": round(rep["tails"]["sojourn"]["p999"], 2),
            "jain_slowdown": round(rep["fairness"]["jain_slowdown"], 4),
        }
    hfsp_lowest = means["hfsp"] < min(means["fair"], means["fifo"])
    print(
        "# scenario smoke (paper-fb@quick): "
        + " ".join(f"{p}={means[p]:.0f}s" for p in ("fifo", "fair", "hfsp"))
        + f"; hfsp strictly lowest: {hfsp_lowest}",
        flush=True,
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="scheduler wall-clock smoke bench only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --quick: dump per-scheduler wall-clock JSON")
    args = ap.parse_args()

    if args.quick:
        quick_sched_wall(json_path=args.json)
        return

    names = list(BENCHES)
    if args.only:
        names = [n for n in args.only.split(",") if n in BENCHES]
    elif args.fast:
        names = [n for n in names if n not in FAST_SKIP]

    failed = []
    for name in names:
        print(f"\n==== {name} " + "=" * (60 - len(name)), flush=True)
        t0 = time.time()
        try:
            BENCHES[name]()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
