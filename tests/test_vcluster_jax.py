"""Unit tests for the jax virtual-cluster backend (repro.core.vcluster_jax)
and the batched what-if projection API, plus the determinism guard for
schedule_order under lazy aging (both backends).
"""

import math

import numpy as np
import pytest

from repro.core import ClusterSpec, HFSPConfig, HFSPScheduler, Phase, Simulator
from repro.core.types import JobSpec, TaskSpec
from repro.core.vcluster import (
    VirtualCluster,
    _project_array,
    _water_fill,
    resolve_backend,
)
from repro.workload import fb_cluster, fb_dataset

jax = pytest.importorskip("jax")

from repro.core import vcluster_jax  # noqa: E402


# ---------------------------------------------------------------------------
# water_fill: jax closed form vs numpy redistribute loop
# ---------------------------------------------------------------------------
WATER_FILL_CASES = [
    # (caps, weights, slots) — degenerate corners first.
    ([], [], 10.0),                                   # empty cluster
    ([7.0], [1.0], 10.0),                             # single job, capped
    ([7.0], [1.0], 3.0),                              # single job, limited
    ([3.0, 5.0], [0.0, 0.0], 8.0),                    # all weights zero
    ([3.0, 5.0, 2.0], [0.0, 1.0, 2.0], 8.0),          # mixed zero weight
    ([1.0, 2.0, 3.0], [1.0, 1.0, 1.0], 100.0),        # caps sum below slots
    ([0.0, 0.0], [1.0, 1.0], 5.0),                    # zero caps
    ([10.0, 10.0, 10.0, 10.0], [1.0, 1.0, 1.0, 1.0], 8.0),  # even split
    ([1.0, 100.0], [1.0, 1.0], 10.0),                 # one caps out, redistribute
    ([4.0, 4.0], [1.0, 3.0], 6.0),                    # weighted shares
    ([5.0, 5.0], [1.0, 1.0], 0.0),                    # no slots
]


@pytest.mark.parametrize("caps,ws,slots", WATER_FILL_CASES)
def test_water_fill_matches_numpy_reference(caps, ws, slots):
    caps = np.asarray(caps, dtype=np.float64)
    ws = np.asarray(ws, dtype=np.float64)
    ref = _water_fill(caps, ws, slots)
    out = vcluster_jax.water_fill(caps, ws, slots)
    np.testing.assert_allclose(out, ref, rtol=1e-9, atol=1e-9)


def test_water_fill_randomized_equivalence():
    rng = np.random.default_rng(7)
    for _ in range(100):
        n = int(rng.integers(0, 50))
        caps = rng.integers(0, 40, size=n).astype(np.float64)
        ws = np.where(rng.random(n) < 0.2, 0.0, rng.uniform(0.1, 5.0, n))
        slots = float(rng.integers(0, 120))
        ref = _water_fill(caps, ws, slots)
        out = vcluster_jax.water_fill(caps, ws, slots)
        np.testing.assert_allclose(out, ref, rtol=1e-9, atol=1e-8)
        # Feasibility invariants hold exactly in both.
        assert (out <= caps + 1e-9).all()
        assert out.sum() <= slots + 1e-6


# ---------------------------------------------------------------------------
# PS finish-time projection: jax while_loop vs numpy event loop
# ---------------------------------------------------------------------------
def test_projection_matches_numpy_reference():
    rng = np.random.default_rng(11)
    for _ in range(60):
        n = int(rng.integers(1, 30))
        rem = np.where(rng.random(n) < 0.15, np.inf, rng.uniform(0.0, 400.0, n))
        caps = rng.integers(0, 15, size=n).astype(np.float64)
        ws = rng.uniform(0.5, 2.0, n)
        slots = float(rng.integers(1, 30))
        now = float(rng.uniform(0.0, 1e4))
        ref = _project_array(rem.copy(), caps, ws, slots, now)
        out = vcluster_jax.project_finish_times(rem, caps, ws, slots, now)
        finite = np.isfinite(ref)
        assert (finite == np.isfinite(out)).all()
        np.testing.assert_allclose(out[finite], ref[finite], rtol=1e-9, atol=1e-9)


def test_projection_batch_rows_match_single_calls():
    """A (B, N) batch must equal B independent single projections, and
    per-row slots/now must be honored."""
    rng = np.random.default_rng(3)
    b, n = 5, 12
    rem_b = rng.uniform(1.0, 300.0, (b, n))
    caps_b = rng.integers(1, 9, (b, n)).astype(np.float64)
    ws_b = rng.uniform(0.5, 2.0, (b, n))
    slots = np.array([4.0, 8.0, 16.0, 5.0, 7.0])
    now = np.array([0.0, 10.0, 0.0, 3.5, 100.0])
    batch = vcluster_jax.project_finish_times_batch(rem_b, caps_b, ws_b, slots, now)
    for i in range(b):
        single = vcluster_jax.project_finish_times(
            rem_b[i], caps_b[i], ws_b[i], float(slots[i]), float(now[i])
        )
        np.testing.assert_array_equal(batch[i], single)


def test_padding_bucket_is_bitwise_neutral():
    """The padded-buffer contract: the same live prefix embedded in a
    wider batch row (bigger padded bucket) produces bit-identical finish
    times — masked padding adds exact float zeros only."""
    rng = np.random.default_rng(5)
    n = 6
    rem = rng.uniform(1.0, 100.0, n)
    caps = rng.integers(1, 6, n).astype(np.float64)
    ws = np.ones(n)
    single = vcluster_jax.project_finish_times(rem, caps, ws, 5.0, 1.0)
    wide = np.zeros((2, 40))
    wide_caps = np.zeros((2, 40))
    wide_ws = np.zeros((2, 40))
    wide[:, :n] = rem
    wide_caps[:, :n] = caps
    wide_ws[:, :n] = ws
    batch = vcluster_jax.project_finish_times_batch(
        wide, wide_caps, wide_ws, 5.0, 1.0, n_valid=np.array([n, n])
    )
    np.testing.assert_array_equal(batch[0, :n], single)
    np.testing.assert_array_equal(batch[1, :n], single)


def test_jit_cache_amortized_within_bucket():
    """Job counts inside one power-of-two bucket must reuse the compiled
    executable (the recompile-amortization contract of docs/vcluster.md)."""
    fill = vcluster_jax._jitted()["fill"]
    if not hasattr(fill, "_cache_size"):
        pytest.skip("jax version without jit cache introspection")
    for n in (17, 21, 25, 31):  # all pad to the 32 bucket
        vcluster_jax.water_fill(np.ones(n), np.ones(n), 5.0)
    before = fill._cache_size()
    for n in (18, 23, 30, 32):  # still the 32 bucket
        vcluster_jax.water_fill(np.ones(n), np.ones(n), 5.0)
    assert fill._cache_size() == before


# ---------------------------------------------------------------------------
# VirtualCluster integration: backend selection + batched what-ifs
# ---------------------------------------------------------------------------
def test_resolve_backend_env(monkeypatch):
    monkeypatch.delenv("REPRO_VC_BACKEND", raising=False)
    assert resolve_backend(None) == "auto"
    monkeypatch.setenv("REPRO_VC_BACKEND", "jax")
    assert resolve_backend(None) == "jax"
    assert resolve_backend("numpy") == "numpy"  # explicit arg wins
    monkeypatch.setenv("REPRO_VC_BACKEND", "numpy")
    assert resolve_backend(None) == "numpy"
    with pytest.raises(ValueError):
        resolve_backend("tpu-emoji")


def test_auto_backend_latches_at_threshold():
    """backend="auto" starts on the numpy kernels and latches to jax when
    the live-job count reaches the threshold; removals never latch back
    (recompile thrash protection)."""
    vc = VirtualCluster(Phase.MAP, slots=10, backend="auto", auto_threshold=4)
    for j in range(3):
        vc.add_job(j, 50.0, 2)
    assert vc.backend == "numpy"
    vc.add_job(3, 50.0, 2)
    assert vc.backend == "jax"
    vc.remove_job(0)
    vc.remove_job(1)
    assert vc.backend == "jax"  # latched


def _make_vc(backend, slots=10, jobs=6):
    vc = VirtualCluster(Phase.MAP, slots=slots, backend=backend)
    for j in range(jobs):
        vc.add_job(j, 40.0 + 17.0 * j, 4 + j)
    return vc


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_projected_finish_batch_matches_set_remaining(backend):
    """A what-if override must price exactly like actually applying
    set_remaining to a fresh cluster."""
    vc = _make_vc(backend)
    scenarios = [{}, {2: 10.0}, {0: math.inf}, {4: 1.0, 5: 500.0}]
    outs = vc.projected_finish_batch(scenarios, now=2.0)
    assert outs[0] == vc.projected_finish(2.0)
    for scenario, out in zip(scenarios[1:], outs[1:]):
        ref = _make_vc(backend)
        for j, r in scenario.items():
            ref.set_remaining(j, r)
        assert out == ref.projected_finish(2.0)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_projected_finish_batch_size_mode_matches_set_size(backend):
    """as_sizes=True must price exactly like actually applying set_size
    (remaining AND task_time/virtual-parallelism re-derived) — the
    semantics the estimator's update path uses."""
    vc = _make_vc(backend)
    vc.age(3.0)  # accrue some done so size -> remaining conversion matters
    vc.allocation()
    scenarios = [{2: 15.0}, {0: 400.0}, {4: 9.0, 5: 700.0}]
    outs = vc.projected_finish_batch(scenarios, now=5.0, as_sizes=True)
    for scenario, out in zip(scenarios, outs):
        ref = _make_vc(backend)
        ref.age(3.0)
        ref.allocation()
        for j, size in scenario.items():
            ref.set_size(j, size)
        assert out == ref.projected_finish(5.0)


def test_projected_finish_batch_backends_agree():
    a = _make_vc("numpy").projected_finish_batch([{}, {1: 5.0}, {3: 1000.0}], 0.0)
    b = _make_vc("jax").projected_finish_batch([{}, {1: 5.0}, {3: 1000.0}], 0.0)
    for fa, fb in zip(a, b):
        assert set(fa) == set(fb)
        for j in fa:
            assert fa[j] == pytest.approx(fb[j], rel=1e-9, abs=1e-9)


def test_projected_finish_batch_empty_cases():
    vc = VirtualCluster(Phase.MAP, slots=4, backend="jax")
    assert vc.projected_finish_batch([], 0.0) == []
    assert vc.projected_finish_batch([{}, {9: 3.0}], 0.0) == [{}, {}]


# ---------------------------------------------------------------------------
# Scheduler-level what-if APIs
# ---------------------------------------------------------------------------
def _tiny_job(job_id, arrival, n_map, dur):
    return JobSpec(
        job_id=job_id,
        arrival_time=arrival,
        map_tasks=tuple(
            TaskSpec(job_id, Phase.MAP, i, dur) for i in range(n_map)
        ),
        reduce_tasks=(),
    )


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_whatif_and_rank_stability(backend):
    cluster = ClusterSpec(num_machines=4)
    sch = HFSPScheduler(cluster, HFSPConfig(vc_backend=backend))
    jobs = [_tiny_job(1, 0.0, 60, 30.0), _tiny_job(2, 0.0, 60, 8.0)]
    sim = Simulator(cluster, sch, jobs)
    try:
        sim.run(max_events=30)
    except Exception:
        pass
    now = sch._clock
    live = [j for j in (1, 2) if j in sch.vc[Phase.MAP]]
    assert live, "probe jobs must still be mid-flight at the event budget"
    target = live[0]
    outs = sch.whatif_finish_times(
        Phase.MAP, [{}, {target: 1e-3}, {target: 1e6}], now
    )
    assert len(outs) == 3
    # Near-zero remaining cannot finish later than the huge-size scenario.
    assert outs[1][target] <= outs[2][target]
    ranks = sch.rank_stability(target, Phase.MAP, now)
    assert all(0 <= r < len(sch.vc[Phase.MAP].jobs) for r in ranks)


def test_rank_stability_spans_candidate_estimates():
    """With wildly different sample durations the leave-one-out candidate
    sizes differ, and every candidate must price as a valid position."""
    cluster = ClusterSpec(num_machines=2)
    sch = HFSPScheduler(cluster, HFSPConfig(vc_backend="numpy"))
    jobs = [_tiny_job(1, 0.0, 10, 5.0), _tiny_job(2, 0.0, 10, 5.0)]
    sim = Simulator(cluster, sch, jobs)
    try:
        sim.run(max_events=60)
    except Exception:
        pass
    for jid in (1, 2):
        js = sch.jobs.get(jid)
        if js is None or jid not in sch.vc[Phase.MAP]:
            continue
        sizes = sch.training.candidate_sizes(js, Phase.MAP)
        ranks = sch.rank_stability(jid, Phase.MAP, sch._clock)
        assert len(ranks) == len(sizes)


# ---------------------------------------------------------------------------
# Determinism of schedule_order under lazy aging (regression guard for the
# PR 1 deferred-dt replay): materialization *timing* must be unobservable.
# ---------------------------------------------------------------------------
def _random_ops(rng, n_jobs, n_ops):
    """Mutating op sequence + fixed schedule_order checkpoints."""
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.5:
            ops.append(("age", float(rng.uniform(0.01, 5.0))))
        elif r < 0.75:
            ops.append(
                ("set_remaining", int(rng.integers(0, n_jobs)),
                 float(rng.uniform(0.0, 200.0)))
            )
        elif r < 0.9:
            ops.append(
                ("set_size", int(rng.integers(0, n_jobs)),
                 float(rng.uniform(1.0, 300.0)))
            )
        else:
            ops.append(("order",))  # checkpoint: query schedule_order
    ops.append(("order",))
    return ops


def _execute(ops, backend, query_mask, n_jobs=5, slots=7):
    """Run the op sequence; query_mask[i] inserts *pure* state queries
    after op i (forcing the deferred-aging replay at that point)."""
    vc = VirtualCluster(Phase.MAP, slots=slots, backend=backend)
    for j in range(n_jobs):
        vc.add_job(j, 30.0 * (j + 1), 3 + j)
    now = 0.0
    orders = []
    for i, op in enumerate(ops):
        if op[0] == "age":
            now += op[1]
            vc.age(op[1])
        elif op[0] == "set_remaining":
            vc.set_remaining(op[1], op[2])
        elif op[0] == "set_size":
            vc.set_size(op[1], op[2])
        else:
            orders.append(tuple(vc.schedule_order(now)))
        if query_mask[i]:
            # Pure queries: allowed to flush deferred aging, must change
            # nothing observable downstream.
            vc.remaining(i % n_jobs)
            vc.allocation()
            _ = vc.jobs[i % n_jobs].effective_cap()
    state = {
        j: (vc.remaining(j), vc.jobs[j].done) for j in range(n_jobs) if j in vc
    }
    return orders, state, vc.allocation()


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_schedule_order_deterministic_under_lazy_aging(backend):
    rng = np.random.default_rng(42)
    for trial in range(8):
        ops = _random_ops(rng, n_jobs=5, n_ops=25)
        masks = [
            [False] * len(ops),                     # fully deferred
            [True] * len(ops),                      # eager flush everywhere
            list(rng.random(len(ops)) < 0.4),       # random interleaving
            list(rng.random(len(ops)) < 0.4),
        ]
        results = [_execute(ops, backend, m) for m in masks]
        ref_orders, ref_state, ref_alloc = results[0]
        for orders, state, alloc in results[1:]:
            assert orders == ref_orders, f"trial {trial}: orders diverge"
            assert state == ref_state, f"trial {trial}: aged state diverges"
            assert alloc == ref_alloc, f"trial {trial}: allocation diverges"


def test_schedule_order_backends_agree_on_op_sequences():
    """The same op sequence must yield the same checkpoint orders on both
    backends (vcluster-level conformance, independent of the simulator)."""
    rng = np.random.default_rng(99)
    for trial in range(5):
        ops = _random_ops(rng, n_jobs=5, n_ops=20)
        mask = [False] * len(ops)
        orders_np, _, alloc_np = _execute(ops, "numpy", mask)
        orders_jx, _, alloc_jx = _execute(ops, "jax", mask)
        assert orders_np == orders_jx, f"trial {trial}"
        assert alloc_np == alloc_jx, f"trial {trial}"
