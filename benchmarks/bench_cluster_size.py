"""Fig. 5 — mean sojourn vs cluster size (10..100 machines), FAIR vs HFSP.

Paper claim: when resources are scarce, HFSP's advantage grows — the same
workload needs a smaller cluster for equal sojourn times."""

from __future__ import annotations

from benchmarks.common import CsvOut, run_fb


def main(out=None) -> dict:
    sizes = [10, 20, 30, 50, 70, 100]
    table = CsvOut("fig5_cluster_size", [
        "machines", "scheduler", "mean_sojourn_s", "makespan_s",
    ])
    gains = {}
    for m in sizes:
        means = {}
        for name in ("fair", "hfsp"):
            res, _, _, _ = run_fb(name, machines=m, seed=0)
            means[name] = res.mean_sojourn()
            table.add(m, name, round(means[name], 1), round(res.makespan, 1))
        gains[m] = means["fair"] / means["hfsp"]
    table.emit(out)
    print("# fig5: FAIR/HFSP mean-sojourn ratio by cluster size: "
          + " ".join(f"{m}m={gains[m]:.2f}x" for m in sizes))
    assert gains[min(sizes)] >= gains[max(sizes)] * 0.8, (
        "HFSP advantage should not shrink drastically as resources shrink"
    )
    return {"gains": gains}


if __name__ == "__main__":
    main()
