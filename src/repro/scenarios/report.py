"""Report layer: reduce simulation outcomes to machine-readable JSON.

One report per scenario cell (:func:`scenario_report`) plus cross-cell
reductions (:func:`matrix_report`, :func:`per_job_delta_summary`) for the
policy-comparison matrices of Sect. 4.  All values are plain
JSON-serializable types — ``benchmarks/run.py`` embeds them into
``BENCH_sched.json`` and ``scripts/bench_gate.py`` tracks the recorded
per-scenario mean sojourns across PRs (policy-level regressions, not just
wall-clock).
"""

from __future__ import annotations

from repro.core.simulator import SimResult
from repro.core.metrics import (
    SojournSummary,
    ecdf_quantiles,
    jain_index,
    per_class_sojourns,
    per_job_delta,
    slowdowns,
    tail_quantiles,
)
from repro.scenarios.spec import ScenarioSpec


def completion_fingerprint(res: SimResult) -> int:
    """Order-insensitive hash of the full completion schedule — two runs
    with equal fingerprints produced bit-identical completions."""
    return hash(tuple(sorted(res.completion.items())))


def _summary_dict(s: SojournSummary) -> dict:
    return {
        "mean_s": s.mean, "median_s": s.median, "p95_s": s.p95, "count": s.count,
    }


def scenario_report(
    spec: ScenarioSpec,
    res: SimResult,
    jobs,
    class_of: dict[int, str],
    scheduler,
    wall_s: float,
) -> dict:
    """The canonical per-cell result record."""
    soj = res.sojourn
    size_of = {j.job_id: j.size for j in jobs}
    slow = slowdowns(res, size_of)
    per_class = {
        cls: {
            **_summary_dict(SojournSummary.of(vals)),
            "ecdf": ecdf_quantiles(vals),
        }
        for cls, vals in sorted(per_class_sojourns(res, class_of).items())
    }
    st = scheduler.stats
    # Preemption-hysteresis / what-if diagnostics (engine-family
    # schedulers expose whatif_diagnostics(); fifo/fair have none): how
    # often the discipline's preemption policy priced a batched what-if
    # projection (rank_stability), how often it vetoed, PSBS late-job
    # re-injections — the per-cell observability the ROADMAP's
    # "scenario-level what-if reports" item asked for.
    diag = getattr(scheduler, "whatif_diagnostics", None)
    whatif = diag() if callable(diag) else None
    # Fault-layer block (None for fault-free cells): injector counters
    # plus goodput = useful / (useful + lost) where useful is the total
    # size of completed jobs and lost is the work thrown away on
    # failures, crashes, and losing speculative copies.
    faults = None
    if res.faults is not None:
        useful = sum(size_of[j] for j in res.completion if j in size_of)
        lost = res.faults.get("work_lost_s", 0.0)
        faults = dict(res.faults)
        faults["goodput"] = (
            useful / (useful + lost) if useful + lost > 0 else 1.0
        )
    return {
        "spec": spec.to_dict(),
        "wall_s": round(wall_s, 3),
        "makespan_s": res.makespan,
        "jobs_completed": len(res.completion),
        "jobs_lost": len(jobs) - len(res.completion),
        "mean_sojourn_s": res.mean_sojourn(),
        "sojourn": {
            **_summary_dict(SojournSummary.of(list(soj.values()))),
            "ecdf": ecdf_quantiles(list(soj.values())),
        },
        "per_class": per_class,
        "slowdown": {
            **_summary_dict(SojournSummary.of(list(slow.values()))),
            "ecdf": ecdf_quantiles(list(slow.values())),
        },
        # Extreme tails + Jain's fairness index (ROADMAP "fairness and
        # tails"): p99/p999 of the sojourn and per-job-slowdown
        # distributions, and the fairness index over slowdowns (1.0 =
        # every job slowed equally; 1/n = one job absorbed all the
        # queueing).  These double as the live service's telemetry
        # counters (src/repro/service/telemetry.py).
        "tails": {
            "sojourn": tail_quantiles(list(soj.values())),
            "slowdown": tail_quantiles(list(slow.values())),
        },
        "fairness": {
            "jain_sojourn": jain_index(list(soj.values())),
            "jain_slowdown": jain_index(list(slow.values())),
        },
        "locality_fraction": res.locality_fraction,
        "completion_fingerprint": completion_fingerprint(res),
        # Scheduler-overhead counters: the epsilon-window axis trades
        # pass count (overhead) against sojourn quality; sweeps read the
        # tradeoff per cell from here.
        "events": res.events,
        "scheduler_passes": res.passes,
        "passes_per_event": round(res.passes / res.events, 4) if res.events else 0.0,
        "whatif": whatif,
        "faults": faults,
        "stats": {
            "suspensions": st.suspensions,
            "resumes": st.resumes,
            "kills": st.kills,
            "waits": st.waits,
            "delay_sched_waits": st.delay_sched_waits,
            "training_tasks": st.training_tasks,
            "hysteresis_fallbacks": st.hysteresis_fallbacks,
        },
    }


def per_job_delta_summary(a: SimResult, b: SimResult) -> dict:
    """Cross-policy per-job sojourn deltas (a - b; positive = b better),
    the Fig. 4 dominance summary in JSON form."""
    delta = per_job_delta(a, b)
    if not delta:
        return {"jobs": 0}
    vals = sorted(delta.values())
    return {
        "jobs": len(vals),
        "b_better_or_equal": sum(1 for v in vals if v >= -1.0),
        "max_gain_s": vals[-1],
        "max_loss_s": -vals[0],
        "ecdf": ecdf_quantiles(vals),
    }


def matrix_report(cells: dict[str, dict], expected=None) -> dict:
    """Cross-cell reduction over one sweep's finished cells.

    ``cells`` maps cell_id -> scenario_report dict.  Returns a compact
    comparison: per-cell mean sojourn plus pairwise mean ratios — the
    "HFSP strictly lowest" acceptance check reads this.

    Quarantined cells (the self-healing sweep runner's poison-cell
    records, ``{"quarantined": True, ...}``) carry no metrics: they are
    listed under ``"quarantined"`` and excluded from the comparison.

    ``expected`` (optional iterable of cell ids — typically the sweep's
    full expansion) makes degradation explicit: cells expected but
    absent from ``cells`` (dead workers, interrupted run, ``max_cells``
    cut) are listed under ``"missing"``, so a partial matrix states
    exactly what was dropped instead of silently comparing fewer cells.
    """
    missing = (
        sorted(set(expected) - set(cells)) if expected is not None else []
    )
    quarantined = sorted(c for c, r in cells.items() if r.get("quarantined"))
    cells = {c: r for c, r in cells.items() if not r.get("quarantined")}
    means = {cid: c["mean_sojourn_s"] for cid, c in cells.items()}
    ranked = sorted(means, key=lambda c: means[c])
    ratios = {}
    if ranked:
        best = ranked[0]
        for cid in ranked[1:]:
            if means[best] > 0:
                ratios[f"{cid}/{best}"] = means[cid] / means[best]
    return {
        "cells": len(cells),
        "quarantined": quarantined,
        "missing": missing,
        "mean_sojourn_s": means,
        "best": ranked[0] if ranked else None,
        "mean_ratio_vs_best": ratios,
    }
