"""The JAX gang runtime — the paper's "Amazon cluster" analogue.

Executes REAL JAX jobs (train/serve runs of the assigned architectures)
under any :class:`repro.core.Scheduler`, mapping the paper's primitives to
TPU-native mechanisms (DESIGN.md §2):

* machine  = host with a gang of chips; slot = gang slot;
* task     = step quantum (a fixed budget of train/serve steps);
* EAGER    = device->host offload of (params, opt, step) via the
  checkpoint store (the "swap partition"); RESUME = restore — on the SAME
  host, per the paper's locality rule;
* KILL     = discard quantum progress, restart from the last durable
  snapshot;
* WAIT     = let the in-flight quantum drain;
* straggler mitigation = speculative re-execution of a quantum that runs
  longer than ``straggler_factor`` x the job's median quantum time;
* fault tolerance = simulated gang failures re-queue the quantum (KILL
  semantics) and restore from the snapshot;
* elastic scaling  = a job suspended on gang A resumes on gang B of a
  different size: the serialized size (total step quanta) is
  width-independent, exactly the paper's trick.

The runtime drives the scheduler with the same event API as the simulator
(`on_job_arrival` / `on_task_complete` / `schedule`), so HFSP/FIFO/FAIR run
UNMODIFIED on real work.  Wall-clock time stands in for sim time.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.core.faults import FirstFinisherWins
from repro.core.scheduler import Kill, Resume, Scheduler, Start, Suspend
from repro.core.types import (
    ClusterSpec,
    JobSpec,
    Phase,
    SlotKey,
    TaskAttempt,
    TaskSpec,
    TaskState,
)
from repro.data import DataConfig, SyntheticLM
from repro.train import OptimizerConfig, TrainConfig, init_train_state, make_train_step


@dataclass
class MLJob:
    """One ML job: a training run chopped into step quanta."""

    job_id: int
    cfg: object                    # ModelConfig (reduced on CPU)
    total_steps: int
    steps_per_quantum: int
    arrival_time: float
    seq_len: int = 64
    global_batch: int = 8
    name: str = ""
    seed: int = 0

    @property
    def num_quanta(self) -> int:
        return -(-self.total_steps // self.steps_per_quantum)

    def to_jobspec(self, est_quantum_seconds: float = 1.0) -> JobSpec:
        tasks = tuple(
            TaskSpec(
                job_id=self.job_id,
                phase=Phase.MAP,
                index=i,
                duration=est_quantum_seconds,
                state_bytes=0,
            )
            for i in range(self.num_quanta)
        )
        return JobSpec(
            job_id=self.job_id,
            arrival_time=self.arrival_time,
            map_tasks=tasks,
            reduce_tasks=(),
            name=self.name or f"job{self.job_id}",
        )


@dataclass
class _JobRuntime:
    job: MLJob
    state: dict | None = None          # live train state (params+opt)
    step_fn: Callable | None = None
    data: SyntheticLM | None = None
    steps_done: int = 0
    quantum_times: list = field(default_factory=list)
    suspended_host: int | None = None  # EAGER locality
    losses: list = field(default_factory=list)


class GangRuntime:
    """Synchronous gang executor: each scheduler pass runs the quanta that
    were granted slots, one slot-quantum at a time (single-process JAX —
    gangs time-share the host devices, which preserves the scheduling
    semantics while keeping the runtime exact)."""

    def __init__(
        self,
        cluster: ClusterSpec,
        scheduler: Scheduler,
        jobs: list[MLJob],
        store: CheckpointStore,
        *,
        straggler_factor: float = 3.0,
        fail_quantum_prob: float = 0.0,
        rng_seed: int = 0,
    ):
        self.spec = cluster
        self.scheduler = scheduler
        self.store = store
        self.straggler_factor = straggler_factor
        self.fail_quantum_prob = fail_quantum_prob
        self.rng = np.random.default_rng(rng_seed)
        self.jobs = {j.job_id: j for j in jobs}
        self.rt: dict[int, _JobRuntime] = {}
        self._pending_arrivals = sorted(jobs, key=lambda j: j.arrival_time)
        self._free: dict[Phase, list[SlotKey]] = {
            Phase.MAP: [
                SlotKey(m, Phase.MAP, i)
                for m in range(cluster.num_machines)
                for i in range(cluster.map_slots_per_machine)
            ],
            Phase.REDUCE: [],
        }
        self._occupied: dict[SlotKey, TaskAttempt] = {}
        self._slot_by_task: dict[tuple, SlotKey] = {}
        self._susp_bytes: dict[int, int] = {}
        self._t0 = time.time()
        self.completions: dict[int, float] = {}
        self.arrivals: dict[int, float] = {}
        self.events: list[tuple[float, str, str]] = []
        self.stats = {"speculative": 0, "spec_wins": 0, "spec_losses": 0,
                      "failures": 0, "offloads": 0, "restores": 0, "kills": 0}
        self._ffw = FirstFinisherWins()

    # -- ClusterView protocol -------------------------------------------------
    def free_slots(self, phase: Phase) -> list[SlotKey]:
        return list(self._free[phase])

    def slot_occupant(self, slot: SlotKey) -> TaskAttempt | None:
        return self._occupied.get(slot)

    def occupied_slots(self, phase: Phase) -> dict[SlotKey, TaskAttempt]:
        return {s: a for s, a in self._occupied.items() if s.phase is phase}

    def machine_suspended_count(self, machine: int) -> int:
        return 0

    def machine_suspended_bytes(self, machine: int) -> int:
        return self._susp_bytes.get(machine, 0)

    def total_suspended_bytes(self) -> int:
        return sum(self._susp_bytes.values())

    # -- time -----------------------------------------------------------------
    def now(self) -> float:
        return time.time() - self._t0

    # -- job lifecycle -----------------------------------------------------------
    def _materialize(self, jid: int) -> _JobRuntime:
        rt = self.rt.get(jid)
        if rt is None:
            job = self.jobs[jid]
            rt = _JobRuntime(job=job)
            rt.data = SyntheticLM(
                job.cfg,
                DataConfig(seq_len=job.seq_len, global_batch=job.global_batch,
                           seed=job.seed),
            )
            step = make_train_step(
                job.cfg, OptimizerConfig(warmup_steps=5, total_steps=job.total_steps),
                TrainConfig(remat="none"),
            )
            rt.step_fn = jax.jit(step)
            rt.state = init_train_state(job.cfg, jax.random.PRNGKey(job.seed))
            self.rt[jid] = rt
        return rt

    def _offload(self, jid: int, host: int) -> None:
        """EAGER suspend: device -> host store ("swap")."""
        rt = self.rt[jid]
        if rt.state is not None:
            self.store.save(f"job{jid}", rt.steps_done, rt.state)
            rt.state = None            # free "HBM"
            rt.suspended_host = host
            self.stats["offloads"] += 1

    def _restore(self, jid: int) -> None:
        rt = self._materialize(jid)
        if rt.state is None:
            found = self.store.restore(f"job{jid}")
            assert found is not None, f"no snapshot for job {jid}"
            step, tree = found
            rt.state = jax.tree.map(jnp.asarray, tree)
            rt.steps_done = step
            rt.suspended_host = None
            self.stats["restores"] += 1

    # -- quantum execution ------------------------------------------------------
    def _run_quantum(self, att: TaskAttempt) -> None:
        jid = att.spec.job_id
        rt = self._materialize(jid)
        if rt.state is None:
            self._restore(jid)
        job = rt.job
        t0 = time.time()
        # Simulated gang failure: lose the quantum, KILL semantics.
        if self.fail_quantum_prob and self.rng.random() < self.fail_quantum_prob:
            self.stats["failures"] += 1
            found = self.store.restore(f"job{jid}")
            if found is not None:
                rt.state = jax.tree.map(jnp.asarray, found[1])
                rt.steps_done = found[0]
            self.events.append((self.now(), "failure", f"job{jid}"))
            return  # quantum must be re-run (task not completed)
        # Pre-quantum snapshot references: the step functions are pure, so
        # the tree rt.state points at now survives the quantum unchanged —
        # a speculative re-execution restarts from exactly here.
        pre_state, pre_steps = rt.state, rt.steps_done
        for s in range(job.steps_per_quantum):
            step_idx = rt.steps_done + s
            if step_idx >= job.total_steps:
                break
            batch = {
                k: jnp.asarray(v) for k, v in rt.data.batch(step_idx).items()
            }
            rt.state, metrics = rt.step_fn(rt.state, batch)
        rt.losses.append(float(metrics["loss"]))
        rt.steps_done = min(rt.steps_done + job.steps_per_quantum, job.total_steps)
        dt = time.time() - t0
        rt.quantum_times.append(dt)
        # Straggler mitigation: a quantum way beyond the median is
        # speculatively re-executed on a spare gang from the pre-quantum
        # snapshot; the first finisher wins and the loser's gang-time is
        # discarded.  (Synchronous runtime: the race is decided by the
        # two attempts' measured wall times.)
        med = float(np.median(rt.quantum_times))
        if len(rt.quantum_times) >= 3 and dt > self.straggler_factor * med:
            spare = self._spare_slot(exclude_machine=att.machine)
            if spare is not None:
                self.stats["speculative"] += 1
                self.events.append((
                    self.now(), "speculative",
                    f"job{jid} gang{att.machine}->gang{spare.machine}",
                ))
                rt.state = self._race_speculative(
                    rt, pre_state, pre_steps, dt, rt.state
                )
        # Durable snapshot at quantum boundary (fault tolerance).
        self.store.save(f"job{jid}", rt.steps_done, rt.state)

    def _spare_slot(self, exclude_machine: int | None) -> SlotKey | None:
        """A free gang for a speculative copy, preferably elsewhere (the
        straggling gang is the suspect)."""
        free = self._free[Phase.MAP]
        for s in free:
            if s.machine != exclude_machine:
                return s
        return free[0] if free else None

    def _race_speculative(
        self, rt: _JobRuntime, pre_state, pre_steps: int, primary_dt: float,
        primary_state,
    ):
        """Re-run the quantum from the pre-quantum snapshot on the spare
        gang and race it against the straggling primary: whichever attempt
        finished faster wins (FirstFinisherWins), the loser is discarded.
        Deterministic data makes the race safe — both attempts compute the
        same state, only the accounting differs."""
        job = rt.job
        t0 = time.time()
        state = pre_state
        for s in range(job.steps_per_quantum):
            step_idx = pre_steps + s
            if step_idx >= job.total_steps:
                break
            batch = {
                k: jnp.asarray(v) for k, v in rt.data.batch(step_idx).items()
            }
            state, _ = rt.step_fn(state, batch)
        shadow_dt = time.time() - t0
        key = (job.job_id, pre_steps)
        self._ffw.reset(key)
        for name, d in sorted(
            (("primary", primary_dt), ("shadow", shadow_dt)),
            key=lambda x: x[1],
        ):
            self._ffw.finish(key, name)
        if self._ffw.winner(key) == "shadow":
            self.stats["spec_wins"] += 1
            return state
        self.stats["spec_losses"] += 1
        return primary_state

    # -- action application -------------------------------------------------------
    def _apply(self, action) -> bool:
        """Apply one scheduler action; returns True if a quantum ran."""
        js_of = self.scheduler.jobs
        if isinstance(action, Start):
            att, slot = action.attempt, action.slot
            self._free[slot.phase].remove(slot)
            js_of[att.spec.job_id].transition(att, TaskState.RUNNING)
            att.machine = slot.machine
            att.attempts += 1
            self._occupied[slot] = att
            self._slot_by_task[att.spec.key] = slot
            self.scheduler.on_task_started(att, slot)
            return True
        if isinstance(action, Resume):
            att, slot = action.attempt, action.slot
            self._free[slot.phase].remove(slot)
            self._restore(att.spec.job_id)
            m = att.machine if att.machine is not None else -1
            self._susp_bytes[m] = 0
            js_of[att.spec.job_id].transition(att, TaskState.RUNNING)
            self._occupied[slot] = att
            self._slot_by_task[att.spec.key] = slot
            self.scheduler.on_task_resumed(att, slot)
            return True
        if isinstance(action, Suspend):
            att = action.attempt
            slot = self._slot_by_task.pop(att.spec.key)
            del self._occupied[slot]
            self._free[slot.phase].append(slot)
            js_of[att.spec.job_id].transition(att, TaskState.SUSPENDED)
            self._offload(att.spec.job_id, slot.machine)
            self._susp_bytes[slot.machine] = (
                self._susp_bytes.get(slot.machine, 0) + 1
            )
            self.scheduler.on_task_suspended(att)
            return False
        if isinstance(action, Kill):
            att = action.attempt
            slot = self._slot_by_task.pop(att.spec.key)
            del self._occupied[slot]
            self._free[slot.phase].append(slot)
            js_of[att.spec.job_id].transition(att, TaskState.PENDING)
            att.machine = None
            self.stats["kills"] += 1
            self.scheduler.on_task_killed(att)
            return False
        raise TypeError(action)

    # -- main loop ------------------------------------------------------------------
    def run(self, *, max_wall_s: float = 600.0) -> dict:
        """Drive scheduler + quanta to completion (or the wall limit)."""
        while time.time() - self._t0 < max_wall_s:
            now = self.now()
            # Admit arrived jobs.
            while self._pending_arrivals and (
                self._pending_arrivals[0].arrival_time <= now
            ):
                job = self._pending_arrivals.pop(0)
                self.arrivals[job.job_id] = now
                self.scheduler.on_job_arrival(
                    job.to_jobspec(est_quantum_seconds=1.0), now
                )
                self.events.append((now, "arrival", job.name))
            # Let the scheduler assign slots.
            for action in self.scheduler.schedule(self, now):
                self._apply(action)
            # Run one in-flight quantum per pass (round-robin over slots).
            ran = False
            for slot, att in list(self._occupied.items()):
                self._run_quantum(att)
                ran = True
                # Completion bookkeeping.
                del self._occupied[slot]
                self._slot_by_task.pop(att.spec.key, None)
                self._free[slot.phase].append(slot)
                rt = self.rt[att.spec.job_id]
                js = self.scheduler.jobs[att.spec.job_id]
                if rt.steps_done >= rt.job.total_steps:
                    # Finish every remaining task of the job.
                    for other in js.attempts(Phase.MAP):
                        if other.state is not TaskState.DONE:
                            js.transition(other, TaskState.DONE)
                            self.scheduler.on_task_complete(
                                att.spec.job_id, other.spec.key, self.now()
                            )
                else:
                    js.transition(att, TaskState.DONE)
                    self.scheduler.on_task_complete(
                        att.spec.job_id, att.spec.key, self.now()
                    )
                if js.is_done() and js.completion_time is None:
                    js.completion_time = self.now()
                    self.completions[att.spec.job_id] = self.now()
                    self.scheduler.on_job_complete(att.spec.job_id, self.now())
                    self.events.append((self.now(), "complete", rt.job.name))
                break  # one quantum per pass keeps scheduling responsive
            if not ran:
                if not self._pending_arrivals and not any(
                    js.completion_time is None
                    for js in self.scheduler.jobs.values()
                ):
                    break
                time.sleep(0.01)
        return self.report()

    def report(self) -> dict:
        sojourn = {
            j: self.completions[j] - self.arrivals[j]
            for j in self.completions
        }
        return {
            "sojourn": sojourn,
            "mean_sojourn": (
                sum(sojourn.values()) / len(sojourn) if sojourn else 0.0
            ),
            "losses": {j: rt.losses[-1] if rt.losses else None
                       for j, rt in self.rt.items()},
            "stats": dict(self.stats),
            "events": list(self.events),
        }
