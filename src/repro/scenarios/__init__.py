"""Scenario engine: declarative experiment matrix, trace replay, sweeps.

The subsystem that owns "an experiment" (see docs/scenarios.md):

* :mod:`repro.scenarios.spec`    — ScenarioSpec / SweepSpec (the axes);
* :mod:`repro.scenarios.presets` — named sweeps (the paper's matrix);
* :mod:`repro.scenarios.trace`   — versioned JSONL trace export/replay;
* :mod:`repro.scenarios.runner`  — one cell -> simulator -> report;
* :mod:`repro.scenarios.sweep`   — parallel, resumable grid execution;
* :mod:`repro.scenarios.report`  — machine-readable JSON reductions.

CLI: ``python -m repro.scenarios run paper-fb --quick``.
"""

from repro.scenarios.presets import (
    get_preset,
    list_presets,
    paper_fb_base,
    quick_sweep,
    register_preset,
)
from repro.scenarios.report import matrix_report, scenario_report
from repro.scenarios.runner import run_scenario, simulate
from repro.scenarios.spec import (
    ClusterAxis,
    FaultAxis,
    ScenarioSpec,
    SchedulerAxis,
    SweepSpec,
    WorkloadAxis,
)
from repro.scenarios.sweep import ResultStore, run_sweep
from repro.scenarios.trace import export_trace, load_trace

__all__ = [
    "ClusterAxis",
    "FaultAxis",
    "ResultStore",
    "ScenarioSpec",
    "SchedulerAxis",
    "SweepSpec",
    "WorkloadAxis",
    "export_trace",
    "get_preset",
    "list_presets",
    "load_trace",
    "matrix_report",
    "paper_fb_base",
    "quick_sweep",
    "register_preset",
    "run_scenario",
    "run_sweep",
    "scenario_report",
    "simulate",
]
