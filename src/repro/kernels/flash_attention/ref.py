"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,   # (b, h, sq, hd)
    k: jnp.ndarray,   # (b, kvh, skv, hd)
    v: jnp.ndarray,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    kvh = k.shape[1]
    groups = h // kvh
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
