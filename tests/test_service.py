"""Live service tests: twin determinism, crash/restore, admission.

The keystone assertions:

* a live session (wall-clock master, real asyncio workers, worker
  death mid-task) replays bit-identically through the offline
  Simulator — ``completion_fingerprint(live) ==
  completion_fingerprint(twin)`` for fifo, hfsp and psbs;
* a master killed at a randomized point restores from journal +
  checkpoint with no lost and no duplicated jobs, and the final
  journal still satisfies the twin property;
* a SIGKILL'd *subprocess* master survives restart end-to-end over
  the wire (exactly-once submits via idempotency tags).
"""

from __future__ import annotations

import asyncio
import json
import os
import signal
import socket
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.core.types import ClusterSpec
from repro.scenarios.trace import load_trace
from repro.service import (
    AdmissionConfig,
    AdmissionControl,
    Journal,
    LiveEngine,
    Master,
    MasterConfig,
    WorkerAgent,
    live_fingerprint,
    read_journal,
    replay_journal,
)
from repro.service.protocol import ServiceClient

REPO = Path(__file__).resolve().parent.parent

CLUSTER = dict(
    num_machines=2, map_slots_per_machine=2, reduce_slots_per_machine=1
)
#: Fast virtual clock: 1 wall ms = 1 virtual second.
TIME_SCALE = 1000.0


def mk_job(i: int, scale: float = 1.0) -> dict:
    """Deterministic nontrivial job payload (trace task schema)."""
    return {
        "name": f"job-{i}",
        "map": [[scale * (20.0 + 7.0 * ((i + k) % 5)), [], 0]
                for k in range(2 + i % 3)],
        "reduce": [[scale * 15.0, [], 0]] if i % 2 else [],
        "weight": 1.0,
        "reduce_slowstart": 1.0,
    }


async def boot(tmp_path, policy, **cfg_kw):
    engine = LiveEngine.create(
        tmp_path / "live.jsonl", policy, ClusterSpec(**CLUSTER),
        time_scale=TIME_SCALE,
    )
    cfg_kw.setdefault("pace_wall", 0.005)
    cfg_kw.setdefault("worker_dead_wall", 0.15)
    master = Master(engine, MasterConfig(**cfg_kw))
    await master.start()
    workers = []
    for m in range(CLUSTER["num_machines"]):
        w = WorkerAgent("127.0.0.1", master.port, m, heartbeat_wall=0.03)
        await w.start()
        workers.append(w)
    return engine, master, workers


def client_submit(port: int, jobs: list[dict], user="u0") -> list[int]:
    with ServiceClient("127.0.0.1", port) as c:
        out = []
        for i, job in enumerate(jobs):
            r = c.call({"op": "submit", "user": user,
                        "tag": f"{user}-{i}", "job": job})
            assert r["ok"], r
            out.append(r["job_id"])
        return out


async def drain(engine, n, timeout=20.0):
    t0 = time.monotonic()
    while len(engine.sim.result.completion) < n:
        if time.monotonic() - t0 > timeout:
            raise AssertionError(
                f"only {len(engine.sim.result.completion)}/{n} jobs "
                f"completed in {timeout}s"
            )
        await asyncio.sleep(0.01)


# ---------------------------------------------------------------------------
# Twin determinism with worker death mid-task
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["fifo", "hfsp", "psbs"])
def test_live_session_replays_bit_identically(tmp_path, policy):
    """The deterministic-twin property: live run (real wall clock, real
    sockets, a worker dying mid-task and later rejoining) == offline
    Simulator replay of the journal, to the fingerprint."""

    async def session():
        engine, master, workers = await boot(tmp_path, policy)
        loop = asyncio.get_running_loop()
        # scale=3: ~1s of wall-clock workload, so the worker death at
        # ~0.2s lands mid-task and the fault machinery must reschedule.
        jobs = [mk_job(i, scale=3.0) for i in range(16)]
        await loop.run_in_executor(None, client_submit, master.port, jobs)
        # Let the workload start, then silently kill machine 1's worker.
        await drain(engine, 2)
        await workers[1].die()
        # Master declares the crash after worker_dead_wall of silence.
        t0 = time.monotonic()
        while master.telemetry.counters["worker_crashes"] == 0:
            assert time.monotonic() - t0 < 5.0, "crash never declared"
            await asyncio.sleep(0.01)
        # Rejoin: fresh agent on the same machine -> journaled recover.
        w = WorkerAgent("127.0.0.1", master.port, 1, heartbeat_wall=0.03)
        await w.start()
        t0 = time.monotonic()
        while master.telemetry.counters["worker_rejoins"] == 0:
            assert time.monotonic() - t0 < 5.0, "rejoin never recorded"
            await asyncio.sleep(0.01)
        await drain(engine, 16)
        fp_live = live_fingerprint(engine.sim)
        completions = dict(engine.sim.result.completion)
        await master.stop()
        await w.die()
        for wk in workers:
            await wk.die()
        return fp_live, completions

    fp_live, completions = asyncio.run(session())
    assert len(completions) == 16

    twin = replay_journal(tmp_path / "live.jsonl")
    assert live_fingerprint(twin) == fp_live
    assert twin.result.completion == completions
    # The journal recorded the death and the rejoin.
    _, entries = read_journal(tmp_path / "live.jsonl")
    kinds = [e.get("event") for e in entries]
    assert "crash" in kinds and "recover" in kinds


def test_journal_doubles_as_plain_trace(tmp_path):
    """A recorded session loads through the ordinary trace loader (event
    lines skipped), so the live workload can re-run offline as a cell."""

    async def session():
        engine, master, workers = await boot(tmp_path, "fifo")
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(
            None, client_submit, master.port, [mk_job(i) for i in range(5)]
        )
        await drain(engine, 5)
        await master.stop()
        for w in workers:
            await w.die()

    asyncio.run(session())
    jobs, _, meta = load_trace(tmp_path / "live.jsonl")
    assert len(jobs) == 5
    assert meta["journal"] is True
    assert [j.job_id for j in jobs] == sorted(j.job_id for j in jobs)


# ---------------------------------------------------------------------------
# Crash/restore at randomized kill points (S4)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_crash_restore_randomized_kill_point(tmp_path, seed):
    """Kill the master (no graceful stop, no final checkpoint) after a
    seed-randomized number of submissions; restore from the journal;
    finish the workload; assert exactly-once jobs and the twin property
    over the stitched journal."""
    import random

    rng = random.Random(seed)
    n_before = rng.randint(1, 10)
    n_total = 12

    async def phase_one():
        engine, master, workers = await boot(tmp_path, "hfsp")
        loop = asyncio.get_running_loop()
        jobs = [mk_job(i) for i in range(n_before)]
        ids = await loop.run_in_executor(
            None, client_submit, master.port, jobs
        )
        # Randomized kill point: let an arbitrary amount of work happen.
        await asyncio.sleep(rng.uniform(0.0, 0.2))
        # Simulated SIGKILL: tear down sockets only — no master.stop(),
        # no checkpoint, journal left exactly as last fsync'd.
        master._pacer.cancel()
        master._server.close()
        for w in workers:
            await w.die()
        engine.journal._f.close()
        return ids

    ids_before = asyncio.run(phase_one())

    # Torn-tail realism: append half a line, as a crash mid-append would.
    with open(tmp_path / "live.jsonl", "a") as f:
        f.write('{"event": "adva')

    async def phase_two():
        engine = LiveEngine.restore(
            tmp_path / "live.jsonl", time_scale=TIME_SCALE
        )
        master = Master(engine, MasterConfig(
            pace_wall=0.005, worker_dead_wall=0.15))
        await master.start()
        workers = []
        for m in range(CLUSTER["num_machines"]):
            w = WorkerAgent("127.0.0.1", master.port, m, heartbeat_wall=0.03)
            await w.start()
            workers.append(w)
        loop = asyncio.get_running_loop()

        def resubmit_and_finish():
            with ServiceClient("127.0.0.1", master.port) as c:
                # Replay every pre-crash tag (client retry after losing
                # its acks) — must dedup, never duplicate.
                redone = []
                for i in range(n_before):
                    r = c.call({"op": "submit", "user": "u0",
                                "tag": f"u0-{i}", "job": mk_job(i)})
                    assert r["ok"] and r["decision"] == "dedup", r
                    redone.append(r["job_id"])
                fresh = []
                for i in range(n_before, n_total):
                    r = c.call({"op": "submit", "user": "u0",
                                "tag": f"u0-{i}", "job": mk_job(i)})
                    assert r["ok"], r
                    fresh.append(r["job_id"])
                return redone, fresh

        redone, fresh = await loop.run_in_executor(None, resubmit_and_finish)
        await drain(engine, n_total)
        fp = live_fingerprint(engine.sim)
        completions = dict(engine.sim.result.completion)
        await master.stop()
        for w in workers:
            await w.die()
        return redone, fresh, fp, completions

    redone, fresh, fp, completions = asyncio.run(phase_two())
    # Exactly-once: pre-crash tags resolve to the original ids, fresh
    # jobs get new ids, and the union is exactly n_total distinct jobs.
    assert redone == ids_before
    assert len(set(redone + fresh)) == n_total
    assert len(completions) == n_total

    # The stitched journal (pre-crash prefix + post-restore suffix)
    # still satisfies the twin property.
    twin = replay_journal(tmp_path / "live.jsonl")
    assert live_fingerprint(twin) == fp
    assert twin.result.completion == completions


def test_journal_tail_repair(tmp_path):
    j = Journal(tmp_path / "j.jsonl", meta={
        "policy": "fifo", "cluster": CLUSTER, "heartbeat": 3.0,
        "event_epsilon": 0.0, "time_scale": 1.0,
    })
    j.append_event({"event": "advance", "t": 1.0})
    j.close()
    with open(tmp_path / "j.jsonl", "a") as f:
        f.write('{"event": "crash", "t": 2.0, "mach')  # torn mid-append
    meta, entries = read_journal(tmp_path / "j.jsonl")
    assert entries == [{"event": "advance", "t": 1.0}]
    # Reopening repairs the file, and appends continue cleanly.
    j2 = Journal(tmp_path / "j.jsonl")
    j2.append_event({"event": "advance", "t": 3.0})
    j2.close()
    _, entries = read_journal(tmp_path / "j.jsonl")
    assert [e["t"] for e in entries] == [1.0, 3.0]


# ---------------------------------------------------------------------------
# SIGKILL a real subprocess master; restart; exactly-once end to end
# ---------------------------------------------------------------------------
def _wait_port(path: Path, timeout=15.0) -> int:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if path.exists() and path.read_text().strip():
            port = int(path.read_text())
            # The port file may outlive a killed master: probe it.
            try:
                socket.create_connection(("127.0.0.1", port), 0.2).close()
                return port
            except OSError:
                pass
        time.sleep(0.05)
    raise AssertionError("master never came up")


def _spawn_master(tmp_path, tag: str) -> subprocess.Popen:
    port_file = tmp_path / f"port-{tag}"
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.service", "master",
            "--journal", str(tmp_path / "live.jsonl"),
            "--checkpoint", str(tmp_path / "ck.json"),
            "--policy", "hfsp", "--machines", "2",
            "--map-slots", "2", "--reduce-slots", "1",
            "--time-scale", str(TIME_SCALE),
            "--port-file", str(port_file),
        ],
        env={**os.environ, "PYTHONPATH": str(REPO / "src")},
        stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL,
    )
    try:
        port = _wait_port(port_file)
    except AssertionError:
        proc.kill()
        raise
    return proc, port


def test_sigkill_master_restart_no_lost_or_duplicated_jobs(tmp_path):
    proc1, port1 = _spawn_master(tmp_path, "a")
    try:
        ids1 = client_submit(port1, [mk_job(i) for i in range(6)])
        os.kill(proc1.pid, signal.SIGKILL)
        proc1.wait(timeout=10)

        proc2, port2 = _spawn_master(tmp_path, "b")
        try:
            with ServiceClient("127.0.0.1", port2) as c:
                # Retry every tag: all must dedup to the original ids.
                for i in range(6):
                    r = c.call({"op": "submit", "user": "u0",
                                "tag": f"u0-{i}", "job": mk_job(i)})
                    assert r["ok"] and r["decision"] == "dedup", r
                    assert r["job_id"] == ids1[i]
                ids2 = []
                for i in range(6, 9):
                    r = c.call({"op": "submit", "user": "u0",
                                "tag": f"u0-{i}", "job": mk_job(i)})
                    assert r["ok"], r
                    ids2.append(r["job_id"])
                assert len(set(ids1 + ids2)) == 9
                # Engine completes everything without workers (they are
                # advisory); wait for it and read decision latency.
                t0 = time.monotonic()
                while True:
                    snap = c.call({"op": "status"})
                    if snap["jobs"]["completed"] >= 9:
                        break
                    assert time.monotonic() - t0 < 30.0, snap["jobs"]
                    time.sleep(0.05)
                assert snap["decision_latency_ms"]["count"] > 0
                assert snap["decision_latency_ms"]["p99"] >= 0.0
                r = c.call({"op": "shutdown"})
                assert r["ok"]
            proc2.wait(timeout=10)
        finally:
            proc2.kill()
    finally:
        proc1.kill()

    # And the whole stitched history still replays bit-identically: the
    # CLI twin agrees with itself and completed every job exactly once.
    out = subprocess.run(
        [sys.executable, "-m", "repro.service", "replay",
         "--journal", str(tmp_path / "live.jsonl")],
        env={**os.environ, "PYTHONPATH": str(REPO / "src")},
        capture_output=True, text=True, check=True,
    )
    rep = json.loads(out.stdout)
    assert rep["jobs_completed"] == 9
    twin = replay_journal(tmp_path / "live.jsonl")
    assert live_fingerprint(twin) == rep["completion_fingerprint"]


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------
def test_admission_rate_limit_and_backpressure():
    ctl = AdmissionControl(AdmissionConfig(
        max_live_jobs=2, rate_limit=10.0, burst=3, max_queue_per_user=2))
    # Burst of 3 passes the bucket; 4th (same instant) is rate-limited.
    assert ctl.offer("u", "a", 0.0, 0) == "admit"
    assert ctl.offer("u", "b", 0.0, 1) == "admit"
    assert ctl.offer("u", "c", 0.0, 2) == "queued"  # at the live ceiling
    assert ctl.offer("u", "d", 0.0, 2) == "reject-rate"
    # Tokens refill with wall time; queue fills, then rejects.
    assert ctl.offer("u", "e", 1.0, 2) == "queued"
    assert ctl.offer("u", "f", 2.0, 2) == "reject-queue"
    # Capacity frees -> drain releases FIFO per user.
    assert ctl.drain(live_jobs=0) == [("u", "c"), ("u", "e")]
    assert ctl.queued_count() == 0


def test_admission_drain_is_round_robin_across_users():
    ctl = AdmissionControl(AdmissionConfig(max_live_jobs=0))
    for k in range(3):
        assert ctl.offer("alice", f"a{k}", 0.0, 0) == "queued"
    assert ctl.offer("bob", "b0", 0.0, 0) == "queued"
    ctl.cfg.max_live_jobs = 3
    # One per user per cycle: alice cannot starve bob.
    assert ctl.drain(live_jobs=0) == [
        ("alice", "a0"), ("bob", "b0"), ("alice", "a1")]
    assert ctl.drain(live_jobs=2) == [("alice", "a2")]


def test_master_backpressure_queues_then_drains(tmp_path):
    async def session():
        engine = LiveEngine.create(
            tmp_path / "live.jsonl", "fifo", ClusterSpec(**CLUSTER),
            time_scale=TIME_SCALE,
        )
        master = Master(engine, MasterConfig(
            pace_wall=0.005,
            admission=AdmissionConfig(max_live_jobs=2),
        ))
        await master.start()
        loop = asyncio.get_running_loop()

        def burst():
            with ServiceClient("127.0.0.1", master.port) as c:
                decisions = []
                for i in range(6):
                    # scale=5: the first two jobs outlive the whole
                    # burst, so the later offers see a full live set.
                    r = c.call({"op": "submit", "user": "u0",
                                "tag": f"t{i}", "job": mk_job(i, scale=5.0)})
                    assert r["ok"], r
                    decisions.append(r["decision"])
                return decisions

        decisions = await loop.run_in_executor(None, burst)
        assert decisions[:2] == ["admit", "admit"]
        assert set(decisions[2:]) == {"queued"}
        # Queued jobs drain as completions free capacity; everything
        # eventually runs (workers are advisory, none needed).
        await drain(engine, 6)
        fp = live_fingerprint(engine.sim)
        await master.stop()
        return fp

    fp = asyncio.run(session())
    twin = replay_journal(tmp_path / "live.jsonl")
    assert live_fingerprint(twin) == fp


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------
def test_telemetry_snapshot_vocabulary(tmp_path):
    async def session():
        engine, master, workers = await boot(tmp_path, "hfsp")
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(
            None, client_submit, master.port, [mk_job(i) for i in range(8)]
        )
        await drain(engine, 8)

        def pull():
            with ServiceClient("127.0.0.1", master.port) as c:
                snap = c.call({"op": "status"})
                stream = [c.call({"op": "telemetry"})]  # ticks=1 default
                return snap, stream

        snap, _ = await loop.run_in_executor(None, pull)
        await master.stop()
        for w in workers:
            await w.die()
        return snap

    snap = asyncio.run(session())
    assert snap["jobs"]["completed"] == 8
    assert snap["jobs"]["submitted"] == 8
    for block, keys in [
        ("sojourn", ("mean_s", "p50", "p99", "p999")),
        ("slowdown", ("p50", "p99", "p999")),
        ("decision_latency_ms", ("count", "p50", "p99")),
    ]:
        for k in keys:
            assert k in snap[block], (block, k, sorted(snap[block]))
    assert 0.0 < snap["fairness"]["jain_slowdown"] <= 1.0
    assert snap["goodput"] == 1.0  # no faults injected in this session
    assert snap["workers"] == {"0": {"alive": True}, "1": {"alive": True}}
