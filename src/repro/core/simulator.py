"""Discrete-event cluster simulator — the paper's Mumak analogue (Sect. 4.1).

Executes any :class:`~repro.core.scheduler.Scheduler` against a simulated
cluster with per-machine MAP/REDUCE slots, data locality, preemption
primitives (SUSPEND / RESUME / KILL) and an optional DMA cost model for the
TPU adaptation (suspend state must cross HBM<->host DRAM; in the paper the
analogous cost is OS swap I/O, which Sect. 5 argues is bounded).

Semantics:

* a RUNNING task progresses at unit rate; progress is frozen on SUSPEND;
* RESUME charges ``ClusterSpec.suspend_cost(state_bytes)`` by *rolling back*
  progress (the swapped-in context must be re-materialized before useful
  work continues — the paper's "Resume operation may introduce further
  delays");
* KILL discards all progress and re-queues the task (Sect. 3.3);
* REDUCE sample tasks report progress to the scheduler after ``delta``
  seconds of execution (supports the sigma = Delta/p estimator, Sect. 3.2.1);
* the scheduler is consulted on every event and on a periodic heartbeat.

The simulator is deterministic given the job list.

Epsilon-window event coalescing
-------------------------------
By default (``event_epsilon=0``) a scheduling pass runs after every event,
with only exact-timestamp ARRIVAL/COMPLETE batches sharing one pass.  With
``event_epsilon=eps > 0`` the loop instead pops *every* heap event within
``eps`` of the window head (the first event after the previous pass),
applies each event's state mutation at its own timestamp, and runs ONE
scheduling pass at the window-end timestamp — the event-batching design of
"A Simulator for Data-Intensive Job Scheduling" (arXiv 1306.6023), which
cuts pass counts by an order of magnitude on bursty traces.

Determinism contract (see docs/scheduler_internals.md):

* events inside a window apply in stable ``(time, kind, seq)`` heap order
  — the same total order the eps=0 loop uses, so a window is just the
  eps=0 mutation sequence with intermediate passes elided;
* each mutation sees ``now`` = its own event time (completion times,
  progress fractions, and virtual-cluster aging are unchanged); only the
  *pass* moves, to the window's last event time;
* eps=0 is bit-identical to the legacy loop (enforced by the conformance
  suite), and any eps is reproducible across runs and processes — the
  window boundaries are a pure function of the event stream and the
  ``run(until=...)`` barriers;
* ``until`` is a simulation-time barrier: a window never spans it — the
  pending pass is flushed before ``run`` returns, so callers always
  observe fully-scheduled state at ``until`` (decisions due by the
  barrier are not deferred past it).  ``run(until=T)`` + ``run()`` may
  therefore place passes differently than one unsliced ``run()`` — by
  design, like any other choice of barrier.  ``max_events`` slicing, by
  contrast, is placement-neutral: an open window persists across the
  budget exception and resumes identically.
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field

from repro.core.faults import FaultInjector, FaultModel
from repro.core.scheduler import Action, Kill, Resume, Scheduler, Start, Suspend
from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    SlotKey,
    TaskAttempt,
    TaskState,
)

_ARRIVAL, _COMPLETE, _PROGRESS, _TICK, _FAULT = 0, 1, 2, 3, 4


def auto_event_epsilon(
    arrivals: list[float], heartbeat: float = 3.0
) -> float:
    """Pick a coalescing window width from observed arrival burstiness.

    Burstiness is measured as the coefficient of variation (CV) of the
    inter-arrival gaps.  CV <= 1 (Poisson or smoother): return 0 — the
    stream has no bursts, so a window would only delay decisions without
    cutting pass counts.  CV > 1: return the *median* gap — in a bursty
    stream the median sits inside the bursts (most gaps are tiny), so a
    median-wide window merges each burst into one scheduling pass while
    the inter-burst gaps, far above the median, still get their own.
    Capped at one ``heartbeat`` so no decision is ever deferred longer
    than the executor's own tick, and 0 for fewer than 3 arrivals (one
    gap is not a distribution).

    Pure and deterministic: scenario cells resolve
    ``event_epsilon="auto"`` through this at build time, and the live
    service's epsilon controller re-evaluates it over the observed
    arrival history (journaling each retune so the twin replay uses the
    recorded value, never a recomputation).
    """
    ts = sorted(arrivals)
    if len(ts) < 3:
        return 0.0
    gaps = [b - a for a, b in zip(ts, ts[1:])]
    mean = sum(gaps) / len(gaps)
    if mean <= 0.0:
        # Every arrival simultaneous: any window merges them; one
        # heartbeat is the largest we ever allow.
        return float(heartbeat)
    var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
    cv = (var ** 0.5) / mean
    if cv <= 1.0:
        return 0.0
    med = sorted(gaps)[len(gaps) // 2]
    return float(min(med, heartbeat))


@dataclass
class SimConfig:
    """Executor knobs, bundled so scenario specs and benchmarks can pass
    one object (`Simulator(..., config=SimConfig(...))`)."""

    heartbeat: float = 3.0
    track_timeline: bool = False
    #: Delta after which a running REDUCE sample task reports progress;
    #: None defers to the scheduler's TrainingModule delta.
    progress_delta: float | None = None
    #: Epsilon-window event coalescing (seconds): 0 = a pass per event
    #: (legacy, bit-identical); eps > 0 = one pass per event window (see
    #: module docstring for the determinism contract); the string
    #: ``"auto"`` = derive the width from the workload's arrival
    #: burstiness at construction time (:func:`auto_event_epsilon`).
    event_epsilon: float | str = 0.0
    #: Deterministic fault injection (repro.core.faults / docs/faults.md);
    #: None or an all-zero-rate model leaves the fault layer entirely off
    #: — zero-fault runs are bit-identical to pre-fault builds.
    faults: FaultModel | None = None


class EventLimitReached(RuntimeError):
    """run(max_events=N) processed N events without draining the heap.

    Subclasses RuntimeError for backward compatibility with callers that
    use max_events as a livelock guard; callers that use it as a
    deliberate slicing budget (the scheduler-overhead benchmarks) catch
    this type specifically so a genuine error can't masquerade as an
    exhausted budget."""


@dataclass
class SimResult:
    """Everything the benchmarks need."""

    arrival: dict[int, float] = field(default_factory=dict)
    completion: dict[int, float] = field(default_factory=dict)
    first_dispatch: dict[int, float] = field(default_factory=dict)
    locality_hits: int = 0
    locality_misses: int = 0
    stats: object | None = None
    # (time, job_id, phase, running-slot-count) samples for Fig. 7 graphs.
    timeline: list[tuple[float, int, str, int]] = field(default_factory=list)
    makespan: float = 0.0
    # Scheduler passes run / events processed — the epsilon-window
    # sojourn-vs-overhead tradeoff reads per pass counts per cell.
    passes: int = 0
    events: int = 0
    # Fault-layer counters + trace length (FaultInjector.stats_dict);
    # None when the fault layer is disabled.
    faults: dict | None = None

    @property
    def sojourn(self) -> dict[int, float]:
        return {
            j: self.completion[j] - self.arrival[j]
            for j in self.completion
            if j in self.arrival
        }

    def mean_sojourn(self) -> float:
        s = self.sojourn
        return sum(s.values()) / len(s) if s else 0.0

    @property
    def locality_fraction(self) -> float:
        tot = self.locality_hits + self.locality_misses
        return self.locality_hits / tot if tot else 1.0


class Simulator:
    """ClusterView implementation + event loop."""

    def __init__(
        self,
        cluster: ClusterSpec,
        scheduler: Scheduler,
        jobs: list[JobSpec],
        heartbeat: float | None = None,
        track_timeline: bool | None = None,
        progress_delta: float | None = None,
        event_epsilon: float | None = None,
        faults: FaultModel | None = None,
        config: SimConfig | None = None,
    ):
        # The knob kwargs default to None sentinels and resolve through
        # SimConfig, so the defaults live in exactly one place.  A config
        # bundle replaces the individual knobs — mixing both would
        # silently drop one side, so explicit kwargs alongside a config
        # are rejected.  (progress_delta=None is itself the "defer to the
        # scheduler's TrainingModule delta" value, so passing it
        # explicitly is indistinguishable from omitting it — harmless.)
        explicit = {
            name: val
            for name, val in (
                ("heartbeat", heartbeat),
                ("track_timeline", track_timeline),
                ("progress_delta", progress_delta),
                ("event_epsilon", event_epsilon),
                ("faults", faults),
            )
            if val is not None
        }
        if config is not None:
            if explicit:
                raise ValueError(
                    "pass executor knobs either via config=SimConfig(...) "
                    f"or as keyword arguments, not both: {sorted(explicit)}"
                )
        else:
            config = SimConfig(**explicit)
        self.spec = cluster
        self.scheduler = scheduler
        self.heartbeat = config.heartbeat
        self.track_timeline = config.track_timeline
        progress_delta = config.progress_delta
        event_epsilon = config.event_epsilon
        if isinstance(event_epsilon, str):
            if event_epsilon != "auto":
                raise ValueError(
                    f"event_epsilon must be a number or 'auto', got "
                    f"{event_epsilon!r}"
                )
            event_epsilon = auto_event_epsilon(
                [j.arrival_time for j in jobs], config.heartbeat
            )
        if event_epsilon < 0:
            raise ValueError(f"event_epsilon must be >= 0, got {event_epsilon}")
        self.event_epsilon = float(event_epsilon)
        # End timestamp of the open coalescing window (None = no window
        # open); persists across incremental run() calls so a window split
        # by an event-budget slice closes identically.
        self._window_end: float | None = None
        # Delta after which a running REDUCE sample task reports progress;
        # defaults to the scheduler's TrainingModule delta if present.
        if progress_delta is None:
            progress_delta = getattr(
                getattr(scheduler, "training", None), "delta", 60.0
            )
        self.progress_delta = progress_delta

        self._jobs = sorted(jobs, key=lambda j: (j.arrival_time, j.job_id))
        self._heap: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self._now = 0.0
        # Physical slot state.  Free slots are insertion-ordered dicts:
        # same iteration/removal order as a list, but O(1) release/claim
        # (the scheduler pass consults free_slots on every event).
        self._free: dict[Phase, dict[SlotKey, None]] = {
            Phase.MAP: {}, Phase.REDUCE: {},
        }
        for m in range(cluster.num_machines):
            for i in range(cluster.map_slots_per_machine):
                self._free[Phase.MAP][SlotKey(m, Phase.MAP, i)] = None
            for i in range(cluster.reduce_slots_per_machine):
                self._free[Phase.REDUCE][SlotKey(m, Phase.REDUCE, i)] = None
        self._occupied: dict[SlotKey, TaskAttempt] = {}
        self._occupied_by_phase: dict[Phase, dict[SlotKey, TaskAttempt]] = {
            Phase.MAP: {}, Phase.REDUCE: {},
        }
        self._slot_by_task: dict[tuple, SlotKey] = {}
        # Epochs invalidate stale COMPLETE/PROGRESS events after preemption.
        self._epoch: dict[tuple, int] = {}
        self._susp_bytes: dict[int, int] = {}
        self._susp_count: dict[int, int] = {}
        self._susp_total = 0
        self._tick_pending = False
        self.result = SimResult()
        # Total events processed / scheduling passes run across all
        # (possibly incremental) run() calls — consumed by the
        # scheduler-overhead benchmarks and the epsilon-sweep reports.
        self.events_processed = 0
        self.passes = 0
        # -- fault layer (repro.core.faults; active only when enabled) --
        fm = config.faults
        self.faults = fm if (fm is not None and fm.enabled) else None
        self._injector = (
            FaultInjector(self.faults, cluster.num_machines)
            if self.faults is not None
            else None
        )
        # Machines currently out of the pool ("crash" | "blacklist").
        # Slots on a down machine stay inside self._free — free_slots()
        # filters the VIEW, so the Resume path's `slot in self._free`
        # assert (intra-pass suspend/resume handover) is untouched.
        self._machine_down: dict[int, str] = {}
        # Speculative shadow executions: task key -> (slot, started_at,
        # generation).  A shadow claims a physical slot but is invisible
        # to the scheduler (never in _occupied or JobState).
        self._spec_running: dict[tuple, tuple[SlotKey, float, int]] = {}
        self._spec_seq = itertools.count()
        # Outstanding arrivals — machine fault events are moot once the
        # workload is drained (no arrivals left, no live jobs), which
        # keeps crash/recover regeneration from inflating the makespan.
        self._arrivals_left = len(self._jobs)
        # -- live-service seam (repro.service; None = offline replay) --
        # Observer callbacks, called AFTER the engine applied the state
        # change (they must not mutate engine state, so the listener-less
        # twin replay stays bit-identical): action_listener(action, now)
        # after every applied scheduling action, completion_listener(
        # job_id, now) on every job completion.
        self.action_listener = None
        self.completion_listener = None

    # ------------------------------------------------------------------
    # ClusterView protocol
    # ------------------------------------------------------------------
    def free_slots(self, phase: Phase) -> list[SlotKey]:
        if self._machine_down:
            down = self._machine_down
            return [s for s in self._free[phase] if s.machine not in down]
        return list(self._free[phase])

    def slot_occupant(self, slot: SlotKey) -> TaskAttempt | None:
        return self._occupied.get(slot)

    def occupied_slots(self, phase: Phase) -> dict[SlotKey, TaskAttempt]:
        # Returned dict is live state — schedulers must treat it read-only.
        return self._occupied_by_phase[phase]

    def machine_suspended_count(self, machine: int) -> int:
        return self._susp_count.get(machine, 0)

    def machine_suspended_bytes(self, machine: int) -> int:
        return self._susp_bytes.get(machine, 0)

    def total_suspended_bytes(self) -> int:
        return self._susp_total

    # ------------------------------------------------------------------
    # Event helpers
    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, payload: object) -> None:
        heapq.heappush(self._heap, (t, kind, next(self._seq), payload))

    def _bump(self, key: tuple) -> int:
        self._epoch[key] = self._epoch.get(key, 0) + 1
        return self._epoch[key]

    def _job_state(self, job_id: int) -> JobState:
        return self.scheduler.jobs[job_id]

    # ------------------------------------------------------------------
    # Action application
    # ------------------------------------------------------------------
    def _apply(self, action: Action) -> None:
        now = self._now
        if isinstance(action, Start):
            att, slot = action.attempt, action.slot
            assert att.state is TaskState.PENDING, (att.spec.key, att.state)
            assert slot in self._free[slot.phase], slot
            del self._free[slot.phase][slot]
            js = self._job_state(att.spec.job_id)
            js.transition(att, TaskState.RUNNING)
            att.machine = slot.machine
            att.started_at = now
            att.attempts += 1
            self._occupied[slot] = att
            self._occupied_by_phase[slot.phase][slot] = att
            self._slot_by_task[att.spec.key] = slot
            if js.first_dispatch_time is None:
                js.first_dispatch_time = now
                self.result.first_dispatch[att.spec.job_id] = now
            ep = self._bump(att.spec.key)
            if self._injector is not None:
                self._arm_fate(att, ep, now)
            rem = att.remaining
            if att.rate != 1.0:
                rem = rem / att.rate  # straggling attempt: dilated wall time
            self._push(now + rem, _COMPLETE, (att, ep))
            if (
                att.spec.phase is Phase.REDUCE
                and att.remaining > self.progress_delta
            ):
                self._push(now + self.progress_delta, _PROGRESS, (att, ep))
            self.scheduler.on_task_started(att, slot)
        elif isinstance(action, Resume):
            att, slot = action.attempt, action.slot
            assert att.state is TaskState.SUSPENDED, (att.spec.key, att.state)
            assert att.machine == slot.machine, "resume must be local (Sect 3.3)"
            assert slot in self._free[slot.phase], slot
            del self._free[slot.phase][slot]
            # Swap-in cost: roll back progress by the DMA latency.
            cost = self.spec.suspend_cost(att.spec.state_bytes)
            att.progress = max(0.0, att.progress - cost)
            self._job_state(att.spec.job_id).transition(att, TaskState.RUNNING)
            att.started_at = now
            att.attempts += 1
            self._occupied[slot] = att
            self._occupied_by_phase[slot.phase][slot] = att
            self._slot_by_task[att.spec.key] = slot
            self._susp_bytes[slot.machine] = self._susp_bytes.get(
                slot.machine, 0
            ) - att.spec.state_bytes
            self._susp_count[slot.machine] = (
                self._susp_count.get(slot.machine, 0) - 1
            )
            self._susp_total -= att.spec.state_bytes
            ep = self._bump(att.spec.key)
            if self._injector is not None:
                self._arm_fate(att, ep, now)
            rem = att.remaining
            if att.rate != 1.0:
                rem = rem / att.rate
            self._push(now + rem, _COMPLETE, (att, ep))
            self.scheduler.on_task_resumed(att, slot)
        elif isinstance(action, Suspend):
            att = action.attempt
            assert att.state is TaskState.RUNNING, (att.spec.key, att.state)
            slot = self._slot_by_task.pop(att.spec.key)
            del self._occupied[slot]
            del self._occupied_by_phase[slot.phase][slot]
            self._free[slot.phase][slot] = None
            elapsed = now - att.started_at
            if att.rate != 1.0:
                elapsed *= att.rate  # straggling attempt accrued work slower
            att.progress = min(att.spec.duration, att.progress + elapsed)
            self._job_state(att.spec.job_id).transition(att, TaskState.SUSPENDED)
            att.suspended_at = now
            self._bump(att.spec.key)
            m = att.machine if att.machine is not None else -1
            self._susp_bytes[m] = self._susp_bytes.get(m, 0) + att.spec.state_bytes
            self._susp_count[m] = self._susp_count.get(m, 0) + 1
            self._susp_total += att.spec.state_bytes
            if self._injector is not None:
                self._cancel_shadow(att.spec.key)
                att.rate = 1.0  # a later Resume draws a fresh fate
            self.scheduler.on_task_suspended(att)
        elif isinstance(action, Kill):
            att = action.attempt
            assert att.state is TaskState.RUNNING, (att.spec.key, att.state)
            slot = self._slot_by_task.pop(att.spec.key)
            del self._occupied[slot]
            del self._occupied_by_phase[slot.phase][slot]
            self._free[slot.phase][slot] = None
            att.progress = 0.0
            self._job_state(att.spec.job_id).transition(att, TaskState.PENDING)
            att.machine = None
            att.started_at = None
            self._bump(att.spec.key)
            if self._injector is not None:
                self._cancel_shadow(att.spec.key)
                att.rate = 1.0
            self.scheduler.on_task_killed(att)
        else:  # pragma: no cover - defensive
            raise TypeError(f"unknown action {action!r}")
        if self.action_listener is not None:
            self.action_listener(action, now)

    # ------------------------------------------------------------------
    # Live-service injection seam (repro.service).  Everything here is a
    # thin, deterministic wrapper over the ordinary event heap: a live
    # session and its journal replay push the exact same events in the
    # exact same order, so the twin's schedule is bit-identical.
    # ------------------------------------------------------------------
    def submit(self, spec: JobSpec) -> None:
        """Inject a dynamic job arrival (live master admission path).

        The job must arrive at or after the current simulation time and
        must not reuse a known job id.  Jobs passed to the constructor
        are seeded by ``run()``; ``submit`` is for arrivals that become
        known only while the simulation is underway.
        """
        if spec.arrival_time < self._now:
            raise ValueError(
                f"job {spec.job_id} arrival {spec.arrival_time} is in "
                f"the past (now={self._now})"
            )
        if spec.job_id in self.scheduler.jobs:
            raise ValueError(f"duplicate job id {spec.job_id}")
        self._arrivals_left += 1
        self._push(spec.arrival_time, _ARRIVAL, spec)

    def inject_fault(self, t: float, kind: str, machine: int) -> None:
        """Schedule a *scripted* machine fault at simulation time ``t``.

        ``kind`` is ``"crash"`` or ``"recover"``.  Unlike the stochastic
        crash/recover chain, scripted events do not regenerate (a
        scripted crash schedules no recovery and vice versa) and are
        never moot — the live service maps worker death onto ``crash``
        and worker rejoin onto ``recover``, and those must take effect
        even on an idle cluster.  Requires an armed fault layer
        (``FaultModel(external=True)`` suffices).
        """
        if self._injector is None:
            raise RuntimeError(
                "scripted faults need an armed fault layer — construct "
                "with SimConfig(faults=FaultModel(external=True, ...))"
            )
        if kind not in ("crash", "recover"):
            raise ValueError(f"unknown scripted fault kind {kind!r}")
        if t < self._now:
            raise ValueError(f"scripted fault at {t} is in the past "
                             f"(now={self._now})")
        self._push(t, _FAULT, (f"x{kind}", machine))

    def set_event_epsilon(self, eps: float) -> None:
        """Retune the coalescing window width mid-run (live service:
        the auto-epsilon controller tracks arrival burstiness).

        Only legal while no window is open — ``run(until=...)`` always
        flushes the open window before returning, so the live loop can
        retune after any advance.  The change is journaled as an event
        so the twin replay retunes at the identical point.
        """
        if eps < 0:
            raise ValueError(f"event_epsilon must be >= 0, got {eps}")
        if self._window_end is not None:  # pragma: no cover - defensive
            raise RuntimeError(
                "cannot retune event_epsilon with a coalescing window "
                "open; call run(until=now) first"
            )
        self.event_epsilon = float(eps)

    # ------------------------------------------------------------------
    # Event processing
    # ------------------------------------------------------------------
    def _on_arrival(self, spec: JobSpec) -> None:
        self._arrivals_left -= 1
        self.result.arrival[spec.job_id] = self._now
        self.scheduler.on_job_arrival(spec, self._now)
        # Jobs with no tasks at all complete immediately.
        js = self._job_state(spec.job_id)
        if js.is_done():
            self._complete_job(js)

    def _on_complete(self, att: TaskAttempt, epoch: int) -> None:
        if self._epoch.get(att.spec.key) != epoch:
            return  # stale (task was suspended/killed since)
        if att.state is not TaskState.RUNNING:
            return
        slot = self._slot_by_task.pop(att.spec.key)
        del self._occupied[slot]
        del self._occupied_by_phase[slot.phase][slot]
        self._free[slot.phase][slot] = None
        att.progress = att.spec.duration
        self._job_state(att.spec.job_id).transition(att, TaskState.DONE)
        self._bump(att.spec.key)
        if self._injector is not None:
            att.rate = 1.0
            self._cancel_shadow(att.spec.key)  # primary won the race
            self._injector.note_success(att.machine)
            self._maybe_lose_sample(att)  # must precede on_task_complete
        self.scheduler.on_task_complete(att.spec.job_id, att.spec.key, self._now)
        js = self._job_state(att.spec.job_id)
        if js.is_done() and js.completion_time is None:
            self._complete_job(js)

    def _on_progress(self, att: TaskAttempt, epoch: int) -> None:
        if self._epoch.get(att.spec.key) != epoch:
            return
        if att.state is not TaskState.RUNNING:
            return
        elapsed = self._now - att.started_at
        # Fraction of this task's input processed so far.  A straggling
        # attempt accrues work at att.rate, but the scheduler still sees
        # wall-clock `elapsed` — exactly the skewed signal a real
        # heartbeat would deliver (the sigma = Delta/p estimator then
        # over-estimates the straggler's size, as on a real cluster).
        worked = att.progress + (
            elapsed if att.rate == 1.0 else elapsed * att.rate
        )
        fraction = min(1.0, worked / att.spec.duration)
        self.scheduler.on_task_progress(
            att.spec.job_id, att.spec.key, fraction, elapsed, self._now
        )

    # ------------------------------------------------------------------
    # Fault layer (repro.core.faults; see docs/faults.md).  Nothing below
    # is reachable when SimConfig.faults is disabled — zero-fault runs
    # stay bit-identical to pre-fault builds.
    # ------------------------------------------------------------------
    def _arm_fate(self, att: TaskAttempt, epoch: int, now: float) -> None:
        """Draw the (re)started attempt's fate and schedule its injected
        failure and/or speculative-execution check."""
        inj = self._injector
        fail_at, rate = inj.attempt_fate(att)
        att.rate = rate
        if rate != 1.0:
            inj.stats["stragglers"] += 1
            inj.record(now, "straggle", att.spec.key, att.attempts)
            if inj.model.speculation:
                # When a nominal-speed attempt would have finished, check
                # whether a speculative copy is worth launching.
                self._push(
                    now + att.remaining, _FAULT, ("spec_check", att, epoch)
                )
        if fail_at is not None:
            if att.failures < inj.model.max_task_retries:
                wall = att.remaining * fail_at / rate
                self._push(now + wall, _FAULT, ("taskfail", att, epoch))
            else:
                # Retry budget spent: stop injecting new failures into
                # this task — it reruns cleanly to completion, so no job
                # is ever lost — and account for the suppression.
                inj.stats["retries_exhausted"] += 1

    def _maybe_lose_sample(self, att: TaskAttempt) -> None:
        """Estimation-sample loss: drop this completed attempt's duration
        observation before the TrainingModule records it."""
        inj = self._injector
        if inj.model.sample_loss_rate <= 0.0:
            return
        tr = getattr(self.scheduler, "training", None)
        if tr is None:
            return
        jid, phase = att.spec.job_id, att.spec.phase
        if not tr.is_training(jid, phase):
            return
        if att.spec.key not in tr.sample_keys(jid, phase):
            return
        if inj.sample_lost(att):
            inj.stats["sample_losses"] += 1
            inj.record(self._now, "sample_lost", att.spec.key)
            self.scheduler.on_sample_lost(att)

    def _cancel_shadow(self, key: tuple) -> None:
        """Tear down the speculative copy of ``key`` — its primary
        completed, suspended, was killed, failed, or crashed out from
        under it (so any pending spec_done event is now moot)."""
        rec = self._spec_running.pop(key, None)
        if rec is None:
            return
        slot, started, _gen = rec
        self._free[slot.phase][slot] = None
        inj = self._injector
        inj.stats["work_lost_s"] += max(0.0, self._now - started)
        inj.stats["speculative_losses"] += 1
        inj.record(self._now, "spec_cancel", key)

    def _fail_task(self, att: TaskAttempt, reason: str) -> None:
        """Fail one live attempt (injected failure or machine crash):
        discard its progress, hand it to the scheduler as FAILED, and
        schedule the re-admission after the capped exponential backoff."""
        now = self._now
        inj = self._injector
        js = self._job_state(att.spec.job_id)
        if att.state is TaskState.RUNNING:
            slot = self._slot_by_task.pop(att.spec.key)
            del self._occupied[slot]
            del self._occupied_by_phase[slot.phase][slot]
            self._free[slot.phase][slot] = None
            elapsed = now - att.started_at
            if att.rate != 1.0:
                elapsed *= att.rate
            inj.stats["work_lost_s"] += att.progress + max(0.0, elapsed)
            self._cancel_shadow(att.spec.key)
        elif att.state is TaskState.SUSPENDED:
            # The swapped-out context dies with its host machine.
            m = att.machine if att.machine is not None else -1
            self._susp_bytes[m] = (
                self._susp_bytes.get(m, 0) - att.spec.state_bytes
            )
            self._susp_count[m] = self._susp_count.get(m, 0) - 1
            self._susp_total -= att.spec.state_bytes
            inj.stats["work_lost_s"] += att.progress
        else:  # pragma: no cover - callers only fail live attempts
            return
        att.progress = 0.0
        att.rate = 1.0
        att.failures += 1
        # Transition BEFORE clearing att.machine: the leaving-SUSPENDED
        # index removal in JobState.transition is machine-keyed.
        js.transition(att, TaskState.FAILED)
        att.machine = None
        att.started_at = None
        self._bump(att.spec.key)
        self.scheduler.on_task_failed(att)
        inj.record(now, reason, att.spec.key, att.failures)
        inj.stats["retries"] += 1
        self._push(
            now + inj.backoff(att.failures), _FAULT,
            ("readmit", att, att.failures),
        )

    def _fault_moot(self, payload: tuple) -> bool:
        """Whether a popped _FAULT event is stale.  Checked before the
        event may advance the clock: a moot fault event must not inflate
        the makespan or regenerate further machine churn."""
        kind = payload[0]
        if kind in ("xcrash", "xrecover"):
            # Scripted (live-service) events never go stale: a worker
            # death must take the machine down even on an idle cluster.
            return False
        if kind in ("crash", "recover", "probation"):
            return self._arrivals_left == 0 and not self.scheduler._live
        if kind in ("taskfail", "spec_check"):
            att, ep = payload[1], payload[2]
            return (
                self._epoch.get(att.spec.key) != ep
                or att.state is not TaskState.RUNNING
            )
        if kind == "readmit":
            att, gen = payload[1], payload[2]
            return att.state is not TaskState.FAILED or att.failures != gen
        if kind == "spec_done":
            att, gen = payload[1], payload[2]
            rec = self._spec_running.get(att.spec.key)
            return rec is None or rec[2] != gen
        return False  # pragma: no cover - defensive

    def _on_fault(self, payload: tuple) -> None:
        kind = payload[0]
        if kind == "crash":
            self._on_machine_crash(payload[1])
        elif kind == "recover":
            self._on_machine_recover(payload[1])
        elif kind == "xcrash":
            self._on_machine_crash(payload[1], chain=False)
        elif kind == "xrecover":
            self._on_machine_recover(payload[1], chain=False)
        elif kind == "probation":
            self._on_probation_end(payload[1])
        elif kind == "taskfail":
            self._on_task_fail_event(payload[1])
        elif kind == "readmit":
            self._on_readmit(payload[1])
        elif kind == "spec_check":
            self._on_spec_check(payload[1], payload[2])
        elif kind == "spec_done":
            self._on_spec_done(payload[1])
        else:  # pragma: no cover - defensive
            raise ValueError(f"unknown fault event {kind!r}")

    def _on_machine_crash(self, m: int, chain: bool = True) -> None:
        inj = self._injector
        now = self._now
        was_up = m not in self._machine_down
        self._machine_down[m] = "crash"  # upgrades a blacklist entry
        inj.stats["machine_crashes"] += 1
        inj.record(now, "crash", m)
        # Fail every attempt RUNNING on the machine...
        for phase in (Phase.MAP, Phase.REDUCE):
            for slot, att in list(self._occupied_by_phase[phase].items()):
                if slot.machine == m:
                    inj.stats["crash_task_failures"] += 1
                    self._fail_task(att, "crash_taskfail")
        # ...every attempt SUSPENDED on it...
        for js in list(self.scheduler._live.values()):
            for phase in (Phase.MAP, Phase.REDUCE):
                bucket = js.suspended_by_machine(phase).get(m)
                for key in list(bucket) if bucket else ():
                    inj.stats["crash_task_failures"] += 1
                    self._fail_task(js.tasks[key], "crash_taskfail")
        # ...and every speculative shadow it hosted.
        for key, rec in list(self._spec_running.items()):
            if rec[0].machine == m:
                self._cancel_shadow(key)
        if was_up:
            self.scheduler.on_machine_crashed(m)
        # Scripted crashes (chain=False) schedule no recovery: the
        # machine stays down until an explicit scripted recover (live
        # service: until the worker rejoins).
        if chain:
            self._push(now + inj.next_recover_delay(m), _FAULT, ("recover", m))

    def _on_machine_recover(self, m: int, chain: bool = True) -> None:
        inj = self._injector
        if self._machine_down.get(m) == "crash":
            del self._machine_down[m]
            inj.stats["machine_recoveries"] += 1
            inj.record(self._now, "recover", m)
            self.scheduler.on_machine_recovered(m)
        # Chain the next outage regardless of blacklist state: the
        # crash/recover cadence is a property of the machine, not of its
        # blacklist state.  Scripted recoveries (chain=False) regenerate
        # nothing.
        if chain:
            self._push(
                self._now + inj.next_outage_delay(m), _FAULT, ("crash", m)
            )

    def _on_probation_end(self, m: int) -> None:
        inj = self._injector
        inj.end_probation(m)
        inj.stats["probations_ended"] += 1
        if self._machine_down.get(m) == "blacklist":
            del self._machine_down[m]
            inj.record(self._now, "unblacklist", m)
            self.scheduler.on_machine_recovered(m)

    def _on_task_fail_event(self, att: TaskAttempt) -> None:
        inj = self._injector
        m = att.machine
        inj.stats["task_failures"] += 1
        self._fail_task(att, "taskfail")
        # Injected failures strike the hosting machine; crash-induced
        # ones don't (the machine is already down and not at fault).
        if m is not None and inj.note_injected_failure(m):
            if m not in self._machine_down:
                self._machine_down[m] = "blacklist"
                inj.stats["blacklists"] += 1
                inj.record(self._now, "blacklist", m)
                self._push(
                    self._now + inj.model.probation_s, _FAULT,
                    ("probation", m),
                )
                self.scheduler.on_machine_crashed(m)

    def _on_readmit(self, att: TaskAttempt) -> None:
        """Re-admission backoff served: FAILED -> PENDING."""
        self._job_state(att.spec.job_id).transition(att, TaskState.PENDING)
        self._injector.record(self._now, "readmit", att.spec.key)
        self.scheduler.on_task_readmitted(att)

    def _on_spec_check(self, att: TaskAttempt, epoch: int) -> None:
        """A straggling attempt outlived its nominal completion time:
        launch a speculative copy on a spare slot, or keep checking."""
        inj = self._injector
        key = att.spec.key
        if key in self._spec_running:
            return  # pragma: no cover - single spec_check per epoch
        # Work the straggler still has left, in nominal seconds.
        worked = att.progress + (self._now - att.started_at) * att.rate
        remaining = att.spec.duration - worked
        if remaining <= inj.model.speculation_min_remaining:
            return
        if att.spec.duration >= remaining / att.rate:
            return  # a from-scratch copy would lose the race anyway
        phase = att.spec.phase
        slots = self.free_slots(phase)
        slot = next(
            (s for s in slots if s.machine != att.machine),
            slots[0] if slots else None,
        )
        if slot is None:
            # No spare capacity right now: check again next heartbeat.
            self._push(
                self._now + self.heartbeat, _FAULT,
                ("spec_check", att, epoch),
            )
            return
        del self._free[phase][slot]
        gen = next(self._spec_seq)
        self._spec_running[key] = (slot, self._now, gen)
        inj.stats["speculative_launches"] += 1
        inj.record(self._now, "spec_launch", key, slot.machine)
        self._push(
            self._now + att.spec.duration, _FAULT, ("spec_done", att, gen)
        )

    def _on_spec_done(self, att: TaskAttempt) -> None:
        """The speculative copy finished first and wins the race: the
        straggling primary is killed, the task completes on the shadow's
        machine."""
        inj = self._injector
        key = att.spec.key
        slot, _started, _gen = self._spec_running.pop(key)
        self._free[slot.phase][slot] = None
        # The primary is guaranteed RUNNING here: any suspend / kill /
        # fail / complete of it cancels the shadow, mooting this event.
        assert att.state is TaskState.RUNNING, (key, att.state)
        pslot = self._slot_by_task.pop(key)
        del self._occupied[pslot]
        del self._occupied_by_phase[pslot.phase][pslot]
        self._free[pslot.phase][pslot] = None
        elapsed = (self._now - att.started_at) * att.rate
        inj.stats["work_lost_s"] += att.progress + max(0.0, elapsed)
        inj.stats["speculative_wins"] += 1
        inj.record(self._now, "spec_win", key, slot.machine)
        att.progress = att.spec.duration
        att.rate = 1.0
        js = self._job_state(att.spec.job_id)
        js.transition(att, TaskState.DONE)
        att.machine = slot.machine
        self._bump(key)
        inj.note_success(slot.machine)
        self._maybe_lose_sample(att)
        self.scheduler.on_task_complete(att.spec.job_id, key, self._now)
        if js.is_done() and js.completion_time is None:
            self._complete_job(js)

    def _complete_job(self, js: JobState) -> None:
        js.completion_time = self._now
        self.result.completion[js.spec.job_id] = self._now
        self.result.locality_hits += js.locality_hits
        self.result.locality_misses += js.locality_misses
        self.scheduler.on_job_complete(js.spec.job_id, self._now)
        if self.completion_listener is not None:
            self.completion_listener(js.spec.job_id, self._now)

    def _live_jobs_exist(self) -> bool:
        return bool(self.scheduler._live)

    def _sample_timeline(self) -> None:
        if not self.track_timeline:
            return
        counts: dict[tuple[int, Phase], int] = {}
        for att in self._occupied.values():
            k = (att.spec.job_id, att.spec.phase)
            counts[k] = counts.get(k, 0) + 1
        for (jid, phase), n in sorted(counts.items()):
            self.result.timeline.append((self._now, jid, phase.value, n))

    def _run_pass(self) -> None:
        """Close any open coalescing window, run one scheduling pass at
        the current time, apply its actions, and keep the heartbeat
        armed."""
        self._window_end = None
        self.passes += 1
        for action in self.scheduler.schedule(self, self._now):
            self._apply(action)
        self._sample_timeline()
        if self._live_jobs_exist() and not self._tick_pending:
            self._push(self._now + self.heartbeat, _TICK, None)
            self._tick_pending = True

    # ------------------------------------------------------------------
    def run(self, until: float = math.inf, max_events: int | None = None) -> SimResult:
        """Run (or incrementally continue) the simulation up to ``until``."""
        if not getattr(self, "_arrivals_seeded", False):
            self._arrivals_seeded = True
            for spec in self._jobs:
                self._push(spec.arrival_time, _ARRIVAL, spec)
            inj = self._injector
            if inj is not None and inj.model.machine_mtbf > 0.0:
                # Seed each machine's first outage; crash/recover chains
                # regenerate from there (repro.core.faults).
                for m in range(self.spec.num_machines):
                    self._push(inj.next_outage_delay(m), _FAULT, ("crash", m))
        n_events = 0
        eps = self.event_epsilon
        while self._heap:
            # Barrier check first: it processes no event, so it neither
            # consumes the max_events budget nor may the budget preempt
            # the flush — callers always observe fully-scheduled state
            # at `until`.
            if self._heap[0][0] > until:
                if self._window_end is not None:
                    # A prior slice left a window open and this run's
                    # barrier is before the window's next event: flush
                    # the deferred pass, exactly where an unsliced
                    # run(until) would have placed it.
                    self._run_pass()
                break
            n_events += 1
            if max_events is not None and n_events > max_events:
                raise EventLimitReached(
                    f"simulator exceeded {max_events} events at t={self._now}"
                    " — scheduler livelock?"
                )
            t, kind, _, payload = heapq.heappop(self._heap)
            if kind == _FAULT and self._fault_moot(payload):
                # Dropped before the clock moves: a stale fault event
                # must neither inflate the makespan nor re-arm machine
                # churn after the workload has drained.
                continue
            self.events_processed += 1
            if eps > 0.0 and self._window_end is None:
                # New coalescing window, anchored at its head event.
                self._window_end = t + eps
            self._now = max(self._now, t)
            # State mutations apply at their own event time, in stable
            # (time, kind, seq) heap order — identical to the eps=0 loop.
            if kind == _ARRIVAL:
                self._on_arrival(payload)
            elif kind == _COMPLETE:
                self._on_complete(*payload)
            elif kind == _PROGRESS:
                self._on_progress(*payload)
            elif kind == _TICK:
                self._tick_pending = False
                self.scheduler.on_tick(self._now)
            elif kind == _FAULT:
                self._on_fault(payload)
            # Coalesce before scheduling a pass: with eps > 0, any event
            # inside the open window; with eps = 0 (legacy), only
            # same-timestamp ARRIVAL/COMPLETE batches.
            if self._heap and self._heap[0][0] <= until:
                if eps > 0.0:
                    if self._heap[0][0] <= self._window_end:
                        continue
                elif self._heap[0][0] <= self._now and (
                    self._heap[0][1] in (_ARRIVAL, _COMPLETE)
                ):
                    continue
            self._run_pass()
        self.result.stats = self.scheduler.stats
        if self._injector is not None:
            self.result.faults = self._injector.stats_dict()
        self.result.makespan = self._now
        self.result.passes = self.passes
        self.result.events = self.events_processed
        return self.result
