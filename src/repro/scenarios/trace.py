"""Versioned JSONL trace schema: export, import, and replay adapters.

A *trace* is a fully-materialized workload — every job with its exact
arrival time and per-task durations/placement — serialized one job per
line so external traces (or previously-synthesized golden workloads) run
through the same :class:`~repro.core.simulator.Simulator` as a
first-class scenario (``WorkloadAxis(kind="trace", trace_path=...)``).

Format (JSON Lines):

* line 1 — header::

      {"kind": "repro-trace", "version": 1, "meta": {...}}

  ``meta`` is free-form provenance (generator name/seed, suggested
  cluster shape, job classes).
* lines 2.. — one job each::

      {"job_id": 0, "arrival_time": 1.5, "name": "fb-small-0",
       "weight": 1.0, "reduce_slowstart": 1.0,
       "map":    [[duration, [input_hosts...], state_bytes], ...],
       "reduce": [[duration, [],               state_bytes], ...]}

Round-trip fidelity is *bit-exact*: floats are emitted via ``json`` (which
uses ``repr`` — the shortest string that parses back to the identical
IEEE-754 double), so export -> import -> replay reproduces the original
schedule to the last bit (pinned by tests/test_scenarios.py).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.types import JobSpec, Phase, TaskSpec

TRACE_KIND = "repro-trace"
TRACE_VERSION = 1


def export_trace(
    path: str | Path,
    jobs: list[JobSpec],
    class_of: dict[int, str] | None = None,
    meta: dict | None = None,
) -> Path:
    """Write ``jobs`` as a versioned JSONL trace; returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    header = {
        "kind": TRACE_KIND,
        "version": TRACE_VERSION,
        "meta": dict(meta or {}),
    }
    if class_of is not None:
        # JSON object keys are strings; parse back to int on load.
        header["class_of"] = {str(j): c for j, c in class_of.items()}
    with path.open("w") as f:
        f.write(json.dumps(header, sort_keys=True) + "\n")
        for job in sorted(jobs, key=lambda j: (j.arrival_time, j.job_id)):
            f.write(json.dumps(_job_record(job), sort_keys=True) + "\n")
    return path


def load_trace(
    path: str | Path,
) -> tuple[list[JobSpec], dict[int, str], dict]:
    """Read a JSONL trace; returns (jobs, class_of, meta)."""
    path = Path(path)
    with path.open() as f:
        first = f.readline()
        if not first.strip():
            raise ValueError(f"{path}: empty trace file")
        header = json.loads(first)
        if header.get("kind") != TRACE_KIND:
            raise ValueError(
                f"{path}: not a {TRACE_KIND} file (kind={header.get('kind')!r})"
            )
        if header.get("version") != TRACE_VERSION:
            raise ValueError(
                f"{path}: trace version {header.get('version')!r} != "
                f"supported {TRACE_VERSION}"
            )
        jobs = []
        for ln in f:
            if not ln.strip():
                continue
            d = json.loads(ln)
            if "event" in d:
                # Live-session journal entry (repro.service.journal):
                # advance barriers, scripted faults, epsilon retunes.
                # Skipping them makes a journal double as a plain trace
                # (the recorded workload replays as a scenario cell).
                continue
            jobs.append(_job_from_record(d))
    class_of = {int(j): c for j, c in header.get("class_of", {}).items()}
    return jobs, class_of, header.get("meta", {})


# ---------------------------------------------------------------------------
# (de)serialization of one job
# ---------------------------------------------------------------------------
def _task_record(t: TaskSpec) -> list:
    return [t.duration, list(t.input_hosts), t.state_bytes]


def _job_record(job: JobSpec) -> dict:
    return {
        "job_id": job.job_id,
        "arrival_time": job.arrival_time,
        "name": job.name,
        "weight": job.weight,
        "reduce_slowstart": job.reduce_slowstart,
        "map": [_task_record(t) for t in job.map_tasks],
        "reduce": [_task_record(t) for t in job.reduce_tasks],
    }


def _tasks_from_records(
    job_id: int, phase: Phase, records: list
) -> tuple[TaskSpec, ...]:
    return tuple(
        TaskSpec(
            job_id=job_id,
            phase=phase,
            index=i,
            duration=float(dur),
            input_hosts=tuple(int(h) for h in hosts),
            state_bytes=int(state_bytes),
        )
        for i, (dur, hosts, state_bytes) in enumerate(records)
    )


def _job_from_record(d: dict) -> JobSpec:
    jid = int(d["job_id"])
    return JobSpec(
        job_id=jid,
        arrival_time=float(d["arrival_time"]),
        map_tasks=_tasks_from_records(jid, Phase.MAP, d.get("map", [])),
        reduce_tasks=_tasks_from_records(jid, Phase.REDUCE, d.get("reduce", [])),
        weight=float(d.get("weight", 1.0)),
        name=d.get("name", ""),
        reduce_slowstart=float(d.get("reduce_slowstart", 1.0)),
    )


# Public aliases for the live-service journal (repro.service.journal),
# which writes job lines in this exact schema so a recorded session is
# itself a loadable trace.  Unknown keys (the journal's "user"/"tag"
# annotations) are ignored by job_from_record by construction.
job_record = _job_record
job_from_record = _job_from_record
