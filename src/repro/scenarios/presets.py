"""Named scenario presets — the paper's evaluation matrix (Sect. 4).

Each preset is a :class:`~repro.scenarios.spec.SweepSpec` factory; the
registry maps the name you pass to ``python -m repro.scenarios run`` to
the sweep it expands into.  Presets are plain data: benchmarks
(``benchmarks/bench_sojourn.py`` etc.) expand the same presets instead of
hand-rolling their own simulate-and-summarize loops.

Register project-specific presets with :func:`register_preset`::

    @register_preset("my-experiment")
    def _my_experiment() -> SweepSpec:
        ...
"""

from __future__ import annotations

from typing import Callable

from repro.scenarios.spec import (
    ClusterAxis,
    ScenarioSpec,
    SchedulerAxis,
    SweepSpec,
    WorkloadAxis,
)

_PRESETS: dict[str, Callable[[], SweepSpec]] = {}


def register_preset(name: str):
    """Decorator: register a SweepSpec factory under ``name``."""

    def deco(fn: Callable[[], SweepSpec]):
        _PRESETS[name] = fn
        return fn

    return deco


def list_presets() -> list[str]:
    return sorted(_PRESETS)


def get_preset(name: str) -> SweepSpec:
    try:
        factory = _PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown preset {name!r}; available: {', '.join(list_presets())}"
        ) from None
    return factory()


def quick_sweep(sweep: SweepSpec) -> SweepSpec:
    """Reduced-scale variant of a sweep (same matrix, smaller trace)."""
    return SweepSpec(
        name=sweep.name + "@quick", base=sweep.base.quick(), grids=sweep.grids
    )


#: The paper's FB-dataset base cell: 100 SWIM-synthesized jobs on the
#: 100-machine Amazon cluster (Sect. 4.1), HFSP with paper defaults.
def paper_fb_base(seed: int = 0) -> ScenarioSpec:
    return ScenarioSpec(
        name="paper-fb",
        workload=WorkloadAxis(kind="fb", seed=seed, num_jobs=100),
        cluster=ClusterAxis(num_machines=100),
        scheduler=SchedulerAxis(policy="hfsp"),
    )


@register_preset("paper-fb")
def _paper_fb() -> SweepSpec:
    """Sect. 4.2 / Fig. 3: FIFO vs FAIR vs HFSP sojourn on the FB trace."""
    return SweepSpec(
        name="paper-fb",
        base=paper_fb_base(),
        grids=(
            SweepSpec.grid(**{"scheduler.policy": ("fifo", "fair", "hfsp")}),
        ),
    )


@register_preset("paper-cluster-size")
def _paper_cluster_size() -> SweepSpec:
    """Fig. 5: mean sojourn vs cluster size (10..100 machines), FAIR vs
    HFSP — scarcity grows HFSP's advantage."""
    return SweepSpec(
        name="paper-cluster-size",
        # num_hosts pinned: the SAME workload (placement + RNG stream) at
        # every swept cluster size — only scarcity varies.
        base=paper_fb_base().override(**{"workload.num_hosts": 100}),
        grids=(
            SweepSpec.grid(**{
                "cluster.num_machines": (10, 20, 30, 50, 70, 100),
                "scheduler.policy": ("fair", "hfsp"),
            }),
        ),
    )


@register_preset("paper-estimation-error")
def _paper_estimation_error() -> SweepSpec:
    """Fig. 6: HFSP robustness to size-estimation error on the MAP-only FB
    variant (Sect. 4.3), alpha x error-seed grid + an error-independent
    FAIR reference cell (non-rectangular: two grids)."""
    base = paper_fb_base().override(**{"workload.map_only": True})
    return SweepSpec(
        name="paper-estimation-error",
        base=base,
        grids=(
            SweepSpec.grid(**{
                "scheduler.error_alpha": (0.0, 0.1, 0.25, 0.5, 0.75, 1.0),
                "scheduler.error_seed": (0, 1, 2, 3, 4),
            }),
            SweepSpec.grid(**{"scheduler.policy": ("fair",)}),
        ),
    )


@register_preset("paper-estimation-error-disciplines")
def _paper_estimation_error_disciplines() -> SweepSpec:
    """Beyond-paper: the headline comparison of "Revisiting Size-Based
    Scheduling with Estimated Job Sizes" / PSBS (Dell'Amico et al.) —
    discipline x estimation-error on the MAP-only FB variant.  SRPT
    ranks by raw estimated remaining size and degrades as error grows
    (underestimated jobs clamp to zero remaining and camp at the head of
    the order); the FSP family (hfsp, psbs) absorbs error through the
    virtual cluster's relative progression; LAS never looks at sizes and
    is the error-independent reference (single cell, second grid).  All
    four resolve through the discipline registry — add a registered
    discipline to the grid and it sweeps identically."""
    base = paper_fb_base().override(**{"workload.map_only": True})
    return SweepSpec(
        name="paper-estimation-error-disciplines",
        base=base,
        grids=(
            SweepSpec.grid(**{
                "scheduler.policy": ("hfsp", "srpt", "psbs"),
                "scheduler.error_alpha": (0.0, 0.5, 1.0),
            }),
            SweepSpec.grid(**{"scheduler.policy": ("las",)}),
        ),
    )


@register_preset("paper-psbs-calibration")
def _paper_psbs_calibration() -> SweepSpec:
    """Beyond-paper: calibrate PSBS's two knobs under estimation error
    *heavier* than the Fig. 6 sweep ever applies (alpha 1.5 / 2.0 vs the
    FB sweep's max of 1.0 — at alpha > 1 the multiplicative error can
    drive estimates to (almost) zero, the regime PSBS was designed for).
    Grid 1 sweeps ``late_factor`` (how aggressively the virtual cluster
    ages jobs whose real progress outruns their estimate) x
    ``max_spread`` (rank-stability hysteresis window: 0 = re-rank on any
    verdict flip, 3 = tolerate small spreads before preempting) x error
    alpha.  Grid 2 runs hfsp and las at the same alphas as references —
    hfsp shares the virtual-cluster machinery without late aging, las
    never reads sizes at all.  Each cell's ``whatif`` block reports the
    swept knob values (``late_factor`` / ``max_spread``), so the report
    matrix is self-describing."""
    base = paper_fb_base().override(**{
        "workload.map_only": True,
        "scheduler.policy": "psbs",
        "name": "paper-psbs-calibration",
    })
    return SweepSpec(
        name="paper-psbs-calibration",
        base=base,
        grids=(
            SweepSpec.grid(**{
                "scheduler.psbs_late_factor": (0.5, 1.0, 2.0),
                "scheduler.psbs_max_spread": (0, 3),
                "scheduler.error_alpha": (1.5, 2.0),
            }),
            SweepSpec.grid(**{
                "scheduler.policy": ("hfsp", "las"),
                "scheduler.error_alpha": (1.5, 2.0),
            }),
        ),
    )


@register_preset("paper-fb-eps")
def _paper_fb_eps() -> SweepSpec:
    """Beyond-paper: the Fig. 3 comparison under epsilon-window event
    coalescing (arXiv 1306.6023's batching design) — policy x epsilon
    grid reporting the sojourn-vs-scheduler-overhead tradeoff per cell
    (each report carries ``scheduler_passes`` / ``passes_per_event``;
    eps=0 cells are bit-identical to ``paper-fb``)."""
    return SweepSpec(
        name="paper-fb-eps",
        base=paper_fb_base(),
        grids=(
            SweepSpec.grid(**{
                "scheduler.policy": ("fifo", "fair", "hfsp"),
                "event_epsilon": (0.0, 0.5, 2.0),
            }),
        ),
    )


@register_preset("paper-preemption")
def _paper_preemption() -> SweepSpec:
    """Sect. 4.4 axis on the FB trace: HFSP under EAGER / WAIT / KILL."""
    return SweepSpec(
        name="paper-preemption",
        base=paper_fb_base(),
        grids=(
            SweepSpec.grid(**{
                "scheduler.preemption": ("eager", "wait", "kill"),
            }),
        ),
    )


@register_preset("seed-robustness")
def _seed_robustness() -> SweepSpec:
    """Beyond-paper: the Fig. 3 comparison across workload seeds 0-5 —
    is the HFSP win an artifact of one synthesized trace?"""
    return SweepSpec(
        name="seed-robustness",
        base=paper_fb_base(),
        grids=(
            SweepSpec.grid(**{
                "scheduler.policy": ("fifo", "fair", "hfsp"),
                "workload.seed": (0, 1, 2, 3, 4, 5),
            }),
        ),
    )


@register_preset("paper-faults")
def _paper_faults() -> SweepSpec:
    """Beyond-paper robustness matrix: scheduling under machine churn,
    task failures, stragglers, and estimation-sample loss (see
    docs/faults.md).  Grid 1 sweeps failure intensity x policy — does the
    HFSP win survive a hostile cluster, and at what goodput?  Grid 2
    holds a mid-intensity fault bundle fixed and sweeps the preemption
    primitive (KILL discards progress a failure-heavy regime already
    taxes; EAGER's suspended state dies with crashed machines).  Every
    cell is bit-reproducible: the fault trace derives from
    ``faults.seed``, never from global RNG state."""
    base = paper_fb_base().override(**{
        "faults.seed": 7,
        "faults.machine_mtbf": 3000.0,
        "faults.machine_mttr": 120.0,
        "faults.straggler_prob": 0.05,
        "faults.straggler_factor": 4.0,
        "faults.sample_loss_rate": 0.1,
        "name": "paper-faults",
    })
    return SweepSpec(
        name="paper-faults",
        base=base,
        grids=(
            SweepSpec.grid(**{
                "faults.task_fail_rate": (0.02, 0.1),
                "scheduler.policy": ("hfsp", "fifo", "fair", "srpt", "psbs"),
            }),
            SweepSpec.grid(**{
                "faults.task_fail_rate": (0.05,),
                "scheduler.preemption": ("eager", "wait", "kill"),
            }),
        ),
    )


@register_preset("ml-workload")
def _ml_workload() -> SweepSpec:
    """Beyond-paper: the TPU-adaptation ML workload under all policies."""
    return SweepSpec(
        name="ml-workload",
        base=ScenarioSpec(
            name="ml-workload",
            workload=WorkloadAxis(kind="ml", num_jobs=40),
            cluster=ClusterAxis(
                num_machines=8, map_slots=2, reduce_slots=1,
                dma_bandwidth=60e9,
            ),
        ),
        grids=(
            SweepSpec.grid(**{"scheduler.policy": ("fifo", "fair", "hfsp")}),
        ),
    )
