"""Direct unit tests for repro/core/metrics.py.

The metrics were previously exercised only through the benchmarks; these
pin their contracts (exact ECDF shape, percentile conventions, per-class
grouping, delta sign) so the scenario report layer can rely on them.
"""

import math

import numpy as np
import pytest

from repro.core.metrics import (
    SojournSummary,
    ecdf,
    ecdf_quantiles,
    per_class_sojourns,
    per_job_delta,
    slowdowns,
    summarize,
)
from repro.core.simulator import SimResult


def _result(arrival: dict, completion: dict) -> SimResult:
    res = SimResult()
    res.arrival.update(arrival)
    res.completion.update(completion)
    return res


# ---------------------------------------------------------------------------
# ecdf
# ---------------------------------------------------------------------------
def test_ecdf_sorted_values_and_uniform_steps():
    xs, ps = ecdf([3.0, 1.0, 2.0, 2.0])
    assert np.array_equal(xs, [1.0, 2.0, 2.0, 3.0])
    assert np.allclose(ps, [0.25, 0.5, 0.75, 1.0])


def test_ecdf_single_value():
    xs, ps = ecdf([7.0])
    assert np.array_equal(xs, [7.0])
    assert np.array_equal(ps, [1.0])


def test_ecdf_quantiles_keys_and_monotonicity():
    q = ecdf_quantiles(list(range(101)))
    assert set(q) == {"p5", "p25", "p50", "p75", "p90", "p95", "p99"}
    assert q["p50"] == 50.0
    vals = [q[k] for k in ("p5", "p25", "p50", "p75", "p90", "p95", "p99")]
    assert vals == sorted(vals)


def test_ecdf_quantiles_empty():
    assert ecdf_quantiles([]) == {
        k: 0.0 for k in ("p5", "p25", "p50", "p75", "p90", "p95", "p99")
    }


# ---------------------------------------------------------------------------
# SojournSummary.of
# ---------------------------------------------------------------------------
def test_sojourn_summary_of_basic():
    s = SojournSummary.of([1.0, 2.0, 3.0, 4.0])
    assert s.mean == 2.5
    assert s.median == 2.5
    assert s.count == 4
    assert s.p95 == pytest.approx(np.percentile([1, 2, 3, 4], 95))


def test_sojourn_summary_of_empty_is_zeros():
    s = SojournSummary.of([])
    assert (s.mean, s.median, s.p95, s.count) == (0.0, 0.0, 0.0, 0)


# ---------------------------------------------------------------------------
# per_class_sojourns / summarize
# ---------------------------------------------------------------------------
def test_per_class_sojourns_groups_and_unknown_class():
    res = _result(
        arrival={0: 0.0, 1: 10.0, 2: 20.0, 3: 0.0},
        completion={0: 5.0, 1: 40.0, 2: 25.0, 3: 9.0},
    )
    per = per_class_sojourns(res, {0: "small", 1: "large", 2: "small"})
    assert per["small"] == [5.0, 5.0]
    assert per["large"] == [30.0]
    assert per["?"] == [9.0]  # job 3 has no class label


def test_per_class_sojourns_ignores_jobs_without_arrival():
    res = _result(arrival={0: 0.0}, completion={0: 5.0, 1: 50.0})
    per = per_class_sojourns(res, {0: "small", 1: "small"})
    assert per == {"small": [5.0]}


def test_summarize_includes_all_bucket():
    res = _result(
        arrival={0: 0.0, 1: 0.0}, completion={0: 10.0, 1: 30.0}
    )
    summ = summarize(res, {0: "small", 1: "large"})
    assert set(summ) == {"small", "large", "all"}
    assert summ["all"].mean == 20.0
    assert summ["small"].count == 1


# ---------------------------------------------------------------------------
# per_job_delta
# ---------------------------------------------------------------------------
def test_per_job_delta_sign_and_intersection():
    a = _result(arrival={0: 0.0, 1: 0.0, 2: 0.0}, completion={0: 20.0, 1: 15.0})
    b = _result(arrival={0: 0.0, 1: 0.0, 2: 0.0}, completion={0: 10.0, 1: 18.0, 2: 5.0})
    delta = per_job_delta(a, b)
    # Only jobs completed in BOTH runs appear; positive = b is better.
    assert set(delta) == {0, 1}
    assert delta[0] == 10.0
    assert delta[1] == -3.0


# ---------------------------------------------------------------------------
# slowdowns
# ---------------------------------------------------------------------------
def test_slowdowns_divides_by_serialized_size():
    res = _result(arrival={0: 0.0, 1: 0.0}, completion={0: 30.0, 1: 8.0})
    slow = slowdowns(res, {0: 10.0, 1: 16.0})
    assert slow[0] == 3.0
    assert slow[1] == 0.5  # parallel speedup -> slowdown below 1


def test_slowdowns_skips_nonpositive_sizes():
    res = _result(arrival={0: 0.0, 1: 0.0}, completion={0: 3.0, 1: 4.0})
    assert slowdowns(res, {0: 0.0}) == {}
