"""Composable scheduling disciplines (the Discipline API).

The paper observes that "the architecture underlying HFSP is suitable for
any size-based scheduling discipline" (Sect. 5): the scheduling *engine* —
demand-indexed passes, executor hooks, delay scheduling, the preemption
machinery, the Training module — is policy-agnostic, and what makes HFSP
"HFSP" is only *how jobs are ranked* (projected virtual-cluster finish
time) plus *how rank conflicts are resolved* (suspend/resume preemption
with hysteresis).  This module makes that composition explicit.  A
**discipline** is

* a :class:`RankPolicy`   — a total job order per phase (FSP virtual
  finish time, SRPT estimated remaining size, LAS attained service,
  arrival order, fair deficit);
* a :class:`PreemptionPolicy` — the preemption primitive (none /
  suspend-resume / drain-wait / kill-restart) plus hysteresis hooks
  that can veto a preemption (PSBS consults
  :meth:`~repro.core.hfsp.HFSPScheduler.rank_stability` here);
* an optional :class:`AgingPolicy` — how job priorities move with time
  (virtual-cluster PS progression, plain wall-clock attained service, or
  PSBS-style re-injection of *late* jobs whose virtual copy finished
  before the real one);

assembled by a :class:`DisciplineRegistry` that the scenario engine
resolves by name, so ``SweepSpec.grid(**{"scheduler.policy": ["hfsp",
"srpt", "las", "psbs"]})`` — or any third-party registration — just
works::

    from repro.core import disciplines

    class LargestFirstRank(disciplines.KeyedRankPolicy):
        name = "largest-first"
        needs_estimates = True

        def key(self, engine, js, phase, now):
            import math
            est = js.est_size.get(phase, math.inf)
            return (-est if math.isfinite(est) else math.inf,
                    js.spec.arrival_time, js.spec.job_id)

    disciplines.register("lpt", disciplines.engine_discipline(
        "lpt", LargestFirstRank, description="longest processing time first"
    ))

The built-in FIFO / FAIR / HFSP schedulers are registered here as thin
assemblies of the same parts (their rank keys live in this module; the
registry builders construct the exact scheduler objects the scenario
runner built before this API existed, so routing through the registry is
bit-identical on the golden conformance traces), and SRPT, LAS, and PSBS
are provided as the first new disciplines — the experimental axis of
"Revisiting Size-Based Scheduling with Estimated Job Sizes" and PSBS
(Dell'Amico et al., 2014).

Engine invariants a policy may rely on are documented in
``docs/disciplines.md``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

from repro.core.scheduler import Scheduler, job_sort_key_fifo
from repro.core.types import ClusterSpec, Phase, Preemption


# ---------------------------------------------------------------------------
# Rank policies: a total job order per phase
# ---------------------------------------------------------------------------
class RankPolicy:
    """Produces the per-phase total job order the engine schedules by.

    ``order_and_pos`` returns ``(order, pos_of)`` where ``order`` is the
    phase-live job ids in ascending rank (best-to-serve first) and
    ``pos_of`` maps job id -> index in ``order``.  The engine treats both
    as *pass-constant*: they are read once per pass and never mutated.

    Two capability flags tell the engine which subsystems to maintain:

    * ``needs_estimates`` — run the Training module (sample-task
      dispatch, size estimation, estimate-error injection);
    * ``uses_vcluster``   — maintain and age the per-phase virtual
      cluster (membership, size updates, lazy PS aging).

    ``invalidate(phase)`` is called by the engine after every structural
    event that can change rank keys or membership (arrivals, task
    completions, suspend/resume/kill materializations, estimate updates,
    REDUCE slow-start unlocks; ``phase=None`` means both phases).
    Policies that cache their order drop it here; policies whose order
    lives elsewhere (the virtual-cluster caches) may ignore it.
    """

    name = "rank"
    needs_estimates = True
    uses_vcluster = False

    def order_and_pos(
        self, engine, phase: Phase, now: float
    ) -> tuple[list[int], dict[int, int]]:
        raise NotImplementedError

    def invalidate(self, phase: Phase | None = None) -> None:
        pass


class VirtualFinishRank(RankPolicy):
    """FSP rank (Sect. 3.1): ascending projected finish time under the
    simulated max-min-fair PS virtual cluster.  The order lives in the
    virtual cluster's caches (valid across passes until the next
    structural event), so this policy carries no state of its own."""

    name = "virtual-finish"
    needs_estimates = True
    uses_vcluster = True

    def order_and_pos(self, engine, phase, now):
        vc = engine.vc[phase]
        return vc.schedule_order(now), vc.schedule_pos(now)


class KeyedRankPolicy(RankPolicy):
    """Rank by a per-job sort key over the phase-live set, with a
    per-phase order cache invalidated by the engine's structural hooks.

    Rank keys must be *event-constant*: derived only from state that
    changes at executor events (estimates, the attained-service
    counters, arrival metadata) — never from continuously-advancing
    quantities — so a cached order stays exact between events and a
    steady-state pass pays O(1) here (the same contract the virtual
    cluster's order cache relies on).
    """

    def __init__(self) -> None:
        self._order: dict[str, list[int] | None] = {
            Phase.MAP.value: None, Phase.REDUCE.value: None,
        }
        self._pos: dict[str, dict[int, int] | None] = {
            Phase.MAP.value: None, Phase.REDUCE.value: None,
        }

    def key(self, engine, js, phase: Phase, now: float) -> tuple:
        """Total-order sort key (ascending = scheduled first).  Must
        embed a deterministic tiebreak (arrival time, job id)."""
        raise NotImplementedError

    def order_and_pos(self, engine, phase, now):
        pv = phase.value
        order = self._order[pv]
        if order is None:
            jobs = engine.demand_union(phase)
            order = sorted(
                jobs, key=lambda j: self.key(engine, jobs[j], phase, now)
            )
            self._order[pv] = order
            self._pos[pv] = {j: i for i, j in enumerate(order)}
        return order, self._pos[pv]

    def invalidate(self, phase: Phase | None = None) -> None:
        if phase is None:
            for pv in self._order:
                self._order[pv] = None
                self._pos[pv] = None
        else:
            self._order[phase.value] = None
            self._pos[phase.value] = None


class SRPTRank(KeyedRankPolicy):
    """Shortest Remaining Processing Time on *estimated* sizes: rank =
    phase size estimate minus attained service.  Uses the Training
    module's online estimates (and inherits the estimate-error model),
    but not the virtual cluster — remaining work depletes with the real
    attained-service counters, not a PS emulation.  Underestimated jobs
    clamp to zero remaining and monopolize the head of the order — the
    known SRPT fragility under estimation error that the
    ``paper-estimation-error-disciplines`` preset reproduces."""

    name = "srpt-remaining"
    needs_estimates = True
    uses_vcluster = False

    def key(self, engine, js, phase, now):
        est = js.est_size.get(phase, math.inf)
        if math.isfinite(est):
            rem = max(
                0.0,
                est - engine.attained_service(js.spec.job_id, phase),
            )
        else:
            rem = math.inf
        return (rem, js.spec.arrival_time, js.spec.job_id)


class LASRank(KeyedRankPolicy):
    """Least Attained Service (FB / foreground-background): jobs that
    have received the least service rank first.  Needs no size estimates
    at all — the size-oblivious end of the size-based spectrum, the
    reference point for how much the estimates actually buy."""

    name = "las-attained"
    needs_estimates = False
    uses_vcluster = False

    def key(self, engine, js, phase, now):
        return (
            engine.attained_service(js.spec.job_id, phase),
            js.spec.arrival_time,
            js.spec.job_id,
        )


class ArrivalRank(KeyedRankPolicy):
    """Priority-weighted arrival order — the stock Hadoop FIFO key.  The
    FIFO scheduler's sorted queue is built on :meth:`key_of`; using the
    policy inside the preemptive engine yields a preemptive-FIFO
    discipline (not registered by default)."""

    name = "arrival"
    needs_estimates = False
    uses_vcluster = False

    key_of = staticmethod(job_sort_key_fifo)

    def key(self, engine, js, phase, now):
        return self.key_of(js)


class FairDeficitRank(RankPolicy):
    """The FAIR deficit order: furthest below the max-min fair target
    first, FIFO ties.  Unlike the other ranks this is not a static job
    key — the targets are recomputed per pass from the live demand — so
    the FAIR scheduler drives its own pass and only the key lives here.
    """

    name = "fair-deficit"
    needs_estimates = False
    uses_vcluster = False

    @staticmethod
    def deficit_key(targets: dict[int, int], by_id: dict, phase: Phase):
        """Sort key closure over one pass's fair targets."""

        def key(j: int) -> tuple:
            js = by_id[j]
            return (
                -(targets[j] - js.n_running(phase)),
                js.spec.arrival_time,
                j,
            )

        return key


# ---------------------------------------------------------------------------
# Preemption policies
# ---------------------------------------------------------------------------
@dataclass
class PreemptionPolicy:
    """The preemption primitive plus hysteresis hooks.

    ``mode`` is the primitive the engine's preemption machinery applies
    (EAGER suspend/resume, WAIT drain, KILL restart — Sect. 3.3; the
    engine's suspended-bytes EAGER->WAIT fallback applies on top).
    ``may_preempt`` is consulted right before the job scheduler preempts
    on behalf of a job with unmet demand; returning False skips the
    preemption for this pass (the job retries next pass).  The default
    always allows — bit-identical to the pre-API engine.
    """

    mode: Preemption = Preemption.EAGER

    def may_preempt(self, engine, js, phase: Phase, now: float) -> bool:
        return True

    def on_pass(
        self, engine, phase: Phase, now: float, have_free: bool
    ) -> None:
        """Once per (phase, scheduling pass), right after the engine read
        the free-slot state and before any job is visited — the place to
        prefetch whatever ``may_preempt`` will consult this pass (the
        batched rank-stability refresh).  Must be decision-neutral: only
        caches may change.  Default: no-op."""

    def on_estimate(self, engine, job_id: int, phase: Phase) -> None:
        """A job's phase-size estimate was just revised (sample
        observation landed).  Lets a policy mark cached verdicts dirty
        without scanning live jobs each pass.  Default: no-op."""

    def on_wall_refresh(self, engine, now: float) -> int:
        """Wall-clock-driven maintenance, reached only through the live
        service's :meth:`~repro.core.scheduler.Scheduler.on_wall_tick`
        seam (offline simulation never calls it).  MUST be
        decision-neutral: only caches whose contents are bit-identical
        to what the lazy path would compute may change, so the replay
        twin — which replays journaled *simulation* events with no wall
        clock — stays deterministic.  Returns how many cached entries
        were refreshed (telemetry).  Default: no-op."""
        return 0

    def forget(self, job_id: int) -> None:
        """Evict any per-job state (called by the engine when the job
        completes)."""


@dataclass
class StabilityHysteresis(PreemptionPolicy):
    """Rank-stability preemption hysteresis (the PSBS assembly's hook).

    While a job is still in training its size estimate is provisional;
    preempting on its behalf risks suspend/resume thrash if the next
    sample observation reorders it.  Before allowing a preemption for an
    in-training job, this policy prices the job's rank across the
    Training module's candidate sizes in one batched what-if projection
    (:meth:`~repro.core.hfsp.HFSPScheduler.rank_stability`) and vetoes
    the preemption when the position spread exceeds ``max_spread``.
    Verdicts are cached per (job, phase) at the current
    observation-count (observation counts only grow, so one slot per
    job-phase suffices): each estimate revision costs at most one
    batched projection, and the cache stays O(active jobs) — the
    engine's ``forget`` call evicts completed jobs.
    """

    #: Largest schedule-position spread across candidate sizes that
    #: still counts as "settled" (0 = require full agreement).
    max_spread: int = 0

    def __post_init__(self) -> None:
        # (job, phase.value) -> (observation count, spread, vetoed).
        self._cache: dict[tuple[int, str], tuple[int, int, bool]] = {}
        # phase.value -> jobs whose estimate moved since their verdict
        # was cached — the only candidates the on_pass prefetch must
        # re-price, so the prefetch costs O(estimate revisions), never
        # O(live jobs).
        self._dirty: dict[str, dict[int, None]] = {
            Phase.MAP.value: {}, Phase.REDUCE.value: {}
        }

    def may_preempt(self, engine, js, phase, now):
        jid = js.spec.job_id
        if not engine.training.is_training(jid, phase):
            return True
        n_obs = engine.training.n_observations(jid, phase)
        ck = (jid, phase.value)
        hit = self._cache.get(ck)
        if hit is None or hit[0] != n_obs:
            positions = engine.rank_stability(jid, phase, now)
            spread = (max(positions) - min(positions)) if positions else 0
            hit = (n_obs, spread, spread > self.max_spread)
            self._cache[ck] = hit
        _, spread, vetoed = hit
        engine.note_rank_stability(spread, vetoed)
        return not vetoed

    def on_estimate(self, engine, job_id, phase):
        if engine.training.is_training(job_id, phase):
            self._dirty[phase.value][job_id] = None

    def on_pass(self, engine, phase, now, have_free):
        """Batched verdict refresh: on a slot-starved pass (the only
        kind whose job walk can reach ``may_preempt``), drain the
        dirty set — jobs whose estimate was revised since their cached
        verdict (``on_estimate``) — and re-price every genuinely stale
        one through ONE ``rank_stability_batch`` projection.
        Per-scenario results are independent, so each verdict is
        bit-identical to the lazy per-job path — which still covers
        jobs the dirty set misses (first consult of a fresh job, or a
        drain below the 2-job batch threshold: a single job batches
        nothing).  Cost is O(revisions since last drain), never
        O(live jobs)."""
        if have_free:
            return
        dirty = self._dirty[phase.value]
        if len(dirty) < 2:
            return
        tr = engine.training
        stale: list[tuple[int, int]] = []
        for jid in dirty:
            if not tr.is_training(jid, phase):
                continue
            n_obs = tr.n_observations(jid, phase)
            hit = self._cache.get((jid, phase.value))
            if hit is None or hit[0] != n_obs:
                stale.append((jid, n_obs))
        dirty.clear()
        if len(stale) < 2:
            return
        positions = engine.rank_stability_batch(
            phase, [jid for jid, _ in stale], now
        )
        for jid, n_obs in stale:
            pos = positions.get(jid, [])
            spread = (max(pos) - min(pos)) if pos else 0
            self._cache[(jid, phase.value)] = (
                n_obs, spread, spread > self.max_spread
            )

    def on_wall_refresh(self, engine, now):
        """Live-service stale-verdict refresh: drain BOTH phases' dirty
        sets and re-price every genuinely stale verdict through one
        batched projection per phase — no slot-starvation gate and no
        2-job batch threshold, because wall time (a long idle stretch
        between simulation events) is what triggered us, not a pass.
        Decision-neutral by the same argument as :meth:`on_pass`: each
        refreshed verdict is bit-identical to what the lazy
        ``may_preempt`` path would compute on its next consult, so
        scheduling decisions (and the replay twin) are unchanged — the
        tick only moves the projection cost off the decision path."""
        refreshed = 0
        for phase in (Phase.MAP, Phase.REDUCE):
            dirty = self._dirty[phase.value]
            if not dirty:
                continue
            tr = engine.training
            stale: list[tuple[int, int]] = []
            for jid in dirty:
                if not tr.is_training(jid, phase):
                    continue
                n_obs = tr.n_observations(jid, phase)
                hit = self._cache.get((jid, phase.value))
                if hit is None or hit[0] != n_obs:
                    stale.append((jid, n_obs))
            dirty.clear()
            if not stale:
                continue
            positions = engine.rank_stability_batch(
                phase, [jid for jid, _ in stale], now
            )
            for jid, n_obs in stale:
                pos = positions.get(jid, [])
                spread = (max(pos) - min(pos)) if pos else 0
                self._cache[(jid, phase.value)] = (
                    n_obs, spread, spread > self.max_spread
                )
            refreshed += len(stale)
        return refreshed

    def forget(self, job_id: int) -> None:
        self._cache.pop((job_id, Phase.MAP.value), None)
        self._cache.pop((job_id, Phase.REDUCE.value), None)
        for d in self._dirty.values():
            d.pop(job_id, None)


# ---------------------------------------------------------------------------
# Aging policies
# ---------------------------------------------------------------------------
class AgingPolicy:
    """How job priorities move as time passes.

    ``advance`` is called whenever the engine's clock moves (every
    event); ``on_pass`` once per (phase, scheduling pass), before the
    rank order is read — the place for pass-scoped priority adjustments.
    """

    name = "none"

    def advance(self, engine, dt: float, now: float) -> None:
        pass

    def on_pass(self, engine, phase: Phase, now: float) -> None:
        pass

    def forget(self, job_id: int) -> None:
        """Evict any per-job state (called by the engine when the job
        completes)."""


class WallClockAging(AgingPolicy):
    """No explicit aging state: priorities move only through the
    event-materialized attained-service counters (SRPT's remaining
    shrinks, LAS's attained grows).  The engine does nothing per tick."""

    name = "wall-clock"


class VirtualClusterAging(AgingPolicy):
    """FSP aging (Sect. 3.1): elapsed time is distributed as progress to
    every allocated *virtual* task (lazily — see
    :meth:`repro.core.vcluster.VirtualCluster.age`)."""

    name = "virtual-cluster"

    def advance(self, engine, dt, now):
        for vc in engine.vc.values():
            vc.age(dt)


@dataclass
class PSBSLateAging(VirtualClusterAging):
    """PSBS-style late-job aging on top of FSP virtual progression.

    Under estimation error, an *underestimated* job's virtual copy
    finishes before the real job does.  Plain FSP then gives the "late"
    job absolute priority forever (its projected finish lies in the
    past) — one badly underestimated giant can monopolize the cluster.
    PSBS instead re-injects late jobs into the virtual cluster with a
    fresh size re-estimate so they keep competing fairly: ``late_factor
    x estimated-task-time x real-unfinished-tasks`` of virtual
    remaining work, scaled by ``growth ** bump-count`` — exponential
    escalation, so a job whose true size exceeds its estimate by a
    factor F is re-injected only O(log F) times (each bump costs an
    order-cache rebuild; without escalation a badly underestimated job
    would go virtually-done again within one estimated-task-time and
    re-rank the cluster every pass).  Detection is cheap:
    :meth:`VirtualCluster.virtually_done` is horizon-gated, so
    steady-state passes pay O(1) and the scan only runs when queued
    aging could actually have finished a job.
    """

    name = "psbs-late"
    #: Fraction of the re-estimated remaining work re-injected per bump.
    late_factor: float = 1.0
    #: Escalation base: bump k re-injects growth**k times the base
    #: re-estimate (2.0 = classic doubling).
    growth: float = 2.0
    #: Per-(phase, job) bump counts (event-deterministic).
    _bumps: dict = field(default_factory=dict, repr=False)

    def on_pass(self, engine, phase, now):
        vc = engine.vc[phase]
        late = vc.virtually_done()
        if not late:
            return
        bumped = False
        for jid in late:
            js = engine.jobs.get(jid)
            if js is None or jid not in vc:
                continue
            n_left = js.n_unfinished(phase)
            if not n_left:
                continue
            k = (phase.value, jid)
            count = self._bumps.get(k, 0)
            self._bumps[k] = count + 1
            tt = vc.jobs[jid].task_time
            scale = self.growth ** min(count, 50)
            vc.set_remaining(
                jid, self.late_factor * max(tt * n_left, tt) * scale
            )
            engine.stats.late_job_bumps += 1
            bumped = True
        if bumped:
            # The virtual ranks just moved: drop cached orders (and the
            # engine's epoch-keyed pass caches) before this pass reads
            # them.
            engine._rank_dirty(phase)

    def forget(self, job_id: int) -> None:
        self._bumps.pop((Phase.MAP.value, job_id), None)
        self._bumps.pop((Phase.REDUCE.value, job_id), None)


# ---------------------------------------------------------------------------
# Disciplines and the registry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Discipline:
    """A named, buildable scheduling discipline.

    ``build(cluster, **axis_kwargs) -> Scheduler`` receives the scenario
    scheduler-axis fields as keyword arguments (``preemption``,
    ``sample_set_size``, ``delta``, ``error_alpha``, ``error_seed``,
    ``vc_backend``, plus ``config=`` for a pre-built scheduler config)
    and must ignore the ones it does not consume — FIFO ignores all of
    them.  The ``rank`` / ``preemption`` / ``aging`` fields are the
    assembly's *descriptive* policy names (what ``list`` surfaces and
    docs reference); the builder is the executable assembly.
    """

    name: str
    build: Callable[..., Scheduler]
    rank: str = "rank"
    preemption: str = "eager"
    aging: str = "none"
    description: str = ""


class DisciplineRegistry:
    """Name -> Discipline, resolved by the scenario engine at build time
    (:func:`repro.scenarios.runner.build_scheduler`); scenario specs do
    NOT validate policy names eagerly, so registering a discipline from
    user code is enough to make it sweepable."""

    def __init__(self) -> None:
        self._disciplines: dict[str, Discipline] = {}

    def register(
        self, name: str, discipline: Discipline, *, override: bool = False
    ) -> Discipline:
        if not override and name in self._disciplines:
            raise ValueError(
                f"discipline {name!r} is already registered; pass "
                f"override=True to replace it"
            )
        self._disciplines[name] = discipline
        return discipline

    def get(self, name: str) -> Discipline:
        try:
            return self._disciplines[name]
        except KeyError:
            raise KeyError(
                f"unknown scheduling discipline {name!r}; registered: "
                f"{', '.join(self.names())}"
            ) from None

    def names(self) -> list[str]:
        return sorted(self._disciplines)

    def build(self, name: str, cluster: ClusterSpec, **kwargs) -> Scheduler:
        return self.get(name).build(cluster, **kwargs)


#: The default (module-level) registry every consumer resolves against.
REGISTRY = DisciplineRegistry()


def register(
    name: str, discipline: Discipline, *, override: bool = False
) -> Discipline:
    """Register ``discipline`` under ``name`` in the default registry."""
    return REGISTRY.register(name, discipline, override=override)


def get(name: str) -> Discipline:
    return REGISTRY.get(name)


def names() -> list[str]:
    return REGISTRY.names()


def build_scheduler(name: str, cluster: ClusterSpec, **kwargs) -> Scheduler:
    """Build the named discipline's scheduler (the scenario runner's
    single resolution point)."""
    return REGISTRY.build(name, cluster, **kwargs)


# ---------------------------------------------------------------------------
# Built-in assemblies
# ---------------------------------------------------------------------------
def _engine_config(
    *,
    preemption: Preemption | str = "eager",
    sample_set_size: int = 5,
    delta: float = 60.0,
    error_alpha: float = 0.0,
    error_seed: int = 0,
    vc_backend: str | None = None,
    config=None,
    **_ignored,
):
    """HFSPConfig from scenario scheduler-axis kwargs (``config=``
    short-circuits for callers holding a fully-built config — tests and
    benchmarks that set debug knobs like ``paranoid_indexes``)."""
    if config is not None:
        return config
    from repro.core.hfsp import HFSPConfig

    if isinstance(preemption, str):
        preemption = Preemption(preemption)
    return HFSPConfig(
        preemption=preemption,
        sample_set_size=sample_set_size,
        delta=delta,
        error_alpha=error_alpha,
        error_seed=error_seed,
        vc_backend=vc_backend,
    )


def engine_discipline(
    name: str,
    rank_factory: Callable[[], RankPolicy],
    *,
    aging_factory: Callable[[], AgingPolicy] | None = None,
    hysteresis: Callable[[Preemption], PreemptionPolicy] | None = None,
    description: str = "",
) -> Discipline:
    """Assemble a size-based-engine discipline from policy factories —
    the ~5-line path for registering a custom rank (see module
    docstring and docs/disciplines.md)."""
    rank_probe = rank_factory()

    def build(cluster: ClusterSpec, **kwargs) -> Scheduler:
        from repro.core.hfsp import HFSPScheduler

        cfg = _engine_config(**kwargs)
        policy = hysteresis(cfg.preemption) if hysteresis else None
        return HFSPScheduler(
            cluster,
            cfg,
            rank=rank_factory(),
            aging=aging_factory() if aging_factory else None,
            preemption_policy=policy,
            name=name,
        )

    return Discipline(
        name=name,
        build=build,
        rank=rank_probe.name,
        preemption="axis" if hysteresis is None else "axis+stability",
        aging=(
            aging_factory().name
            if aging_factory
            else (
                VirtualClusterAging.name
                if rank_probe.uses_vcluster
                else WallClockAging.name
            )
        ),
        description=description,
    )


def _build_fifo(cluster: ClusterSpec, *, config=None, **_ignored) -> Scheduler:
    from repro.core.fifo import FIFOScheduler

    return FIFOScheduler(cluster, config)


def _build_fair(cluster: ClusterSpec, *, config=None, **_ignored) -> Scheduler:
    from repro.core.fair import FairScheduler

    return FairScheduler(cluster, config)


register("fifo", Discipline(
    name="fifo",
    build=_build_fifo,
    rank=ArrivalRank.name,
    preemption="none",
    aging=WallClockAging.name,
    description="stock Hadoop FIFO (priority-weighted arrival order)",
))

register("fair", Discipline(
    name="fair",
    build=_build_fair,
    rank=FairDeficitRank.name,
    preemption="none",
    aging=WallClockAging.name,
    description="Hadoop Fair Scheduler (max-min deficit order)",
))

register("hfsp", engine_discipline(
    "hfsp",
    VirtualFinishRank,
    description="HFSP: FSP virtual-finish rank + axis preemption (the paper)",
))

register("srpt", engine_discipline(
    "srpt",
    SRPTRank,
    description="SRPT on estimated remaining size (error-fragile)",
))

register("las", engine_discipline(
    "las",
    LASRank,
    description="least attained service (size-oblivious reference)",
))

def _build_psbs(
    cluster: ClusterSpec,
    *,
    psbs_late_factor: float = 1.0,
    psbs_max_spread: int = 0,
    **kwargs,
) -> Scheduler:
    """PSBS assembly with its calibration knobs exposed as scenario axes
    (``scheduler.psbs_late_factor`` / ``scheduler.psbs_max_spread``, see
    the ``paper-psbs-calibration`` preset): how aggressively late jobs
    are re-injected, and how much rank-stability spread the hysteresis
    tolerates before vetoing a preemption.  Defaults reproduce the PR 5
    assembly exactly."""
    from repro.core.hfsp import HFSPScheduler

    cfg = _engine_config(**kwargs)
    return HFSPScheduler(
        cluster,
        cfg,
        rank=VirtualFinishRank(),
        aging=PSBSLateAging(late_factor=float(psbs_late_factor)),
        preemption_policy=StabilityHysteresis(
            mode=cfg.preemption, max_spread=int(psbs_max_spread)
        ),
        name="psbs",
    )


register("psbs", Discipline(
    name="psbs",
    build=_build_psbs,
    rank=VirtualFinishRank.name,
    preemption="axis+stability",
    aging=PSBSLateAging.name,
    description="PSBS: FSP + late-job aging + rank-stability hysteresis",
))
