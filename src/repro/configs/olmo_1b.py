"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304
— non-parametric LN [arXiv:2402.00838; hf]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    act="silu_glu",
    norm="layernorm",
    non_parametric_norm=True,   # OLMo's defining quirk
    use_bias=False,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = reduced(CONFIG)
