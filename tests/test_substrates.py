"""Substrate tests: optimizer, data pipeline, checkpoint store, serving,
runtime, sharding specs, roofline parsing."""

import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke
from repro.configs.base import SHAPES, input_specs
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import init_model
from repro.serve import greedy_generate
from repro.train import OptimizerConfig, adamw_update, init_opt_state, lr_at
from repro.utils.roofline import Roofline, parse_collectives


class TestOptimizer:
    def test_lr_schedule_shape(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
        assert float(lr_at(cfg, jnp.asarray(0))) == pytest.approx(0.0)
        assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)

    def test_adamw_moves_toward_minimum(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        opt = init_opt_state(params)
        cfg = OptimizerConfig(lr=0.5, warmup_steps=0, weight_decay=0.0,
                              schedule="constant")
        for _ in range(120):
            grads = {"w": params["w"]}  # d/dw (w^2/2)
            params, opt, _ = adamw_update(cfg, params, grads, opt)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_grad_clipping(self):
        from repro.train.optimizer import clip_by_global_norm

        grads = {"a": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(grads, 1.0)
        assert float(norm) == pytest.approx(200.0)
        total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
        assert float(total) == pytest.approx(1.0, rel=1e-4)

    def test_int8_compression_roundtrip(self):
        from repro.train.optimizer import compress_int8, decompress_int8

        g = jnp.asarray(np.random.default_rng(0).normal(size=(128,)) * 3)
        q, scale = compress_int8(g)
        back = decompress_int8(q, scale)
        assert q.dtype == jnp.int8
        np.testing.assert_allclose(back, g, atol=float(scale) * 0.51)


class TestData:
    def test_determinism_and_rank_disjointness(self):
        cfg = get_smoke("olmo_1b")
        src = SyntheticLM(cfg, DataConfig(seq_len=16, global_batch=8))
        a = src.batch(3, rank=0, num_ranks=2)
        b = src.batch(3, rank=0, num_ranks=2)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = src.batch(3, rank=1, num_ranks=2)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = get_smoke("olmo_1b")
        src = SyntheticLM(cfg, DataConfig(seq_len=16, global_batch=2))
        b = src.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetcher(self):
        cfg = get_smoke("olmo_1b")
        src = SyntheticLM(cfg, DataConfig(seq_len=8, global_batch=2))
        pf = Prefetcher(src, depth=2)
        steps = [pf.next()[0] for _ in range(4)]
        pf.close()
        assert steps == [0, 1, 2, 3]


class TestCheckpoint:
    def test_roundtrip_and_gc(self):
        with tempfile.TemporaryDirectory() as d:
            store = CheckpointStore(d, keep=2)
            tree = {"a": np.arange(5), "b": {"c": np.ones((2, 2))}}
            for step in (1, 2, 3):
                store.save("t", step, tree)
            files = [f for f in os.listdir(d) if f.endswith(".npz")]
            assert len(files) == 2  # gc keeps 2
            step, restored = store.restore("t")
            assert step == 3
            np.testing.assert_array_equal(restored["a"], tree["a"])
            np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])

    def test_async_save(self):
        with tempfile.TemporaryDirectory() as d:
            store = CheckpointStore(d)
            store.save_async("t", 7, {"x": jnp.ones((3,))})
            store.wait()
            assert store.restore("t")[0] == 7

    def test_restore_missing_returns_none(self):
        with tempfile.TemporaryDirectory() as d:
            assert CheckpointStore(d).restore("nope") is None


class TestServe:
    def test_greedy_generate_deterministic(self):
        cfg = get_smoke("olmo_1b")
        params = init_model(cfg, jax.random.PRNGKey(0))
        prompt = jnp.asarray([[1, 2, 3]], jnp.int32)
        a = greedy_generate(cfg, params, prompt, max_new_tokens=4)
        b = greedy_generate(cfg, params, prompt, max_new_tokens=4)
        assert a.shape == (1, 7)
        np.testing.assert_array_equal(a, b)
        assert int(a.max()) < cfg.vocab_size  # padded vocab never sampled

    def test_batching_queue_lifecycle(self):
        from repro.serve import BatchingQueue

        cfg = get_smoke("olmo_1b")
        q = BatchingQueue(cfg, batch_slots=2, max_seq=16)
        for i in range(3):
            q.submit({"id": i, "prompt": [1, 2], "max_new_tokens": 2})
        admitted = q.admit()
        assert len(admitted) == 2  # only 2 slots
        for slot, _ in admitted:
            for tok in (5, 6, 7):
                q.step_done(slot, tok)
        assert len(q.finished) == 2
        assert len(q.admit()) == 1  # third request admitted after slots free


class TestShardingSpecs:
    def test_specs_cover_every_leaf(self):
        from repro.launch.mesh import make_host_mesh
        from repro.sharding.specs import param_specs

        mesh = make_host_mesh()
        for arch in ("olmo_1b", "granite_moe_3b", "zamba2_2b7", "whisper_base",
                     "rwkv6_1b6"):
            cfg = get_smoke(arch)
            shapes = jax.eval_shape(
                lambda c=cfg: init_model(c, jax.random.PRNGKey(0))
            )
            specs = param_specs(cfg, mesh, shapes)
            flat_s = jax.tree.leaves(
                specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or
                type(x).__name__ == "PartitionSpec"
            )
            flat_p = jax.tree.leaves(shapes)
            assert len(flat_s) == len(flat_p)
            for sp, leaf in zip(flat_s, flat_p):
                assert len(sp) <= len(leaf.shape)

    def test_input_specs_match_assigned_shapes(self):
        cfg = get_smoke("olmo_1b")
        for name, shape in SHAPES.items():
            specs = input_specs(cfg, shape)
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
            else:
                assert specs["tokens"].shape[0] == shape.global_batch


class TestRooflineParsing:
    HLO = """
  %ar = f32[8,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,8]<=[32], to_apply=%add
  %ag = bf16[16,256]{1,0} all-gather(%y), channel_id=2, replica_groups=[8,4]<=[32], dimensions={0}
  %rs = f32[4,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[4,8]<=[32], to_apply=%add
  %cp = f32[32]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %ard = f32[8,128]{1,0} all-reduce-done(%ar)
"""

    def test_wire_bytes(self):
        st = parse_collectives(self.HLO)
        assert st.count_by_op == {
            "all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
            "collective-permute": 1,
        }
        z_ar = 8 * 128 * 4
        assert st.bytes_by_op["all-reduce"] == pytest.approx(
            2 * z_ar * 7 / 8
        )
        z_ag = 16 * 256 * 2
        assert st.bytes_by_op["all-gather"] == pytest.approx(z_ag * 3 / 4)
        z_rs = 4 * 64 * 4
        assert st.bytes_by_op["reduce-scatter"] == pytest.approx(z_rs * 7)

    def test_dominant_term(self):
        r = Roofline(flops=197e12, bytes_accessed=1.0, collective_bytes=1.0)
        assert r.dominant == "compute"
        assert r.compute_s == pytest.approx(1.0)


class TestGangRuntime:
    def test_two_jobs_hfsp(self):
        from repro.core import ClusterSpec, HFSPConfig, HFSPScheduler
        from repro.runtime import GangRuntime, MLJob

        cluster = ClusterSpec(num_machines=1, map_slots_per_machine=1,
                              reduce_slots_per_machine=0)
        jobs = [
            MLJob(0, get_smoke("olmo_1b"), total_steps=4, steps_per_quantum=2,
                  arrival_time=0.0, name="a"),
            MLJob(1, get_smoke("olmo_1b"), total_steps=2, steps_per_quantum=2,
                  arrival_time=0.1, name="b", seed=1),
        ]
        with tempfile.TemporaryDirectory() as d:
            rtm = GangRuntime(
                cluster,
                HFSPScheduler(cluster, HFSPConfig(sample_set_size=1)),
                jobs, CheckpointStore(d),
            )
            rep = rtm.run(max_wall_s=300)
        assert len(rep["sojourn"]) == 2
        assert all(v is not None for v in rep["losses"].values())

    def test_speculative_reexecution_on_spare_gang(self):
        from repro.core import ClusterSpec, FIFOScheduler
        from repro.runtime import GangRuntime, MLJob

        cluster = ClusterSpec(num_machines=2, map_slots_per_machine=1,
                              reduce_slots_per_machine=0)
        jobs = [MLJob(0, get_smoke("olmo_1b"), total_steps=8,
                      steps_per_quantum=2, arrival_time=0.0, name="slow")]
        with tempfile.TemporaryDirectory() as d:
            # straggler_factor ~0: every quantum past the 3rd counts as a
            # straggler, forcing the speculative re-execution path.
            rtm = GangRuntime(cluster, FIFOScheduler(cluster), jobs,
                              CheckpointStore(d), straggler_factor=1e-6)
            rep = rtm.run(max_wall_s=300)
        st = rep["stats"]
        assert 0 in rep["sojourn"]          # job completed despite racing
        assert st["speculative"] >= 1
        # Every race was decided: exactly one winner per speculation.
        assert st["spec_wins"] + st["spec_losses"] == st["speculative"]
        # Speculative copies bypass suspend/kill bookkeeping entirely.
        assert st["offloads"] == 0 and st["kills"] == 0
        spec_events = [e for e in rep["events"] if e[1] == "speculative"]
        assert len(spec_events) == st["speculative"]
        # The shadow ran on the spare gang, never the suspect's own.
        for e in spec_events:
            suspect, spare = e[2].split(" ")[1].split("->")
            assert suspect != spare

    def test_failure_recovery(self):
        from repro.core import ClusterSpec, FIFOScheduler
        from repro.runtime import GangRuntime, MLJob

        cluster = ClusterSpec(num_machines=1, map_slots_per_machine=1,
                              reduce_slots_per_machine=0)
        jobs = [MLJob(0, get_smoke("olmo_1b"), total_steps=6,
                      steps_per_quantum=2, arrival_time=0.0, name="flaky")]
        with tempfile.TemporaryDirectory() as d:
            # seed 2: rng draws 0.262, 0.298 < 0.4 => the first two quanta
            # fail deterministically, then recovery completes the job.
            rtm = GangRuntime(cluster, FIFOScheduler(cluster), jobs,
                              CheckpointStore(d), fail_quantum_prob=0.4,
                              rng_seed=2)
            rep = rtm.run(max_wall_s=300)
        assert 0 in rep["sojourn"]          # completed despite failures
        assert rep["stats"]["failures"] >= 1
