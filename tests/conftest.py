import os

# Smoke tests and benchmarks must see the REAL device count (the dry-run
# alone forces 512 host devices, in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Deterministic jax/XLA numerics for the vcluster backend-conformance
# suite: a fixed single-threaded CPU reduction order makes kernel outputs
# reproducible across CI machines and laptops (threaded reductions may
# reassociate float sums).  setdefault only — an externally configured
# XLA_FLAGS (e.g. the dry-run's forced device count) wins.
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1",
)

import pytest  # noqa: E402


def pytest_collection_modifyitems(config, items):
    # Per-test wall-clock budget: the fault-injection and self-healing
    # sweep suites spawn worker processes, and a hung child should fail
    # its one test, not wedge the whole run.  Gated on the optional
    # pytest-timeout plugin (requirements-dev.txt) being installed —
    # without it the marker would be inert noise.
    if not config.pluginmanager.hasplugin("timeout"):
        return
    for item in items:
        if item.get_closest_marker("timeout") is None:
            item.add_marker(pytest.mark.timeout(300))


@pytest.fixture(scope="session")
def rng():
    import jax

    return jax.random.PRNGKey(0)
