"""Jitted wrapper for the rwkv6 Pallas kernel in the model's layout."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import rwkv6_chunked_bhtd


def rwkv6_chunked(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = False):
    """Model layout (b, t, h, d) -> (out (b,t,h,dv), state (b,h,dk,dv))."""
    to_bh = lambda x: jnp.moveaxis(x, 1, 2)
    out, s = rwkv6_chunked_bhtd(
        to_bh(r), to_bh(k), to_bh(v), to_bh(w), u, s0,
        chunk=chunk, interpret=interpret,
    )
    return jnp.moveaxis(out, 1, 2), s
