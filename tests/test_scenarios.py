"""Scenario engine tests: spec algebra, presets, trace replay fidelity,
sweep resume semantics, and the paper-fb acceptance property.

Everything runs at quick scale (30 jobs / 20 machines) so the suite stays
in seconds; the properties pinned here are scale-independent.
"""

import json

import pytest

from repro.scenarios import (
    ClusterAxis,
    ResultStore,
    ScenarioSpec,
    SchedulerAxis,
    SweepSpec,
    WorkloadAxis,
    export_trace,
    get_preset,
    list_presets,
    load_trace,
    matrix_report,
    paper_fb_base,
    quick_sweep,
    run_scenario,
    run_sweep,
)
from repro.scenarios.runner import build_workload
from repro.scenarios.spec import cell_id
from repro.scenarios.sweep import _TEST_HOOK_ENV


# ---------------------------------------------------------------------------
# Spec algebra
# ---------------------------------------------------------------------------
def test_spec_roundtrips_through_json():
    spec = paper_fb_base().override(**{
        "scheduler.policy": "fair", "workload.seed": 7, "heartbeat": 5.0,
    })
    blob = json.dumps(spec.to_dict(), sort_keys=True)
    back = ScenarioSpec.from_dict(json.loads(blob))
    assert back == spec
    assert back.spec_hash() == spec.spec_hash()


def test_override_validates_unknown_fields():
    with pytest.raises(KeyError):
        paper_fb_base().override(**{"scheduler.polcy": "fair"})
    with pytest.raises(KeyError):
        paper_fb_base().override(**{"heartbeet": 1.0})
    with pytest.raises(KeyError):
        # First segment names a plain (non-axis) field.
        paper_fb_base().override(**{"name.typo": "x"})


def test_override_applies_codependent_axis_fields_together():
    # kind="trace" is only valid with trace_path: both land in one replace.
    spec = paper_fb_base().override(**{
        "workload.kind": "trace", "workload.trace_path": "/tmp/x.jsonl",
    })
    assert spec.workload.kind == "trace"


def test_spec_hash_changes_with_any_axis():
    base = paper_fb_base()
    assert base.spec_hash() != base.override(**{"workload.seed": 1}).spec_hash()
    assert base.spec_hash() != base.override(**{"scheduler.error_alpha": 0.5}).spec_hash()


def test_workload_axis_validation():
    with pytest.raises(ValueError):
        WorkloadAxis(kind="nope")
    with pytest.raises(ValueError):
        WorkloadAxis(kind="trace")  # no trace_path


# ---------------------------------------------------------------------------
# Sweeps + presets
# ---------------------------------------------------------------------------
def test_sweep_expansion_union_and_dedup():
    sweep = SweepSpec(
        name="t",
        base=paper_fb_base(),
        grids=(
            SweepSpec.grid(**{"scheduler.policy": ("fifo", "fair")}),
            SweepSpec.grid(**{"scheduler.policy": ("fair", "hfsp")}),
        ),
    )
    cells = sweep.expand()
    ids = [cid for cid, _ in cells]
    assert ids == [
        "scheduler.policy=fifo", "scheduler.policy=fair", "scheduler.policy=hfsp",
    ]


def test_cell_id_is_deterministic_and_sorted():
    a = cell_id((("b", 2), ("a", 1)))
    b = cell_id((("a", 1), ("b", 2)))
    assert a == b == "a=1,b=2"
    assert cell_id(()) == "base"


def test_registered_presets_expand():
    assert "paper-fb" in list_presets()
    for name in list_presets():
        cells = get_preset(name).expand()
        assert cells, name
        assert len({cid for cid, _ in cells}) == len(cells), name


def test_paper_fb_matrix_covers_all_policies():
    policies = {
        spec.scheduler.policy for _, spec in get_preset("paper-fb").expand()
    }
    assert policies == {"fifo", "fair", "hfsp"}


# ---------------------------------------------------------------------------
# Trace export -> import -> replay (bit-identical)
# ---------------------------------------------------------------------------
def test_trace_roundtrip_bit_identical_replay(tmp_path):
    base = paper_fb_base().quick()
    jobs, class_of = build_workload(base)
    path = tmp_path / "golden.jsonl"
    export_trace(path, jobs, class_of, {"generator": "fb", "seed": 0})

    jobs2, class_of2, meta = load_trace(path)
    assert meta["generator"] == "fb"
    assert class_of2 == class_of
    by_id = {j.job_id: j for j in jobs}
    for j2 in jobs2:
        j = by_id[j2.job_id]
        assert j2.arrival_time == j.arrival_time  # bit-exact float
        for a, b in zip(
            j2.map_tasks + j2.reduce_tasks, j.map_tasks + j.reduce_tasks
        ):
            assert a.duration == b.duration
            assert a.input_hosts == b.input_hosts
            assert a.state_bytes == b.state_bytes

    direct = run_scenario(base)
    replay = run_scenario(base.override(**{
        "workload.kind": "trace", "workload.trace_path": str(path),
    }))
    assert (
        replay["completion_fingerprint"] == direct["completion_fingerprint"]
    )


def test_trace_rejects_wrong_kind_and_version(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"kind": "not-a-trace", "version": 1}\n')
    with pytest.raises(ValueError, match="not a repro-trace"):
        load_trace(p)
    p.write_text('{"kind": "repro-trace", "version": 99}\n')
    with pytest.raises(ValueError, match="version"):
        load_trace(p)


# ---------------------------------------------------------------------------
# Sweep engine: resume + staleness + the acceptance property
# ---------------------------------------------------------------------------
def test_sweep_interrupted_resumes_without_recompute(tmp_path):
    sweep = quick_sweep(get_preset("paper-fb"))
    store = ResultStore(tmp_path / "store.jsonl")

    # "Interrupt" mid-grid after 2 of 3 cells.
    first = run_sweep(sweep, store=store, max_cells=2)
    assert len(first) == 2
    stored_lines = store.path.read_text().splitlines()
    assert len(stored_lines) == 2

    # Resume: only the missing cell is computed (store grows by one line,
    # the two finished cells' stored results are returned verbatim).
    resumed = run_sweep(sweep, store=store)
    assert len(resumed) == 3
    lines_after = store.path.read_text().splitlines()
    assert len(lines_after) == 3
    assert lines_after[:2] == stored_lines
    for cid, res in first.items():
        assert resumed[cid]["completion_fingerprint"] == res["completion_fingerprint"]

    # Idempotent: a third run computes nothing.
    again = run_sweep(sweep, store=store)
    assert len(store.path.read_text().splitlines()) == 3
    assert again.keys() == resumed.keys()


def test_sweep_store_invalidates_on_spec_change(tmp_path):
    base = paper_fb_base().quick()
    sweep = SweepSpec(
        name="t", base=base,
        grids=(SweepSpec.grid(**{"scheduler.policy": ("hfsp",)}),),
    )
    store = ResultStore(tmp_path / "store.jsonl")
    run_sweep(sweep, store=store)
    assert len(store.path.read_text().splitlines()) == 1

    # Same cell_id, different base spec -> spec_hash mismatch -> recompute.
    edited = SweepSpec(
        name="t", base=base.override(**{"workload.seed": 1}),
        grids=sweep.grids,
    )
    run_sweep(edited, store=store)
    assert len(store.path.read_text().splitlines()) == 2


def test_sweep_store_tolerates_torn_trailing_line(tmp_path):
    sweep = quick_sweep(get_preset("paper-fb"))
    store = ResultStore(tmp_path / "store.jsonl")
    run_sweep(sweep, store=store, max_cells=1)
    with store.path.open("a") as f:
        f.write('{"cell_id": "torn')  # crash mid-write
    assert len(store.load()) == 1
    resumed = run_sweep(sweep, store=store)
    assert len(resumed) == 3


def test_parallel_sweep_poison_cell_is_quarantined(tmp_path):
    """A cell failing past its retry budget must not take the sweep down
    with it: the siblings' finished work is stored, the poison cell
    lands as a quarantine record, the sweep completes, and a resume
    treats the quarantine record as done."""
    base = paper_fb_base().quick()
    sweep = SweepSpec(
        name="t", base=base,
        grids=(
            SweepSpec.grid(**{"scheduler.policy": ("fifo", "fair")}),
            SweepSpec.grid(**{
                "workload.kind": ("trace",),
                "workload.trace_path": (str(tmp_path / "missing.jsonl"),),
            }),
        ),
    )
    bad_cid = next(
        cid for cid, spec in sweep.expand() if spec.workload.kind == "trace"
    )
    store = ResultStore(tmp_path / "store.jsonl")
    results = run_sweep(
        sweep, store=store, workers=2, max_retries=1, retry_backoff=0.05
    )
    assert len(results) == 3
    assert results[bad_cid]["quarantined"]
    assert results[bad_cid]["attempts"] == 2  # initial try + 1 retry
    assert len(store.load()) == 3  # both good cells + the quarantine record
    # matrix_report lists and excludes the poison cell.
    matrix = matrix_report(results)
    assert matrix["quarantined"] == [bad_cid]
    assert bad_cid not in matrix["mean_sojourn_s"]
    assert matrix["cells"] == 2
    # Resume computes nothing: the quarantine record counts as done.
    recomputed = []
    resumed = run_sweep(
        sweep, store=store, workers=2,
        progress=lambda cid, res: recomputed.append(cid),
    )
    assert recomputed == []
    assert resumed[bad_cid]["quarantined"]


# ---------------------------------------------------------------------------
# Self-healing sweep supervisor (hangs, poison cells, crash recovery)
# ---------------------------------------------------------------------------
def _tiny_sweep(n_cells: int = 3) -> SweepSpec:
    """The smallest real sweep: n seeds x 6 jobs x 4 machines, FIFO.
    Each cell runs in well under a second — the supervisor tests spawn
    one process per attempt, so cell cost dominates test wall time."""
    base = ScenarioSpec(
        name="tiny",
        workload=WorkloadAxis(kind="fb", num_jobs=6),
        cluster=ClusterAxis(num_machines=4),
        scheduler=SchedulerAxis(policy="fifo"),
    )
    return SweepSpec(
        name="tiny", base=base,
        grids=(
            SweepSpec.grid(**{"workload.seed": tuple(range(n_cells))}),
        ),
    )


def test_sweep_hanging_cell_times_out_and_recovers(tmp_path, monkeypatch):
    """A cell hanging past the per-attempt wall-clock budget is killed
    and re-issued; the retry (where the hook no longer hangs) succeeds
    and the matrix completes with no quarantine."""
    sweep = _tiny_sweep(3)
    cids = [cid for cid, _ in sweep.expand()]
    hook = {
        "hang_once": [cids[1]],
        "fail_always": [],
        "state_dir": str(tmp_path),
    }
    hook_path = tmp_path / "hook.json"
    hook_path.write_text(json.dumps(hook))
    # Spawned attempt processes cannot see parent monkeypatches; the
    # hook travels through the environment instead.
    monkeypatch.setenv(_TEST_HOOK_ENV, str(hook_path))

    store = ResultStore(tmp_path / "store.jsonl")
    results = run_sweep(
        sweep, store=store, workers=2,
        timeout=5.0, max_retries=2, retry_backoff=0.05,
    )
    # The hook fired (first attempt hung) and the re-issue recovered.
    assert (tmp_path / f"hung-{cids[1]}").exists()
    assert set(results) == set(cids)
    assert not any(r.get("quarantined") for r in results.values())
    assert results[cids[1]]["jobs_completed"] == 6


def test_sweep_worker_crash_is_retried(tmp_path, monkeypatch):
    """An attempt process dying without a result (here: killed by the
    hook raising) is a retryable failure, not a sweep abort."""
    sweep = _tiny_sweep(2)
    cids = [cid for cid, _ in sweep.expand()]
    hook = {
        "hang_once": [],
        "fail_always": [cids[0]],
        "state_dir": str(tmp_path),
    }
    hook_path = tmp_path / "hook.json"
    hook_path.write_text(json.dumps(hook))
    monkeypatch.setenv(_TEST_HOOK_ENV, str(hook_path))

    results = run_sweep(
        sweep, workers=2, timeout=30.0, max_retries=1, retry_backoff=0.05,
    )
    assert results[cids[0]]["quarantined"]
    assert "fails" in results[cids[0]]["error"]
    assert results[cids[1]]["jobs_completed"] == 6


def test_inline_sweep_retries_and_quarantines(tmp_path):
    """The inline (workers=0) path applies the same bounded-retry +
    quarantine contract, minus timeouts (no process boundary to kill)."""
    base = paper_fb_base().quick().override(**{
        "workload.kind": "trace",
        "workload.trace_path": str(tmp_path / "missing.jsonl"),
    })
    sweep = SweepSpec(
        name="t", base=base,
        grids=(SweepSpec.grid(**{"scheduler.policy": ("fifo",)}),),
    )
    results = run_sweep(sweep, workers=0, max_retries=2, retry_backoff=0.01)
    (only,) = results.values()
    assert only["quarantined"]
    assert only["attempts"] == 3  # initial try + 2 retries


def test_result_store_survives_truncation_at_every_byte(tmp_path):
    """Crash-recovery property: truncate the store at EVERY byte offset;
    load() must return exactly the records whose full line (including
    newline) survived — finished cells preserved, torn tail dropped,
    never an error or a phantom record."""
    sweep = _tiny_sweep(3)
    store = ResultStore(tmp_path / "store.jsonl")
    originals = run_sweep(sweep, store=store, workers=0)
    raw = store.path.read_bytes()
    # A record survives once its full JSON content is on disk — losing
    # only the trailing newline must not lose the record (append repairs
    # the newline before writing the next one).
    content_ends = [i for i, b in enumerate(raw) if b == ord("\n")]
    order = [
        json.loads(ln)["cell_id"]
        for ln in raw.decode().splitlines()
    ]
    offsets_by_count: dict[int, int] = {}
    for off in range(len(raw) + 1):
        store.path.write_bytes(raw[:off])
        loaded = store.load()
        n_complete = sum(1 for e in content_ends if e <= off)
        assert len(loaded) == n_complete, f"offset {off}"
        assert [cid for cid, _ in loaded] == order[:n_complete]
        offsets_by_count.setdefault(n_complete, off)

    # Resume from one truncation point per surviving-record count: the
    # sweep recomputes exactly the missing cells, nothing else.
    for n_complete, off in sorted(offsets_by_count.items()):
        store.path.write_bytes(raw[:off])
        recomputed = []
        resumed = run_sweep(
            sweep, store=store, workers=0,
            progress=lambda cid, res: recomputed.append(cid),
        )
        assert sorted(recomputed) == sorted(order[n_complete:])
        for cid, res in originals.items():
            assert (
                resumed[cid]["completion_fingerprint"]
                == res["completion_fingerprint"]
            )
        # The repaired store is whole again: every record loads.
        assert len(store.load()) == len(order)


def test_paper_fb_quick_hfsp_strictly_lowest():
    """The acceptance property: FIFO, Fair, and HFSP on the same
    synthesized FB trace, HFSP mean sojourn strictly lowest (the paper's
    qualitative Sect. 4.2 result)."""
    results = run_sweep(quick_sweep(get_preset("paper-fb")))
    means = {cid: r["mean_sojourn_s"] for cid, r in results.items()}
    hfsp = means["scheduler.policy=hfsp"]
    assert hfsp < means["scheduler.policy=fair"]
    assert hfsp < means["scheduler.policy=fifo"]
    matrix = matrix_report(results)
    assert matrix["best"] == "scheduler.policy=hfsp"


def test_map_only_axis_strips_reduce_tasks():
    spec = paper_fb_base().quick().override(**{"workload.map_only": True})
    jobs, _ = build_workload(spec)
    assert all(not j.reduce_tasks for j in jobs)
    assert any(j.map_tasks for j in jobs)


# ---------------------------------------------------------------------------
# PSBS calibration knobs (scheduler.psbs_late_factor / psbs_max_spread)
# ---------------------------------------------------------------------------
def test_spec_hash_stable_after_psbs_knob_fields():
    """Adding SchedulerAxis fields must not move existing hashes (the
    FaultAxis precedent): knobs at their defaults are omitted from
    to_dict, so every store written before the fields existed still
    resumes.  This anchor is the paper-fb base cell's hash at the time
    the knobs were added — if it moves, stored sweeps invalidate."""
    assert paper_fb_base().spec_hash() == "0286c8364f3373fb"
    sched = paper_fb_base().to_dict()["scheduler"]
    assert "psbs_late_factor" not in sched
    assert "psbs_max_spread" not in sched


def test_psbs_knobs_roundtrip_and_change_hash():
    base = paper_fb_base()
    tuned = base.override(**{
        "scheduler.policy": "psbs",
        "scheduler.psbs_late_factor": 2.0,
        "scheduler.psbs_max_spread": 3,
    })
    d = tuned.to_dict()
    assert d["scheduler"]["psbs_late_factor"] == 2.0
    assert d["scheduler"]["psbs_max_spread"] == 3
    assert ScenarioSpec.from_dict(d) == tuned
    assert tuned.spec_hash() != base.override(
        **{"scheduler.policy": "psbs"}
    ).spec_hash()


def test_psbs_calibration_cell_reports_swept_knobs():
    """The calibration preset's whatif block is self-describing: each
    cell reports the late_factor / max_spread it actually ran with, and
    the knobs reach the built scheduler (not just the report)."""
    sweep = quick_sweep(get_preset("paper-psbs-calibration"))
    cells = dict(sweep.expand())
    cid = (
        "scheduler.error_alpha=1.5,scheduler.psbs_late_factor=2.0,"
        "scheduler.psbs_max_spread=3"
    )
    assert cid in cells
    rep = run_scenario(cells[cid])
    assert rep["whatif"]["late_factor"] == 2.0
    assert rep["whatif"]["max_spread"] == 3
    # Reference grid: las cells are error-alpha swept but knob-free.
    assert any(s.scheduler.policy == "las" for s in cells.values())
    alphas = {s.scheduler.error_alpha for s in cells.values()}
    assert alphas == {1.5, 2.0}  # heavier than the Fig. 6 sweep's max 1.0
