"""The default Hadoop scheduler: FIFO with optional priorities (Sect. 2.2).

"Task assignment is accomplished by scanning through all jobs that are
waiting to be scheduled, in order of priority and job submission time."
No preemption; delay scheduling is NOT part of the stock FIFO scheduler
(it greedily prefers local tasks among the chosen job's pending tasks but
never waits).

Performance: the queue order ``(-weight, arrival_time, job_id)`` is
maintained as a per-phase sorted index updated on arrival (and on the
REDUCE slow-start unlock) instead of re-sorting every live job on every
pass.  FIFO itself never preempts, so in practice a job leaves the
pending set once and dead queue entries are dropped lazily (amortized
compaction); the public ``on_task_killed`` hook — which re-adds pending
demand — re-enqueues if the entry was already compacted away.  A pass
costs O(slots assigned + dead entries scanned), not
O(live jobs x log(live jobs)).
"""

from __future__ import annotations

import bisect

from repro.core.disciplines import ArrivalRank
from repro.core.scheduler import (
    Action,
    ClusterView,
    Scheduler,
    SchedulerConfig,
)
from repro.core.types import ClusterSpec, JobSpec, JobState, Phase

#: The discipline rank this scheduler assembles (registry entry "fifo"):
#: the queue below is a sorted index over exactly this key.
job_sort_key_fifo = ArrivalRank.key_of


class FIFOScheduler(Scheduler):
    name = "fifo"
    rank_policy = ArrivalRank

    def __init__(self, cluster: ClusterSpec, config: SchedulerConfig | None = None):
        cfg = config or SchedulerConfig()
        # Stock FIFO greedily picks local tasks but never delays a slot.
        cfg.locality_max_skips = 0
        super().__init__(cluster, cfg)
        # Per-phase FIFO queue: (sort_key, job_id) tuples kept sorted by
        # bisect on insert.  Entries whose job has left the pending set
        # are skipped during iteration and compacted once they outnumber
        # the live pending entries.  FIFO itself never emits Kill, but
        # the public on_task_killed hook re-adds pending demand — the
        # override below re-enqueues if compaction already dropped the
        # entry (`_queued` tracks which jobs still have one; an entry
        # still in the list simply revives when the job re-enters the
        # pending set).
        self._queue: dict[str, list[tuple[tuple, int]]] = {
            Phase.MAP.value: [], Phase.REDUCE.value: [],
        }
        self._queued: dict[str, set[int]] = {
            Phase.MAP.value: set(), Phase.REDUCE.value: set(),
        }

    def _enqueue(self, js: JobState, phase: Phase) -> None:
        bisect.insort(
            self._queue[phase.value], (job_sort_key_fifo(js), js.spec.job_id)
        )
        self._queued[phase.value].add(js.spec.job_id)

    def on_task_killed(self, att) -> None:
        super().on_task_killed(att)  # re-adds the job's pending demand
        self._requeue(att)

    def on_task_readmitted(self, att) -> None:
        # Fault layer: a FAILED task re-entered PENDING after its
        # re-admission backoff — same re-enqueue contract as KILL.
        super().on_task_readmitted(att)
        self._requeue(att)

    def _requeue(self, att) -> None:
        pv = att.spec.phase.value
        jid = att.spec.job_id
        if jid not in self._queued[pv]:
            js = self.jobs.get(jid)
            if js is not None:
                self._enqueue(js, att.spec.phase)

    def on_job_arrival(self, spec: JobSpec, now: float) -> JobState:
        js = super().on_job_arrival(spec, now)
        if js.n_pending(Phase.MAP):
            self._enqueue(js, Phase.MAP)
        return js

    def _on_reduce_unlocked(self, js: JobState) -> None:
        if js.n_pending(Phase.REDUCE):
            self._enqueue(js, Phase.REDUCE)

    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        self._begin_pass()
        actions: list[Action] = []
        for phase in (Phase.MAP, Phase.REDUCE):
            if self.config.paranoid_indexes:
                self._paranoid_check(view, phase)
                self._check_queue(phase)
            free = view.free_slots(phase)
            if not free:
                continue
            if not self.config.demand_indexed:
                # Legacy walk: re-sort every phase-live job each pass,
                # from a fresh live-table scan (index-free reference).
                for js in sorted(
                    self.live_jobs_scan(phase).values(), key=job_sort_key_fifo
                ):
                    if not free:
                        break
                    acts, free = self._assign_pending(
                        js, phase, free, len(free), now
                    )
                    actions.extend(acts)
                continue
            pv = phase.value
            q = self._queue[pv]
            pend = self._jobs_pending[pv]
            dead = 0
            for entry in q:
                jid = entry[1]
                if jid not in pend:
                    dead += 1  # left the pending set; permanently dead
                    continue
                if not free:
                    break
                acts, free = self._assign_pending(
                    self.jobs[jid], phase, free, len(free), now
                )
                actions.extend(acts)
            # Compact once the *scanned* dead prefix is worth it — dead
            # entries cluster at the head (FIFO order ~ completion
            # order), and the loop above may break long before the tail,
            # so the trigger must not require a full scan.  The constant
            # threshold amortizes: ~64 extra skips per pass at most
            # between compactions.
            if dead > 64 or (dead and dead * 2 > len(q)):
                self._queue[pv] = [e for e in q if e[1] in pend]
                self._queued[pv] = {e[1] for e in self._queue[pv]}
        return actions

    def _check_queue(self, phase: Phase) -> None:
        """Paranoid cross-check: the queue's live entries must cover the
        pending set, in exactly the order a full re-sort would produce."""
        pend = self._jobs_pending[phase.value]
        live = [e[1] for e in self._queue[phase.value] if e[1] in pend]
        ref = [
            js.spec.job_id
            for js in sorted(
                (self.jobs[j] for j in pend), key=job_sort_key_fifo
            )
        ]
        assert live == ref, f"fifo queue mismatch ({phase}): {live} != {ref}"
