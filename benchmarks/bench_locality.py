"""Sect. 4.3 data-locality micro-benchmark.

Paper claim: HFSP reaches 100% MAP-task data locality (vs ~98% for FAIR)
because focusing gives a scheduled job all the slots it needs, so the
random HDFS placement almost always offers a local one."""

from __future__ import annotations

from benchmarks.common import CsvOut, run_fb


def main(out=None) -> dict:
    table = CsvOut("locality", ["scheduler", "locality_pct", "tasks"])
    res_by = {}
    for name in ("fair", "hfsp"):
        res, _, _, _ = run_fb(name, seed=0)
        pct = 100.0 * res.locality_fraction
        res_by[name] = pct
        table.add(name, round(pct, 2), res.locality_hits + res.locality_misses)
    table.emit(out)
    print(f"# locality: HFSP {res_by['hfsp']:.1f}% vs FAIR "
          f"{res_by['fair']:.1f}% (paper: 100% vs 98%)")
    return res_by


if __name__ == "__main__":
    main()
