"""Parallel sweep engine: resumable result store + self-healing workers.

``run_sweep`` expands a :class:`~repro.scenarios.spec.SweepSpec` into its
scenario cells and fans them out across worker *processes* (the simulator
is pure Python — process pools are the only way to use multiple cores).
Results stream into a store (see :mod:`repro.scenarios.store`; the
reference backend is append-only JSONL, ``open_store`` also accepts a
sqlite path) as cells finish, keyed by ``(cell_id, spec_hash)``:

* **resume** — a re-run of an interrupted sweep skips every cell whose
  (cell_id, spec_hash) pair is already stored, recomputing nothing;
* **staleness** — editing a preset changes the affected cells'
  ``spec_hash``, so stale stored results are ignored (and recomputed)
  instead of being silently reused;
* **determinism** — a cell's result is a pure function of its spec (all
  RNG seeds, including the fault-injection seed, are spec fields), so
  parallel/serial execution, any resume order, and any self-healing
  retry or re-issue produce identical stores up to line order.

Self-healing (the parallel path supervises one spawned process per cell
attempt, so a sick cell cannot take the sweep down with it):

* **timeout** — an attempt exceeding the per-cell wall-clock budget is
  killed and counts as a failure;
* **bounded retry** — a failed cell is re-queued with capped exponential
  backoff, up to ``max_retries`` times;
* **quarantine** — a cell failing past its retry budget lands in the
  store as a poison-cell record ``{"quarantined": True, "error": ...}``
  instead of aborting the sweep; ``matrix_report`` lists and excludes
  it.  A resume treats the quarantine record as done — delete its store
  line to retry the cell;
* **straggler re-issue** — a cell running far past the median finished
  wall time gets a second racing attempt on spare capacity; the first
  finisher wins (:class:`repro.core.faults.FirstFinisherWins`) and the
  loser is killed.  Purity makes the race safe: both attempts compute
  the same result.

This module is the *local* (single-machine, private-store) executor.
The distributed fabric reuses the same per-attempt primitives under a
lease protocol: see :mod:`repro.scenarios.worker` (lease-claiming
worker loop), :mod:`repro.scenarios.store` (pluggable shared-store
backends), :mod:`repro.scenarios.lease` (claim/renew/release protocol)
and :mod:`repro.scenarios.coordinator` (``sweep-status`` view).

Workers use the ``spawn`` start method: the parent may hold jax state
(the vcluster jax backend), which does not survive ``fork``.
"""

from __future__ import annotations

import itertools
import time
from pathlib import Path

from repro.core.faults import FirstFinisherWins
from repro.scenarios.runner import run_scenario
from repro.scenarios.spec import ScenarioSpec, SweepSpec
from repro.scenarios.store import (  # noqa: F401 - ResultStore re-exported
    ResultStore,
    SweepStore,
    open_store,
)
from repro.scenarios.worker import (  # noqa: F401 - hook re-exported for tests
    _TEST_HOOK_ENV,
    _cell_worker,
    _quarantine_record,
)


class _Attempt:
    """One running cell attempt (a spawned process + its result pipe)."""

    __slots__ = ("cid", "proc", "conn", "started")

    def __init__(self, cid, proc, conn, started):
        self.cid, self.proc, self.conn, self.started = cid, proc, conn, started


def run_sweep(
    sweep: SweepSpec,
    store: SweepStore | str | Path | None = None,
    workers: int = 0,
    max_cells: int | None = None,
    progress=None,
    timeout: float | None = 600.0,
    max_retries: int = 2,
    retry_backoff: float = 0.5,
    straggler_factor: float = 4.0,
) -> dict[str, dict]:
    """Run (or resume) a sweep; returns {cell_id: scenario_report}.

    ``workers=0`` runs inline (deterministic single-process order, used
    by tests and small presets); ``workers=N`` fans cells out over N
    spawn-based attempt processes under the self-healing supervisor (see
    module docstring).  ``max_cells`` bounds how many *new* cells are
    computed this call — the hook tests use it to interrupt a sweep
    mid-grid and assert resume semantics.  ``progress`` is an optional
    ``f(cell_id, result)`` callback invoked as each cell finishes.

    ``store`` accepts a backend instance or a path (coerced via
    :func:`~repro.scenarios.store.open_store`, so ``results.sqlite``
    selects the sqlite backend).  To spread one sweep across machines
    sharing a store, run :func:`repro.scenarios.worker.run_worker`
    loops instead — this function is the local executor and does not
    take leases.

    Self-healing knobs (parallel path): ``timeout`` is the per-attempt
    wall-clock budget in seconds (None = unbounded); a failed or
    timed-out cell retries up to ``max_retries`` times with capped
    exponential ``retry_backoff`` before being stored as a quarantine
    record; an attempt running past ``straggler_factor`` x the median
    finished wall time is raced by a second attempt (first finisher
    wins).  The inline path applies retry + quarantine only — there is
    no process boundary to kill, so no timeout or re-issue.
    """
    if store is not None and not isinstance(store, SweepStore):
        store = open_store(store)
    cells = sweep.expand()
    done = store.load() if store is not None else {}

    results: dict[str, dict] = {}
    todo: list[tuple[str, ScenarioSpec]] = []
    for cid, spec in cells:
        prior = done.get((cid, spec.spec_hash()))
        if prior is not None:
            results[cid] = prior
        else:
            todo.append((cid, spec))
    if max_cells is not None:
        todo = todo[:max_cells]

    def finish(cid: str, spec: ScenarioSpec, result: dict) -> None:
        results[cid] = result
        if store is not None:
            store.append(cid, spec.spec_hash(), result)
        if progress is not None:
            progress(cid, result)

    if workers <= 1:
        for cid, spec in todo:
            n_fails = 0
            while True:
                try:
                    finish(cid, spec, run_scenario(spec))
                    break
                except Exception as e:  # noqa: BLE001 - bounded retry
                    n_fails += 1
                    if n_fails > max_retries:
                        finish(
                            cid, spec, _quarantine_record(cid, repr(e), n_fails)
                        )
                        break
                    time.sleep(retry_backoff * (2.0 ** (n_fails - 1)))
        return results

    _supervise(
        todo, workers, finish,
        timeout=timeout,
        max_retries=max_retries,
        retry_backoff=retry_backoff,
        straggler_factor=straggler_factor,
    )
    return results


def _supervise(
    todo: list[tuple[str, ScenarioSpec]],
    workers: int,
    finish,
    *,
    timeout: float | None,
    max_retries: int,
    retry_backoff: float,
    straggler_factor: float,
) -> None:
    """The self-healing parallel executor: one spawned process per cell
    attempt, supervised for results, failures, timeouts, and stragglers."""
    import multiprocessing
    from multiprocessing.connection import wait as conn_wait

    ctx = multiprocessing.get_context("spawn")
    spec_of = dict(todo)
    # (not_before, launch-order, cid) — backoff-delayed retries re-enter
    # here; the tiebreaker keeps ordering deterministic.
    order = itertools.count()
    queue: list[tuple[float, int, str]] = [
        (0.0, next(order), cid) for cid, _ in todo
    ]
    n_fails: dict[str, int] = {}
    attempts: dict[str, list[_Attempt]] = {}
    by_conn: dict[object, _Attempt] = {}
    ffw = FirstFinisherWins()
    finished_walls: list[float] = []

    def n_running() -> int:
        return len(by_conn)

    def launch(cid: str) -> None:
        parent_conn, child_conn = ctx.Pipe(duplex=False)
        proc = ctx.Process(
            target=_cell_worker,
            args=(child_conn, cid, spec_of[cid].to_dict()),
            daemon=True,
        )
        proc.start()
        child_conn.close()
        att = _Attempt(cid, proc, parent_conn, time.monotonic())
        attempts.setdefault(cid, []).append(att)
        by_conn[parent_conn] = att

    def kill(att: _Attempt) -> None:
        by_conn.pop(att.conn, None)
        atts = attempts.get(att.cid)
        if atts and att in atts:
            atts.remove(att)
            if not atts:
                del attempts[att.cid]
        try:
            att.conn.close()
        except Exception:
            pass
        if att.proc.is_alive():
            att.proc.terminate()
            att.proc.join(5.0)
            if att.proc.is_alive():  # pragma: no cover - hard hang
                att.proc.kill()
        att.proc.join(5.0)

    def attempt_failed(att: _Attempt, error: str) -> None:
        """One attempt died; the cell fails only when none remain."""
        cid = att.cid
        kill(att)
        if cid in attempts:
            return  # a racing sibling is still in flight
        n = n_fails.get(cid, 0) + 1
        n_fails[cid] = n
        if n > max_retries:
            finish(cid, spec_of[cid], _quarantine_record(cid, error, n))
        else:
            delay = retry_backoff * (2.0 ** (n - 1))
            queue.append((time.monotonic() + delay, next(order), cid))

    while queue or attempts:
        now = time.monotonic()
        queue.sort()
        while queue and n_running() < workers and queue[0][0] <= now:
            _, _, cid = queue.pop(0)
            launch(cid)
        # Straggler re-issue: race a second attempt against any cell
        # running far past the median finished wall time.
        if len(finished_walls) >= 3 and n_running() < workers:
            med = sorted(finished_walls)[len(finished_walls) // 2]
            cutoff = straggler_factor * max(med, 0.1)
            for cid, atts in list(attempts.items()):
                if n_running() >= workers:
                    break
                if len(atts) == 1 and now - atts[0].started > cutoff:
                    launch(cid)
        if not by_conn:
            if queue:  # every cell is sitting out a retry backoff
                time.sleep(min(0.05, max(0.0, queue[0][0] - now)))
            continue
        for conn in conn_wait(list(by_conn), timeout=0.1):
            att = by_conn.get(conn)
            if att is None:
                continue  # a sibling's win already tore this attempt down
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                msg = ("err", "worker process died without sending a result")
            if msg[0] == "ok":
                if ffw.finish(att.cid, id(att)):
                    finished_walls.append(time.monotonic() - att.started)
                    cid = att.cid
                    for other in list(attempts.get(cid, ())):
                        kill(other)  # includes att itself
                    finish(cid, spec_of[cid], msg[1])
            else:
                attempt_failed(att, msg[1])
        if timeout is not None:
            now = time.monotonic()
            for atts in list(attempts.values()):
                for att in list(atts):
                    if now - att.started > timeout:
                        attempt_failed(
                            att, f"timeout: exceeded {timeout}s wall clock"
                        )
