"""Direct unit tests for repro/core/metrics.py.

The metrics were previously exercised only through the benchmarks; these
pin their contracts (exact ECDF shape, percentile conventions, per-class
grouping, delta sign) so the scenario report layer can rely on them.
"""

import math

import numpy as np
import pytest

from repro.core.metrics import (
    SojournSummary,
    ecdf,
    ecdf_quantiles,
    jain_index,
    per_class_sojourns,
    per_job_delta,
    slowdowns,
    summarize,
    tail_quantiles,
)
from repro.core.simulator import SimResult


def _result(arrival: dict, completion: dict) -> SimResult:
    res = SimResult()
    res.arrival.update(arrival)
    res.completion.update(completion)
    return res


# ---------------------------------------------------------------------------
# ecdf
# ---------------------------------------------------------------------------
def test_ecdf_sorted_values_and_uniform_steps():
    xs, ps = ecdf([3.0, 1.0, 2.0, 2.0])
    assert np.array_equal(xs, [1.0, 2.0, 2.0, 3.0])
    assert np.allclose(ps, [0.25, 0.5, 0.75, 1.0])


def test_ecdf_single_value():
    xs, ps = ecdf([7.0])
    assert np.array_equal(xs, [7.0])
    assert np.array_equal(ps, [1.0])


def test_ecdf_quantiles_keys_and_monotonicity():
    q = ecdf_quantiles(list(range(101)))
    assert set(q) == {"p5", "p25", "p50", "p75", "p90", "p95", "p99"}
    assert q["p50"] == 50.0
    vals = [q[k] for k in ("p5", "p25", "p50", "p75", "p90", "p95", "p99")]
    assert vals == sorted(vals)


def test_ecdf_quantiles_empty():
    assert ecdf_quantiles([]) == {
        k: 0.0 for k in ("p5", "p25", "p50", "p75", "p90", "p95", "p99")
    }


# ---------------------------------------------------------------------------
# SojournSummary.of
# ---------------------------------------------------------------------------
def test_sojourn_summary_of_basic():
    s = SojournSummary.of([1.0, 2.0, 3.0, 4.0])
    assert s.mean == 2.5
    assert s.median == 2.5
    assert s.count == 4
    assert s.p95 == pytest.approx(np.percentile([1, 2, 3, 4], 95))


def test_sojourn_summary_of_empty_is_zeros():
    s = SojournSummary.of([])
    assert (s.mean, s.median, s.p95, s.count) == (0.0, 0.0, 0.0, 0)


# ---------------------------------------------------------------------------
# per_class_sojourns / summarize
# ---------------------------------------------------------------------------
def test_per_class_sojourns_groups_and_unknown_class():
    res = _result(
        arrival={0: 0.0, 1: 10.0, 2: 20.0, 3: 0.0},
        completion={0: 5.0, 1: 40.0, 2: 25.0, 3: 9.0},
    )
    per = per_class_sojourns(res, {0: "small", 1: "large", 2: "small"})
    assert per["small"] == [5.0, 5.0]
    assert per["large"] == [30.0]
    assert per["?"] == [9.0]  # job 3 has no class label


def test_per_class_sojourns_ignores_jobs_without_arrival():
    res = _result(arrival={0: 0.0}, completion={0: 5.0, 1: 50.0})
    per = per_class_sojourns(res, {0: "small", 1: "small"})
    assert per == {"small": [5.0]}


def test_summarize_includes_all_bucket():
    res = _result(
        arrival={0: 0.0, 1: 0.0}, completion={0: 10.0, 1: 30.0}
    )
    summ = summarize(res, {0: "small", 1: "large"})
    assert set(summ) == {"small", "large", "all"}
    assert summ["all"].mean == 20.0
    assert summ["small"].count == 1


# ---------------------------------------------------------------------------
# per_job_delta
# ---------------------------------------------------------------------------
def test_per_job_delta_sign_and_intersection():
    a = _result(arrival={0: 0.0, 1: 0.0, 2: 0.0}, completion={0: 20.0, 1: 15.0})
    b = _result(arrival={0: 0.0, 1: 0.0, 2: 0.0}, completion={0: 10.0, 1: 18.0, 2: 5.0})
    delta = per_job_delta(a, b)
    # Only jobs completed in BOTH runs appear; positive = b is better.
    assert set(delta) == {0, 1}
    assert delta[0] == 10.0
    assert delta[1] == -3.0


# ---------------------------------------------------------------------------
# slowdowns
# ---------------------------------------------------------------------------
def test_slowdowns_divides_by_serialized_size():
    res = _result(arrival={0: 0.0, 1: 0.0}, completion={0: 30.0, 1: 8.0})
    slow = slowdowns(res, {0: 10.0, 1: 16.0})
    assert slow[0] == 3.0
    assert slow[1] == 0.5  # parallel speedup -> slowdown below 1


def test_slowdowns_skips_nonpositive_sizes():
    res = _result(arrival={0: 0.0, 1: 0.0}, completion={0: 3.0, 1: 4.0})
    assert slowdowns(res, {0: 0.0}) == {}


# ---------------------------------------------------------------------------
# tail_quantiles / jain_index (PR 8: fairness-and-tails report block)
# ---------------------------------------------------------------------------
def test_tail_quantiles_keys_and_values():
    q = tail_quantiles(list(range(1001)))
    assert set(q) == {"p99", "p999"}
    assert q["p99"] == pytest.approx(np.percentile(range(1001), 99))
    assert q["p999"] == pytest.approx(np.percentile(range(1001), 99.9))
    assert q["p999"] >= q["p99"]


def test_tail_quantiles_empty():
    assert tail_quantiles([]) == {"p99": 0.0, "p999": 0.0}


def test_jain_index_perfectly_fair():
    assert jain_index([3.0, 3.0, 3.0]) == pytest.approx(1.0)


def test_jain_index_one_job_takes_all():
    # n jobs, one gets everything -> index = 1/n.
    assert jain_index([10.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)


def test_jain_index_degenerate_inputs():
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0]) == 1.0


def test_jain_index_range():
    vals = [1.0, 2.0, 3.0, 50.0]
    j = jain_index(vals)
    assert 1.0 / len(vals) <= j <= 1.0
