#!/usr/bin/env python
"""Scheduler-overhead trajectory gate (ROADMAP: "wire BENCH_sched.json
into a history file across PRs so perf regressions are caught
automatically").

Reads the record ``benchmarks/run.py --quick --json`` just wrote, appends
it (timestamped, with its verdict) to a JSONL history file, and fails
when

* the hfsp wall-clock regressed more than ``--threshold`` (default 25%)
  versus the baseline, or
* the recorded 5000x1000 sparse-demand decision latency
  (``sched_sparse_5000x1000.decision_latency_ms``) regressed more than
  ``--threshold`` — the demand-indexed scheduling core's headline cell,
  gated under the same policy as the wall clock (skipped when either
  record predates the block).  An absolute noise floor
  (``--latency-floor``, default 0.3 ms) keeps sub-noise jitter from
  tripping the percentage gate: the cell measures ~0.1 ms per pass and
  container CPU-placement noise is bimodal at that scale, while a real
  loss of the O(actionable) bound lands at >=1 ms (legacy walk: ~10 ms)
  and trips regardless, or
* any scenario-smoke cell's mean / p99 / p999 sojourn (the ``scenarios``
  block: ``paper-fb@quick/<policy>``) worsened more than
  ``--sojourn-threshold`` (default 10%) versus the baseline, or its Jain
  slowdown-fairness index dropped more than ``JAIN_DROP_LIMIT`` absolute
  — a *policy-level* regression gate: a scheduler edit that silently
  degrades scheduling quality (mean, tails, or fairness) fails here even
  if it runs faster, or
* any registry discipline's recorded decision latency at the same
  5000x1000 cell (``sched_disciplines_5000x1000``, Discipline API) lands
  above ``--discipline-factor`` (default 2x) times the hfsp latency —
  a *same-record* sanity bound, not a trajectory: a rank policy that
  loses its cached-order O(actionable) contract on the steady-state
  (heartbeat-only) passes fails here the first time it is recorded
  (the same absolute noise floor applies).  The bound covers the
  median-based steady-state estimator only: event passes legitimately
  pay O(n log n) order rebuilds (hfsp and psbs alike), so the recorded
  ``p99_pass_ms`` is informational, not gated.

The baseline is the most recent entry that did NOT itself fail the gate —
a regressed run is recorded for the trajectory but never becomes the
baseline, so re-running the gate after a failure cannot silently ratchet
the regression in.

Usage (scripts/check.sh runs this after the quick bench):
  python scripts/bench_gate.py [--json BENCH_sched.json] \
      [--history BENCH_history.jsonl] [--threshold 0.25] [--key hfsp] \
      [--sojourn-threshold 0.10]
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import socket
import sys
import time
from pathlib import Path

#: Max tolerated absolute drop of a cell's Jain slowdown-fairness index
#: versus the baseline (the index lives in (0, 1]; the simulation is
#: deterministic, so any drop is a policy change, but tiny shifts from
#: re-tuned tie-breaks are expected PR-to-PR).
JAIN_DROP_LIMIT = 0.05


def machine_fingerprint() -> dict:
    """Identify the machine a benchmark record was taken on.

    Wall-clock numbers only compare meaningfully against a baseline from
    the same hardware; the fingerprint (hostname + CPU count + CPU
    model) travels with each history entry so the gate can detect that
    the machine changed and treat the history as stale rather than
    flagging a bogus regression (or, worse, silently ratcheting a fast
    machine's numbers in as the bar for a slow one)."""
    model = ""
    try:
        with open("/proc/cpuinfo") as f:
            for ln in f:
                if ln.lower().startswith("model name"):
                    model = ln.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return {
        "hostname": socket.gethostname(),
        "cpus": os.cpu_count() or 0,
        "cpu_model": model or platform.processor(),
    }


def sojourn_regressions(
    record: dict, baseline: dict, threshold: float
) -> list[str]:
    """Scenario-smoke cells whose mean sojourn worsened past threshold.

    Only cells present in BOTH records are compared (a renamed or newly
    added scenario has no baseline to regress against).  Besides the
    mean, the tail/fairness keys recorded since PR 8 are gated under the
    same only-when-both-records-carry-it policy: p99/p999 sojourn by the
    same percentage threshold, and Jain's slowdown-fairness index by an
    absolute drop bound (it lives in (0, 1], so percentages mislead).
    """
    out = []
    new_s, old_s = record.get("scenarios", {}), baseline.get("scenarios", {})
    gated = (
        ("mean_sojourn_s", "mean sojourn"),
        ("p99_sojourn_s", "p99 sojourn"),
        ("p999_sojourn_s", "p999 sojourn"),
    )
    for cell in sorted(set(new_s) & set(old_s)):
        for key, label in gated:
            new_m = new_s[cell].get(key)
            old_m = old_s[cell].get(key)
            if new_m is None or old_m is None:
                continue  # cell predates (or dropped) the gated key
            if old_m > 0 and new_m > old_m * (1.0 + threshold):
                out.append(
                    f"{cell}: {label} {old_m:.1f}s -> {new_m:.1f}s "
                    f"({new_m / old_m - 1.0:+.1%})"
                )
        new_j = new_s[cell].get("jain_slowdown")
        old_j = old_s[cell].get("jain_slowdown")
        if new_j is not None and old_j is not None:
            if new_j < old_j - JAIN_DROP_LIMIT:
                out.append(
                    f"{cell}: Jain slowdown-fairness {old_j:.4f} -> "
                    f"{new_j:.4f} (drop > {JAIN_DROP_LIMIT})"
                )
    return out


def discipline_regressions(
    record: dict, factor: float, latency_floor_ms: float
) -> list[str]:
    """Registry disciplines whose recorded decision latency exceeds
    ``factor`` x the same record's hfsp sparse-cell latency (floored by
    the absolute noise guard).  Same-record sanity bound — needs no
    baseline, so a brand-new discipline is gated on first recording."""
    out = []
    hfsp_lat = record.get("sched_sparse_5000x1000", {}).get(
        "decision_latency_ms"
    )
    cells = record.get("sched_disciplines_5000x1000", {})
    if hfsp_lat is None or not cells:
        return out
    limit = max(factor * hfsp_lat, latency_floor_ms)
    for name in sorted(cells):
        lat = cells[name].get("decision_latency_ms")
        if lat is None:
            continue
        if lat > limit:
            out.append(
                f"{name}: decision latency {lat:.4f}ms > limit "
                f"{limit:.4f}ms (= max({factor:.1f}x hfsp "
                f"{hfsp_lat:.4f}ms, {latency_floor_ms}ms floor))"
            )
    return out


def gate(
    json_path: str = "BENCH_sched.json",
    history_path: str = "BENCH_history.jsonl",
    threshold: float = 0.25,
    key: str = "hfsp",
    sojourn_threshold: float = 0.10,
    latency_floor_ms: float = 0.3,
    discipline_factor: float = 2.0,
) -> int:
    # Every malformed-input path below is a one-line diagnosis, never a
    # traceback: the gate runs at the tail of scripts/check.sh and its
    # output is the thing a contributor reads.
    bench_path = Path(json_path)
    if not bench_path.exists():
        print(
            f"bench_gate: no benchmark record at {json_path} — run "
            f"'python benchmarks/run.py --quick --json {json_path}' first; "
            f"nothing to gate"
        )
        return 0
    try:
        record = dict(json.loads(bench_path.read_text()))
    except ValueError:
        print(
            f"bench_gate: {json_path} is not valid JSON — re-run the quick "
            f"bench to regenerate it"
        )
        return 2
    new_wall = (record.get("schedulers") or {}).get(key, {}).get("wall_s")
    if new_wall is None:
        print(
            f"bench_gate: {json_path} lacks the gated key "
            f"schedulers[{key!r}].wall_s — re-run the quick bench "
            f"(or pass the right --key)"
        )
        return 2
    history = Path(history_path)
    # Baseline = newest entry that did not itself fail the gate (entries
    # from before the gate field existed count as passing; unparseable
    # lines — e.g. a torn tail from an interrupted run — are skipped).
    baseline = None
    if history.exists():
        for ln in reversed(history.read_text().splitlines()):
            if not ln.strip():
                continue
            try:
                entry = json.loads(ln)
            except ValueError:
                continue
            if entry.get("gate", "ok") == "ok":
                baseline = entry
                break
    if baseline is not None and (
        (baseline.get("schedulers") or {}).get(key, {}).get("wall_s") is None
    ):
        print(
            f"bench_gate: baseline history entry lacks "
            f"schedulers[{key!r}].wall_s (older record format) — treating "
            f"this run as the first entry, nothing to compare"
        )
        baseline = None
    machine = machine_fingerprint()
    if baseline is not None:
        base_machine = baseline.get("machine")
        # Entries from before the fingerprint field compare as before —
        # only a *known different* machine invalidates the baseline.
        if base_machine is not None and base_machine != machine:
            print(
                f"bench_gate: STALE baseline — recorded on "
                f"{base_machine.get('hostname')!r} "
                f"({base_machine.get('cpus')} cpus, "
                f"{base_machine.get('cpu_model')!r}), this run is on "
                f"{machine['hostname']!r} ({machine['cpus']} cpus, "
                f"{machine['cpu_model']!r}); wall-clock comparison would "
                f"be meaningless — treating this run as a fresh baseline"
            )
            baseline = None
    record["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    record["machine"] = machine
    # Same-record discipline sanity bound (no baseline needed).
    disc_bad = discipline_regressions(
        record, discipline_factor, latency_floor_ms
    )
    if baseline is None:
        record["gate"] = "ok" if not disc_bad else "regression"
        with history.open("a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        print(f"bench_gate: first history entry ({key} {new_wall:.3f}s); "
              f"nothing to compare — fresh clones pass trivially")
        for line in disc_bad:
            print(f"bench_gate:   discipline bound: {line}")
        return 1 if disc_bad else 0
    old_wall = baseline["schedulers"][key]["wall_s"]
    limit = old_wall * (1.0 + threshold)
    wall_ok = new_wall <= limit
    sojourn_bad = sojourn_regressions(record, baseline, sojourn_threshold)
    # Decision-latency gate on the sparse-demand cell (only when both
    # records carry the block — history entries from before PR 4 don't).
    lat_ok, lat_msg = True, None
    new_lat = record.get("sched_sparse_5000x1000", {}).get(
        "decision_latency_ms"
    )
    old_lat = baseline.get("sched_sparse_5000x1000", {}).get(
        "decision_latency_ms"
    )
    if new_lat is not None and old_lat is not None and old_lat > 0:
        # The percentage limit is lower-bounded by an absolute noise
        # floor: at ~0.1 ms per pass, container CPU-placement noise
        # exceeds the percentage threshold run-to-run, while any real
        # loss of the O(actionable) bound lands at >= 1 ms and trips
        # the gate regardless of which mode the baseline sampled.
        lat_limit = max(old_lat * (1.0 + threshold), latency_floor_ms)
        lat_ok = new_lat <= lat_limit
        lat_msg = (
            f"bench_gate: sparse 5000x1000 decision latency "
            f"{old_lat:.4f}ms -> {new_lat:.4f}ms "
            f"(limit {lat_limit:.4f}ms = max(+{threshold:.0%}, "
            f"{latency_floor_ms}ms floor)): "
            f"{'OK' if lat_ok else 'REGRESSION'}"
        )
    verdict = (
        "OK"
        if wall_ok and lat_ok and not sojourn_bad and not disc_bad
        else "REGRESSION"
    )
    record["gate"] = verdict.lower()
    with history.open("a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    print(
        f"bench_gate: {key} wall {old_wall:.3f}s -> {new_wall:.3f}s "
        f"(limit {limit:.3f}s, +{threshold:.0%}): "
        f"{'OK' if wall_ok else 'REGRESSION'}"
    )
    if lat_msg:
        print(lat_msg)
    n_cells = len(
        set(record.get("scenarios", {})) & set(baseline.get("scenarios", {}))
    )
    print(
        f"bench_gate: scenario sojourns ({n_cells} comparable cells, "
        f"+{sojourn_threshold:.0%} limit): "
        f"{'OK' if not sojourn_bad else 'REGRESSION'}"
    )
    for line in sojourn_bad:
        print(f"bench_gate:   {line}")
    n_disc = len(record.get("sched_disciplines_5000x1000", {}))
    print(
        f"bench_gate: discipline latencies ({n_disc} disciplines, "
        f"{discipline_factor:.1f}x hfsp bound): "
        f"{'OK' if not disc_bad else 'REGRESSION'}"
    )
    for line in disc_bad:
        print(f"bench_gate:   {line}")
    if verdict != "OK":
        if not wall_ok:
            print(
                f"bench_gate: {key} wall-clock regressed "
                f"{new_wall / old_wall - 1.0:+.1%} vs the previous entry in "
                f"{history_path}; investigate before merging (or delete the "
                f"stale entry if the machine changed)."
            )
        if not lat_ok:
            print(
                f"bench_gate: sparse-demand decision latency regressed "
                f"{new_lat / old_lat - 1.0:+.1%} vs the previous entry — "
                f"the demand-indexed pass lost its O(actionable) bound; "
                f"investigate before merging."
            )
        if sojourn_bad:
            print(
                "bench_gate: scheduling-quality (mean sojourn) regressed on "
                "the scenario smoke sweep — a policy change, not noise "
                "(the simulation is deterministic); investigate before "
                "merging."
            )
        if disc_bad:
            print(
                "bench_gate: a registry discipline's steady-state pass "
                "exceeds the 2x-hfsp sanity bound — its rank policy lost "
                "the cached-order O(actionable) contract "
                "(docs/disciplines.md); investigate before merging."
            )
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_sched.json")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--key", default="hfsp")
    ap.add_argument("--sojourn-threshold", type=float, default=0.10)
    ap.add_argument("--latency-floor", type=float, default=0.3,
                    metavar="MS", help="absolute decision-latency limit "
                    "floor (noise guard for the sub-ms sparse cell)")
    ap.add_argument("--discipline-factor", type=float, default=2.0,
                    metavar="X", help="same-record bound: max allowed "
                    "discipline latency as a multiple of hfsp's")
    args = ap.parse_args()
    sys.exit(
        gate(
            args.json, args.history, args.threshold, args.key,
            args.sojourn_threshold, args.latency_floor,
            args.discipline_factor,
        )
    )


if __name__ == "__main__":
    main()
