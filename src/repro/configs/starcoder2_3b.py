"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, biases, plain-GELU MLP [arXiv:2402.19173; hf]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    act="gelu",                  # starcoder2: non-gated MLP
    norm="layernorm",
    use_bias=True,
    tie_embeddings=True,
    rope_theta=999_999.4,        # published rope base ~1e6
)

SMOKE = reduced(CONFIG)
