"""Fig. 3 — ECDFs of sojourn times per job class, FIFO vs FAIR vs HFSP.

Paper claims to validate:
* HFSP ~= FAIR for small jobs, significantly shorter for medium/large;
* FIFO mean sojourn is a multiple (paper: ~5x) of HFSP's.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import CsvOut, run_fb
from repro.core.metrics import ecdf, per_class_sojourns, summarize


def main(out=None) -> dict:
    table = CsvOut("fig3_sojourn", [
        "scheduler", "class", "mean_s", "median_s", "p95_s", "count",
    ])
    means = {}
    per_class = {}
    for name in ("fifo", "fair", "hfsp"):
        res, class_of, sch, wall = run_fb(name, seed=0)
        summ = summarize(res, class_of)
        for cls, s in summ.items():
            table.add(name, cls, round(s.mean, 1), round(s.median, 1),
                      round(s.p95, 1), s.count)
        means[name] = summ["all"].mean
        per_class[name] = per_class_sojourns(res, class_of)
    table.emit(out)

    # ECDF quartiles for the figure (printed compactly).
    q = CsvOut("fig3_ecdf", ["scheduler", "class", "p25_s", "p50_s", "p75_s", "p90_s"])
    for name, pc in per_class.items():
        for cls, vals in sorted(pc.items()):
            xs = np.asarray(vals)
            q.add(name, cls, *[round(float(np.percentile(xs, p)), 1)
                               for p in (25, 50, 75, 90)])
    q.emit(out)

    ratio = means["fifo"] / means["hfsp"]
    print(f"# fig3: FIFO/HFSP mean sojourn ratio = {ratio:.2f}x "
          f"(paper: ~5x on their trace); HFSP {means['hfsp']:.0f}s "
          f"FAIR {means['fair']:.0f}s FIFO {means['fifo']:.0f}s")
    return {"means": means, "fifo_over_hfsp": ratio}


if __name__ == "__main__":
    main()
