"""Deterministic synthetic data pipeline.

Produces next-token-prediction batches from a seeded PRNG stream with a
Zipfian token distribution (realistic softmax/label statistics), sharded
per data-parallel rank, with background host prefetch.

The pipeline is the same object on 1 chip and 512: each rank draws its own
slice of the global batch from a rank-folded key, so the global batch is
identical regardless of topology (elastic-rescale safe — the paper's
serialized-size trick needs jobs to be resumable at a different width).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # token frequency skew
    prefetch: int = 2


class SyntheticLM:
    """Infinite deterministic token stream: batch(step, rank, num_ranks)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        # Zipfian unigram distribution over the vocab (stable across calls).
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-data.zipf_a)
        self.probs = probs / probs.sum()

    def batch(self, step: int, rank: int = 0, num_ranks: int = 1) -> dict:
        d, c = self.data, self.cfg
        assert d.global_batch % num_ranks == 0, (d.global_batch, num_ranks)
        per = d.global_batch // num_ranks
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, rank])
        )
        s_text = (
            d.seq_len - c.num_patches if c.family == "vlm" else d.seq_len
        )
        toks = rng.choice(
            c.vocab_size, size=(per, s_text + 1), p=self.probs
        ).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.family == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (per, c.num_patches, c.d_model), dtype=np.float32
            ).astype(np.dtype(c.dtype) if c.dtype != "bfloat16" else np.float32)
        if c.family == "encdec":
            out["frame_embeds"] = rng.standard_normal(
                (per, c.num_frames, c.d_model), dtype=np.float32
            )
        return out


class Prefetcher:
    """Background-thread host prefetch (overlaps batch synthesis/IO with
    device compute)."""

    def __init__(self, source: SyntheticLM, rank: int = 0, num_ranks: int = 1,
                 start_step: int = 0, depth: int | None = None):
        self.source = source
        self.rank, self.num_ranks = rank, num_ranks
        self._q: queue.Queue = queue.Queue(
            maxsize=depth or source.data.prefetch
        )
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        while not self._stop.is_set():
            b = self.source.batch(self._step, self.rank, self.num_ranks)
            self._q.put((self._step, b))
            self._step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
