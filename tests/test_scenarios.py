"""Scenario engine tests: spec algebra, presets, trace replay fidelity,
sweep resume semantics, and the paper-fb acceptance property.

Everything runs at quick scale (30 jobs / 20 machines) so the suite stays
in seconds; the properties pinned here are scale-independent.
"""

import json

import pytest

from repro.scenarios import (
    ResultStore,
    ScenarioSpec,
    SweepSpec,
    WorkloadAxis,
    export_trace,
    get_preset,
    list_presets,
    load_trace,
    matrix_report,
    paper_fb_base,
    quick_sweep,
    run_scenario,
    run_sweep,
)
from repro.scenarios.runner import build_workload
from repro.scenarios.spec import cell_id


# ---------------------------------------------------------------------------
# Spec algebra
# ---------------------------------------------------------------------------
def test_spec_roundtrips_through_json():
    spec = paper_fb_base().override(**{
        "scheduler.policy": "fair", "workload.seed": 7, "heartbeat": 5.0,
    })
    blob = json.dumps(spec.to_dict(), sort_keys=True)
    back = ScenarioSpec.from_dict(json.loads(blob))
    assert back == spec
    assert back.spec_hash() == spec.spec_hash()


def test_override_validates_unknown_fields():
    with pytest.raises(KeyError):
        paper_fb_base().override(**{"scheduler.polcy": "fair"})
    with pytest.raises(KeyError):
        paper_fb_base().override(**{"heartbeet": 1.0})
    with pytest.raises(KeyError):
        # First segment names a plain (non-axis) field.
        paper_fb_base().override(**{"name.typo": "x"})


def test_override_applies_codependent_axis_fields_together():
    # kind="trace" is only valid with trace_path: both land in one replace.
    spec = paper_fb_base().override(**{
        "workload.kind": "trace", "workload.trace_path": "/tmp/x.jsonl",
    })
    assert spec.workload.kind == "trace"


def test_spec_hash_changes_with_any_axis():
    base = paper_fb_base()
    assert base.spec_hash() != base.override(**{"workload.seed": 1}).spec_hash()
    assert base.spec_hash() != base.override(**{"scheduler.error_alpha": 0.5}).spec_hash()


def test_workload_axis_validation():
    with pytest.raises(ValueError):
        WorkloadAxis(kind="nope")
    with pytest.raises(ValueError):
        WorkloadAxis(kind="trace")  # no trace_path


# ---------------------------------------------------------------------------
# Sweeps + presets
# ---------------------------------------------------------------------------
def test_sweep_expansion_union_and_dedup():
    sweep = SweepSpec(
        name="t",
        base=paper_fb_base(),
        grids=(
            SweepSpec.grid(**{"scheduler.policy": ("fifo", "fair")}),
            SweepSpec.grid(**{"scheduler.policy": ("fair", "hfsp")}),
        ),
    )
    cells = sweep.expand()
    ids = [cid for cid, _ in cells]
    assert ids == [
        "scheduler.policy=fifo", "scheduler.policy=fair", "scheduler.policy=hfsp",
    ]


def test_cell_id_is_deterministic_and_sorted():
    a = cell_id((("b", 2), ("a", 1)))
    b = cell_id((("a", 1), ("b", 2)))
    assert a == b == "a=1,b=2"
    assert cell_id(()) == "base"


def test_registered_presets_expand():
    assert "paper-fb" in list_presets()
    for name in list_presets():
        cells = get_preset(name).expand()
        assert cells, name
        assert len({cid for cid, _ in cells}) == len(cells), name


def test_paper_fb_matrix_covers_all_policies():
    policies = {
        spec.scheduler.policy for _, spec in get_preset("paper-fb").expand()
    }
    assert policies == {"fifo", "fair", "hfsp"}


# ---------------------------------------------------------------------------
# Trace export -> import -> replay (bit-identical)
# ---------------------------------------------------------------------------
def test_trace_roundtrip_bit_identical_replay(tmp_path):
    base = paper_fb_base().quick()
    jobs, class_of = build_workload(base)
    path = tmp_path / "golden.jsonl"
    export_trace(path, jobs, class_of, {"generator": "fb", "seed": 0})

    jobs2, class_of2, meta = load_trace(path)
    assert meta["generator"] == "fb"
    assert class_of2 == class_of
    by_id = {j.job_id: j for j in jobs}
    for j2 in jobs2:
        j = by_id[j2.job_id]
        assert j2.arrival_time == j.arrival_time  # bit-exact float
        for a, b in zip(
            j2.map_tasks + j2.reduce_tasks, j.map_tasks + j.reduce_tasks
        ):
            assert a.duration == b.duration
            assert a.input_hosts == b.input_hosts
            assert a.state_bytes == b.state_bytes

    direct = run_scenario(base)
    replay = run_scenario(base.override(**{
        "workload.kind": "trace", "workload.trace_path": str(path),
    }))
    assert (
        replay["completion_fingerprint"] == direct["completion_fingerprint"]
    )


def test_trace_rejects_wrong_kind_and_version(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"kind": "not-a-trace", "version": 1}\n')
    with pytest.raises(ValueError, match="not a repro-trace"):
        load_trace(p)
    p.write_text('{"kind": "repro-trace", "version": 99}\n')
    with pytest.raises(ValueError, match="version"):
        load_trace(p)


# ---------------------------------------------------------------------------
# Sweep engine: resume + staleness + the acceptance property
# ---------------------------------------------------------------------------
def test_sweep_interrupted_resumes_without_recompute(tmp_path):
    sweep = quick_sweep(get_preset("paper-fb"))
    store = ResultStore(tmp_path / "store.jsonl")

    # "Interrupt" mid-grid after 2 of 3 cells.
    first = run_sweep(sweep, store=store, max_cells=2)
    assert len(first) == 2
    stored_lines = store.path.read_text().splitlines()
    assert len(stored_lines) == 2

    # Resume: only the missing cell is computed (store grows by one line,
    # the two finished cells' stored results are returned verbatim).
    resumed = run_sweep(sweep, store=store)
    assert len(resumed) == 3
    lines_after = store.path.read_text().splitlines()
    assert len(lines_after) == 3
    assert lines_after[:2] == stored_lines
    for cid, res in first.items():
        assert resumed[cid]["completion_fingerprint"] == res["completion_fingerprint"]

    # Idempotent: a third run computes nothing.
    again = run_sweep(sweep, store=store)
    assert len(store.path.read_text().splitlines()) == 3
    assert again.keys() == resumed.keys()


def test_sweep_store_invalidates_on_spec_change(tmp_path):
    base = paper_fb_base().quick()
    sweep = SweepSpec(
        name="t", base=base,
        grids=(SweepSpec.grid(**{"scheduler.policy": ("hfsp",)}),),
    )
    store = ResultStore(tmp_path / "store.jsonl")
    run_sweep(sweep, store=store)
    assert len(store.path.read_text().splitlines()) == 1

    # Same cell_id, different base spec -> spec_hash mismatch -> recompute.
    edited = SweepSpec(
        name="t", base=base.override(**{"workload.seed": 1}),
        grids=sweep.grids,
    )
    run_sweep(edited, store=store)
    assert len(store.path.read_text().splitlines()) == 2


def test_sweep_store_tolerates_torn_trailing_line(tmp_path):
    sweep = quick_sweep(get_preset("paper-fb"))
    store = ResultStore(tmp_path / "store.jsonl")
    run_sweep(sweep, store=store, max_cells=1)
    with store.path.open("a") as f:
        f.write('{"cell_id": "torn')  # crash mid-write
    assert len(store.load()) == 1
    resumed = run_sweep(sweep, store=store)
    assert len(resumed) == 3


def test_parallel_sweep_failure_keeps_finished_cells(tmp_path):
    """One failing cell must not discard its siblings' finished work:
    the successes are stored, the failure is raised at the end, and a
    resume recomputes only the failed cell."""
    base = paper_fb_base().quick()
    sweep = SweepSpec(
        name="t", base=base,
        grids=(
            SweepSpec.grid(**{"scheduler.policy": ("fifo", "fair")}),
            SweepSpec.grid(**{
                "workload.kind": ("trace",),
                "workload.trace_path": (str(tmp_path / "missing.jsonl"),),
            }),
        ),
    )
    store = ResultStore(tmp_path / "store.jsonl")
    with pytest.raises(RuntimeError, match="1 sweep cell"):
        run_sweep(sweep, store=store, workers=2)
    assert len(store.load()) == 2  # both good cells stored


def test_paper_fb_quick_hfsp_strictly_lowest():
    """The acceptance property: FIFO, Fair, and HFSP on the same
    synthesized FB trace, HFSP mean sojourn strictly lowest (the paper's
    qualitative Sect. 4.2 result)."""
    results = run_sweep(quick_sweep(get_preset("paper-fb")))
    means = {cid: r["mean_sojourn_s"] for cid, r in results.items()}
    hfsp = means["scheduler.policy=hfsp"]
    assert hfsp < means["scheduler.policy=fair"]
    assert hfsp < means["scheduler.policy=fifo"]
    matrix = matrix_report(results)
    assert matrix["best"] == "scheduler.policy=hfsp"


def test_map_only_axis_strips_reduce_tasks():
    spec = paper_fb_base().quick().override(**{"workload.map_only": True})
    jobs, _ = build_workload(spec)
    assert all(not j.reduce_tasks for j in jobs)
    assert any(j.map_tasks for j in jobs)
