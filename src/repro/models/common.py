"""Shared model components: norms, RoPE, MLPs, initializers, softcaps.

Everything is functional: params are nested dicts of ``jnp`` arrays, and
every function takes ``(cfg, params, inputs)``.  Master parameters are kept
in ``cfg.param_dtype`` (fp32) and cast to the activation dtype at use —
the mixed-precision policy lives here, not in the training loop.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis_size: int, dtype) -> jnp.ndarray:
    """Truncated-normal fan-in init (the common LM choice)."""
    std = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dim: int | None = None) -> dict:
    if cfg.non_parametric_norm:
        return {}
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype=cfg.param_dtype)}
    if cfg.norm == "layernorm" and cfg.use_bias:
        p["bias"] = jnp.zeros((d,), dtype=cfg.param_dtype)
    return p


def apply_norm(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm / LayerNorm, optionally non-parametric (olmo-style)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(var + cfg.norm_eps)
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    if p:
        scale = p["scale"].astype(jnp.float32)
        if cfg.norm == "rmsnorm":
            # gemma-style (1 + scale) keeps init at identity; we use plain
            # scale initialized to 1 for generality.
            x = x * scale
        else:
            x = x * scale
        if "bias" in p:
            x = x + p["bias"].astype(jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(cfg: ModelConfig, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given integer positions, shape (..., head_dim/2)."""
    hd = cfg.head_size
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Softcap (gemma2)
# ---------------------------------------------------------------------------
def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None, *, layers: int | None = None) -> dict:
    """Gated (SwiGLU/GeGLU) or plain 2-layer MLP.  ``layers`` stacks a
    leading layer axis for scan-over-layers."""
    d = cfg.d_model
    dff = d_ff or cfg.d_ff
    pref = () if layers is None else (layers,)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {}
    if cfg.act.endswith("_glu"):
        p["wi"] = dense_init(k1, (*pref, d, dff), d, cfg.param_dtype)
        p["wg"] = dense_init(k3, (*pref, d, dff), d, cfg.param_dtype)
    else:
        p["wi"] = dense_init(k1, (*pref, d, dff), d, cfg.param_dtype)
    p["wo"] = dense_init(k2, (*pref, dff, d), dff, cfg.param_dtype)
    if cfg.use_bias:
        p["bi"] = jnp.zeros((*pref, dff), dtype=cfg.param_dtype)
        p["bo"] = jnp.zeros((*pref, d), dtype=cfg.param_dtype)
    return p


def _act_fn(name: str):
    if name.startswith("silu"):
        return jax.nn.silu
    if name.startswith("gelu"):
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def apply_mlp(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    dtype = x.dtype
    act = _act_fn(cfg.act)
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dtype))
    if "bi" in p:
        h = h + p["bi"].astype(dtype)
    h = act(h)
    if "wg" in p:
        h = h * jnp.einsum("...d,df->...f", x, p["wg"].astype(dtype))
    out = jnp.einsum("...f,fd->...d", h, p["wo"].astype(dtype))
    if "bo" in p:
        out = out + p["bo"].astype(dtype)
    return out


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(cfg: ModelConfig, key) -> dict:
    v = cfg.padded_vocab
    p = {"embedding": embed_init(key, (v, cfg.d_model), cfg.param_dtype)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = dense_init(
            k2, (cfg.d_model, v), cfg.d_model, cfg.param_dtype
        )
    return p


def embed_tokens(cfg: ModelConfig, p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(p["embedding"], tokens, axis=0).astype(cfg.activation_dtype())
    # gemma-style sqrt(d) scaling keeps tied-embedding logits well ranged.
    return x * jnp.asarray(math.sqrt(cfg.d_model), dtype=x.dtype)


def unembed(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    w = p.get("unembed")
    if w is None:
        w = p["embedding"].T
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    logits = softcap(logits, cfg.final_softcap)
    if cfg.vocab_pad:
        # Mask padded vocab entries out of every softmax/argmax.
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy, fp32 accumulation.

    The gold logit is picked with a one-hot einsum, NOT take_along_axis: a
    gather along the vocab dim forces GSPMD to all-gather vocab-sharded
    logits (tens of GB per device at 256k vocab); the one-hot contraction
    keeps the reduction sharded and turns it into a cheap psum."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    return jnp.mean(logz - gold)
