"""Core datatypes shared by every scheduler (HFSP, FIFO, FAIR) and by both
execution substrates (the discrete-event simulator and the JAX gang runtime).

Terminology follows the paper:

* a *job* has two phases, MAP and REDUCE; each phase is a bag of *tasks*;
* a task runs on one *slot* of a *machine* (TaskTracker);
* job *size* is serialized: the sum of its task runtimes as if executed on a
  single slot (Sect. 3.1 — "the remaining amount of work of a job is
  independent of the resources available in the cluster");
* *sojourn time* = completion time - arrival time.

In the TPU adaptation (see DESIGN.md §2) a "machine" is a host with a gang
of chips, a "slot" is a gang slot, and a "task" is a step quantum; the
datatypes are identical, only the duration/cost models differ.

Performance note: schedulers are consulted on *every* simulator event
(tens of thousands per workload), so :class:`JobState` maintains
incremental per-(phase, state) indices — every task state change MUST go
through :meth:`JobState.transition` so that queries stay O(bucket) and
counters stay O(1).
"""

from __future__ import annotations

import enum
import itertools
import math
from dataclasses import dataclass, field


class Phase(enum.Enum):
    MAP = "map"
    REDUCE = "reduce"

    # Members are singletons: identity hash is consistent with enum
    # equality and skips Enum.__hash__'s per-call name hashing — Phase
    # keys index the per-pass bucket dicts on the scheduler hot path.
    __hash__ = object.__hash__

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


class TaskState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    SUSPENDED = "suspended"  # EAGER-preempted; state swapped out
    # Failed (injected fault or machine crash) and sitting out its
    # re-admission backoff.  Not schedulable demand: a FAILED task is
    # neither pending nor live until the fault layer re-admits it
    # (FAILED -> PENDING).  The job's phase stays unfinished throughout.
    FAILED = "failed"
    DONE = "done"

    __hash__ = object.__hash__  # see Phase.__hash__


class Preemption(enum.Enum):
    """Preemption primitive (Sect. 3.3)."""

    EAGER = "eager"  # SUSPEND/RESUME (SIGSTOP/SIGCONT; TPU: HBM<->host DMA)
    WAIT = "wait"    # wait for the running task to drain
    KILL = "kill"    # discard work, re-queue the task from scratch


@dataclass
class TaskSpec:
    """Immutable description of one task."""

    job_id: int
    phase: Phase
    index: int
    duration: float               # true serialized runtime (seconds)
    input_hosts: tuple[int, ...] = ()   # machines holding this task's input
    state_bytes: int = 0          # working-set size (preemption cost model)
    # Cached identity tuple (job_id, phase, index) — hot in every scheduler
    # pass, so computed once.
    key: tuple = field(default=None, repr=False, compare=False)

    def __post_init__(self) -> None:
        self.key = (self.job_id, self.phase.value, self.index)


@dataclass
class JobSpec:
    """Immutable description of one job, as produced by the workload layer."""

    job_id: int
    arrival_time: float
    map_tasks: tuple[TaskSpec, ...]
    reduce_tasks: tuple[TaskSpec, ...]
    weight: float = 1.0           # GPS weight (Sect. 5, "different priorities")
    name: str = ""
    # Fraction of MAP tasks that must finish before REDUCE tasks become
    # schedulable (the alpha parameter of Sect. 2.2, footnote 1).
    reduce_slowstart: float = 1.0

    def tasks(self, phase: Phase) -> tuple[TaskSpec, ...]:
        return self.map_tasks if phase is Phase.MAP else self.reduce_tasks

    @property
    def size_map(self) -> float:
        return sum(t.duration for t in self.map_tasks)

    @property
    def size_reduce(self) -> float:
        return sum(t.duration for t in self.reduce_tasks)

    @property
    def size(self) -> float:
        return self.size_map + self.size_reduce


@dataclass
class TaskAttempt:
    """Mutable run state of one task (possibly across suspend/resume/kill).

    ``state`` must only be changed through :meth:`JobState.transition`.
    """

    spec: TaskSpec
    state: TaskState = TaskState.PENDING
    machine: int | None = None
    progress: float = 0.0         # seconds of work already done
    started_at: float | None = None
    suspended_at: float | None = None
    attempts: int = 0             # bumped on every (re)start, incl. after KILL
    # Monotone per-job suspension order (assigned by JobState.transition);
    # lets machine-grouped scans replay the suspension-bucket order exactly.
    susp_seq: int = 0
    # Fault layer (repro.core.faults): execution-speed multiplier of the
    # current attempt (1.0 nominal, <1.0 while straggling) and the number
    # of injected/crash failures this task has absorbed so far.
    rate: float = 1.0
    failures: int = 0

    @property
    def remaining(self) -> float:
        return max(0.0, self.spec.duration - self.progress)

    def is_schedulable(self) -> bool:
        return self.state is TaskState.PENDING

    def is_live(self) -> bool:
        return self.state in (TaskState.RUNNING, TaskState.SUSPENDED)


@dataclass
class JobState:
    """Mutable bookkeeping for one job inside a scheduler.

    Maintains per-(phase, state) dict buckets (insertion-ordered sets) and a
    MAP pending-by-host index so schedulers can take O(1)/O(bucket)
    decisions at every heartbeat.
    """

    spec: JobSpec
    tasks: dict[tuple, TaskAttempt] = field(default_factory=dict)
    # Estimated serialized size per phase; None until the Training module
    # produces the initial estimate (Sect. 3.2).
    est_size: dict[Phase, float] = field(default_factory=dict)
    # True while the phase size is still the xi-weighted initial guess.
    in_training: dict[Phase, bool] = field(default_factory=dict)
    completion_time: float | None = None
    first_dispatch_time: float | None = None
    locality_hits: int = 0
    locality_misses: int = 0
    # -- incremental indices (private; see transition()) --------------------
    _buckets: dict = field(default_factory=dict, repr=False)
    _pending_by_host: dict = field(default_factory=dict, repr=False)
    _done: dict = field(default_factory=dict, repr=False)
    # SUSPENDED tasks grouped by the machine holding their swapped-out
    # state: phase -> machine -> {key: attempt}.  Lets the HFSP resume path
    # visit only machines that can actually act instead of scanning every
    # suspended task each pass.
    _suspended_by_machine: dict = field(default_factory=dict, repr=False)
    _susp_seq: int = field(default=0, repr=False)

    def __post_init__(self) -> None:
        for phase in (Phase.MAP, Phase.REDUCE):
            for st in TaskState:
                self._buckets[(phase, st)] = {}
            self._done[phase] = 0
            self._suspended_by_machine[phase] = {}
        if not self.tasks:
            for t in itertools.chain(self.spec.map_tasks, self.spec.reduce_tasks):
                att = TaskAttempt(spec=t)
                self.tasks[t.key] = att
                self._buckets[(t.phase, TaskState.PENDING)][t.key] = att
                if t.phase is Phase.MAP:
                    for h in t.input_hosts:
                        self._pending_by_host.setdefault(h, {})[t.key] = att

    # -- the single state-transition entry point ----------------------------
    def transition(self, att: TaskAttempt, new_state: TaskState) -> None:
        phase, key = att.spec.phase, att.spec.key
        old_state = att.state
        if old_state is new_state:
            return
        del self._buckets[(phase, old_state)][key]
        self._buckets[(phase, new_state)][key] = att
        att.state = new_state
        if new_state is TaskState.SUSPENDED:
            self._susp_seq += 1
            att.susp_seq = self._susp_seq
            m = att.machine if att.machine is not None else -1
            self._suspended_by_machine[phase].setdefault(m, {})[key] = att
        elif old_state is TaskState.SUSPENDED:
            m = att.machine if att.machine is not None else -1
            bucket = self._suspended_by_machine[phase].get(m)
            if bucket is not None:
                bucket.pop(key, None)
                if not bucket:
                    del self._suspended_by_machine[phase][m]
        if phase is Phase.MAP and att.spec.input_hosts:
            if old_state is TaskState.PENDING:
                for h in att.spec.input_hosts:
                    self._pending_by_host.get(h, {}).pop(key, None)
            elif new_state is TaskState.PENDING:  # KILL re-queues
                for h in att.spec.input_hosts:
                    self._pending_by_host.setdefault(h, {})[key] = att
        if new_state is TaskState.DONE:
            self._done[phase] += 1
        elif old_state is TaskState.DONE:  # pragma: no cover - never undone
            self._done[phase] -= 1

    # -- O(1) counters -------------------------------------------------------
    def n_state(self, phase: Phase, st: TaskState) -> int:
        return len(self._buckets[(phase, st)])

    def n_pending(self, phase: Phase) -> int:
        return self.n_state(phase, TaskState.PENDING)

    def n_running(self, phase: Phase) -> int:
        return self.n_state(phase, TaskState.RUNNING)

    def n_suspended(self, phase: Phase) -> int:
        return self.n_state(phase, TaskState.SUSPENDED)

    def n_done(self, phase: Phase) -> int:
        return self._done[phase]

    def n_unfinished(self, phase: Phase) -> int:
        return len(self.spec.tasks(phase)) - self._done[phase]

    # -- bucket views (O(bucket size)) ---------------------------------------
    def attempts(self, phase: Phase) -> list[TaskAttempt]:
        return [self.tasks[t.key] for t in self.spec.tasks(phase)]

    def pending(self, phase: Phase) -> list[TaskAttempt]:
        return list(self._buckets[(phase, TaskState.PENDING)].values())

    def iter_pending(self, phase: Phase):
        return iter(self._buckets[(phase, TaskState.PENDING)].values())

    def running(self, phase: Phase) -> list[TaskAttempt]:
        return list(self._buckets[(phase, TaskState.RUNNING)].values())

    def suspended(self, phase: Phase) -> list[TaskAttempt]:
        return list(self._buckets[(phase, TaskState.SUSPENDED)].values())

    def suspended_by_machine(self, phase: Phase) -> dict[int, dict]:
        """SUSPENDED tasks grouped by machine (read-only view).  Within a
        machine, insertion order equals suspension order; across machines,
        ``TaskAttempt.susp_seq`` recovers the global suspension order."""
        return self._suspended_by_machine[phase]

    def unfinished(self, phase: Phase) -> list[TaskAttempt]:
        return [a for a in self.attempts(phase) if a.state is not TaskState.DONE]

    def local_pending(self, machine: int):
        """Pending MAP tasks whose input lives on ``machine`` (delay sched)."""
        return self._pending_by_host.get(machine, {}).values()

    # -- phase queries -------------------------------------------------------
    def phase_done(self, phase: Phase) -> bool:
        return self.n_unfinished(phase) == 0

    def map_completion_fraction(self) -> float:
        total = len(self.spec.map_tasks)
        if total == 0:
            return 1.0
        return self._done[Phase.MAP] / total

    def reduce_unlocked(self) -> bool:
        return self.map_completion_fraction() >= self.spec.reduce_slowstart

    def is_done(self) -> bool:
        return self.phase_done(Phase.MAP) and self.phase_done(Phase.REDUCE)

    def active_phase(self) -> Phase:
        """The phase the job currently needs slots for."""
        return Phase.MAP if not self.phase_done(Phase.MAP) else Phase.REDUCE

    # -- sizes -------------------------------------------------------------
    def true_remaining(self, phase: Phase) -> float:
        return sum(a.remaining for a in self.attempts(phase))

    def estimated_remaining(self, phase: Phase) -> float:
        """Remaining serialized work per the *estimate* (what HFSP sees)."""
        est = self.est_size.get(phase)
        if est is None:
            return math.inf
        done = sum(a.progress for a in self.attempts(phase))
        return max(0.0, est - done)


@dataclass(frozen=True, eq=False)
class SlotKey:
    """One slot on one machine, typed by phase (MAP slots vs REDUCE slots).

    Hash/eq are identity-cached: slot objects are created once by the
    executor and reused, and hashing them is on the scheduler hot path.
    """

    machine: int
    phase: Phase
    index: int

    def __hash__(self) -> int:
        h = self.__dict__.get("_hash")
        if h is None:
            h = hash((self.machine, self.phase.value, self.index))
            object.__setattr__(self, "_hash", h)
        return h

    def __eq__(self, other) -> bool:
        return self is other or (
            isinstance(other, SlotKey)
            and self.machine == other.machine
            and self.phase is other.phase
            and self.index == other.index
        )


@dataclass
class ClusterSpec:
    """Static description of the cluster the scheduler manages.

    The defaults mirror the paper's Amazon cluster: 100 nodes, 4 MAP slots
    and 2 REDUCE slots each (Sect. 4.1).
    """

    num_machines: int = 100
    map_slots_per_machine: int = 4
    reduce_slots_per_machine: int = 2
    # TPU adaptation: cost of EAGER suspend/resume = state_bytes / dma_bw
    # (0 disables the cost model and reproduces SIGSTOP-like behaviour).
    dma_bandwidth: float = 0.0
    # Hysteresis thresholds on total suspended bytes per machine (Sect. 3.3,
    # "Finite machine resources").  When suspended state exceeds `hi`, the
    # scheduler falls back EAGER->WAIT until it drops below `lo`.
    suspend_bytes_hi: int = 1 << 62
    suspend_bytes_lo: int = 1 << 61

    def slots(self, phase: Phase) -> int:
        per = (
            self.map_slots_per_machine
            if phase is Phase.MAP
            else self.reduce_slots_per_machine
        )
        return self.num_machines * per

    def suspend_cost(self, state_bytes: int) -> float:
        if self.dma_bandwidth <= 0:
            return 0.0
        return state_bytes / self.dma_bandwidth


@dataclass
class Assignment:
    """A scheduling decision returned to the executor."""

    task: TaskAttempt
    slot: SlotKey
    local: bool = True
    resumed: bool = False


@dataclass
class SchedulerStats:
    """Counters every scheduler maintains; consumed by benchmarks."""

    suspensions: int = 0
    resumes: int = 0
    kills: int = 0
    waits: int = 0
    delay_sched_waits: int = 0
    training_tasks: int = 0
    hysteresis_fallbacks: int = 0
    # Discipline-API diagnostics: rank-stability preemption hysteresis
    # (repro.core.disciplines.StabilityHysteresis) and PSBS late-job
    # virtual re-injections (PSBSLateAging).
    rank_stability_checks: int = 0
    rank_stability_vetoes: int = 0
    #: Jobs whose stability verdict was refreshed through the fused
    #: per-pass ``rank_stability_batch`` projection (vs one batched
    #: projection per job on the lazy path).
    rank_stability_batched: int = 0
    late_job_bumps: int = 0
    #: Live-service wall-tick maintenance (Scheduler.on_wall_tick ->
    #: PreemptionPolicy.on_wall_refresh): how many wall-clock refresh
    #: rounds ran, and how many cached stability verdicts they
    #: re-priced.  Always 0 in offline simulation (never ticked) —
    #: decision-neutral by contract, so these are telemetry only.
    wall_refreshes: int = 0
    wall_refreshed_verdicts: int = 0
