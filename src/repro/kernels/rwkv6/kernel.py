"""RWKV6 chunked WKV scan as a Pallas TPU kernel.

Grid = (batch, heads, num_chunks), chunks innermost: the TPU's sequential
grid execution carries the (dk x dv) recurrent state in VMEM scratch across
chunk steps — no HBM round-trip for the state inside a sequence.

Per chunk (length c, fp32 math):

    cum      = cumsum(log w)               # (c, dk)
    o_inter  = (r * exp(cum - log w)) @ S
    scores   = (r * a_pre) @ (k / (a_pre * w))^T, strictly lower-triangular
    o_intra  = scores @ v
    o_diag   = ((r * u * k).sum(-1))[:, None] * v
    S        = exp(total) * S + (k * exp(total - cum))^T @ v

The intra-chunk part is two (c x c) matmuls + one (c x dk)x(dk x dv) — all
MXU-shaped with c = 64..256 and dk = dv = 64 (rwkv6 head size).  VMEM per
step at c=256, dk=dv=64: ~0.6 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
    o_ref, sout_ref,
    s_scr,
    *, num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)   # (c, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)   # (c, dv)
    w = w_ref[0, 0].astype(jnp.float32)   # (c, dk)
    u = u_ref[0].astype(jnp.float32)      # (1, dk) -> (dk,)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)        # inclusive
    total = cum[-1:, :]                   # (1, dk)
    a_pre = jnp.exp(cum - logw)           # prod_{i<t} w_i
    S = s_scr[...]

    r_dec = r * a_pre
    o_inter = jax.lax.dot_general(
        r_dec, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    inv_k = k / jnp.maximum(a_pre * w, 1e-30)
    scores = jax.lax.dot_general(
        r_dec, inv_k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    c = r.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    scores = jnp.where(col < row, scores, 0.0)
    o_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)   # (c, 1)
    o = o_inter + o_intra + diag * v
    o_ref[0, 0] = o.astype(o_ref.dtype)

    k_dec = k * jnp.exp(total - cum)
    kv_end = jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                    # (dk, dv)
    s_scr[...] = jnp.exp(total).T * S + kv_end

    @pl.when(ci == num_chunks - 1)
    def _flush():
        sout_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked_bhtd(
    r: jnp.ndarray,   # (b, h, t, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,   # (b, h, t, dv)
    w: jnp.ndarray,   # (b, h, t, dk)  per-channel decays in (0, 1]
    u: jnp.ndarray,   # (h, dk)        bonus
    s0: jnp.ndarray,  # (b, h, dk, dv) carried state (fp32)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)), constant_values=1.0)
    nc = r.shape[2] // chunk
    kernel = functools.partial(_rwkv6_kernel, num_chunks=nc)
    seq_spec = pl.BlockSpec(
        (1, 1, chunk, dk), lambda b_, h_, ci: (b_, h_, ci, 0)
    )
    seq_spec_v = pl.BlockSpec(
        (1, 1, chunk, dv), lambda b_, h_, ci: (b_, h_, ci, 0)
    )
    state_spec = pl.BlockSpec(
        (1, 1, dk, dv), lambda b_, h_, ci: (b_, h_, 0, 0)
    )
    o, s_out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            seq_spec, seq_spec, seq_spec_v, seq_spec,
            pl.BlockSpec((1, dk), lambda b_, h_, ci: (h_, 0)),
            state_spec,
        ],
        out_specs=[seq_spec_v, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, r.shape[2], dv), r.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[_vmem((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    if pad:
        o = o[:, :, :t]
    return o, s_out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
