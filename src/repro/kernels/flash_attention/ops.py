"""Jitted wrapper: model-layout adapter + kernel/ref dispatch.

``flash_attention`` takes the model layout (b, s, h, hd) used everywhere in
:mod:`repro.models` and handles transposition, GQA, scale, and the
interpret-mode fallback used for CPU validation.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jnp.ndarray,   # (b, sq, h, hd)
    k: jnp.ndarray,   # (b, skv, kvh, hd)
    v: jnp.ndarray,
    *,
    mask=None,        # accepted for API parity; kernel derives its own mask
    scale: float,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = flash_attention_bhsd(
        qt, kt, vt,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return jnp.transpose(out, (0, 2, 1, 3))


def flash_attention_reference(q, k, v, *, scale, causal=True, window=None, **_):
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = attention_ref(qt, kt, vt, scale=scale, causal=causal, window=window)
    return jnp.transpose(out, (0, 2, 1, 3))
