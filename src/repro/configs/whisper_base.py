"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=12,               # 6 enc + 6 dec
    enc_layers=6,
    dec_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    vocab_pad=7,          # 51872 = 16*3242: vocab-shardable
    act="gelu",
    norm="layernorm",
    use_bias=True,
    learned_pos_emb=True,
    num_frames=1500,             # 30 s of audio after the conv frontend
    tie_embeddings=True,
)

SMOKE = reduced(CONFIG)
