"""Demand-indexed scheduling core: equivalence, invariants, unit updates.

The base scheduler keeps per-phase demand indexes (``_jobs_pending`` /
``_jobs_suspended`` / ``_jobs_running`` + an O(1) phase-live counter) so a
scheduling pass iterates only jobs with actionable demand.  Contract
(mirrors the PR-1 run-state engine):

* ``SchedulerConfig.demand_indexed=False`` falls back to the legacy full
  walk over every phase-live job and must produce bit-identical schedules
  (completions, locality, preemption stats, pass counts);
* ``SchedulerConfig.paranoid_indexes=True`` rebuilds reference demand
  sets from the live-job table every pass and asserts membership equality
  — drift raises inside the run;
* index membership updates are O(1) per executor event: arrival, task
  start/resume/suspend/kill, completion, the REDUCE slow-start unlock.
"""

import pytest

from conformance import TRACE_SCHEDULERS, assert_traces_equal, run_trace
from repro.core import (
    ClusterSpec,
    FIFOScheduler,
    HFSPConfig,
    HFSPScheduler,
    Phase,
    Simulator,
)
from repro.core.types import JobSpec, TaskSpec, TaskState
from repro.workload import fb_cluster, fb_dataset


@pytest.mark.parametrize("name", TRACE_SCHEDULERS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_demand_indexed_matches_legacy_walk(name, seed):
    """Legacy full-walk passes and demand-indexed passes must schedule
    bit-identically (the pre-filter + position cutoff only skip provable
    no-ops)."""
    indexed = run_trace(name, seed, demand_indexed=True)
    legacy = run_trace(name, seed, demand_indexed=False)
    assert_traces_equal(indexed, legacy)


@pytest.mark.parametrize("name", TRACE_SCHEDULERS)
def test_paranoid_demand_indexes_hold(name):
    """The paranoid cross-check (which now also rebuilds the demand sets
    from the live table every pass) must hold over a full golden trace."""
    checked = run_trace(name, 0, paranoid=True)
    plain = run_trace(name, 0)
    assert_traces_equal(checked, plain)


def test_paranoid_detects_demand_corruption():
    """Corrupting a demand index mid-run must trip the paranoid check."""
    cluster = fb_cluster(num_machines=4)
    jobs, _ = fb_dataset(seed=0, num_jobs=10)
    sch = HFSPScheduler(cluster, HFSPConfig(paranoid_indexes=True))

    orig = sch.on_task_started
    calls = {"n": 0}

    def corrupting_hook(att, slot):
        orig(att, slot)
        calls["n"] += 1
        if calls["n"] == 5:
            # Claim pending demand for a job that has none.
            sch._jobs_pending[Phase.MAP.value][10**6] = None

    sch.on_task_started = corrupting_hook
    with pytest.raises(AssertionError):
        Simulator(cluster, sch, jobs).run()


def _job(jid, n_map=3, n_reduce=2, dur=5.0, slowstart=1.0, arrival=0.0):
    return JobSpec(
        job_id=jid,
        arrival_time=arrival,
        map_tasks=tuple(TaskSpec(jid, Phase.MAP, i, dur) for i in range(n_map)),
        reduce_tasks=tuple(
            TaskSpec(jid, Phase.REDUCE, i, dur) for i in range(n_reduce)
        ),
        reduce_slowstart=slowstart,
    )


def test_index_updates_through_task_lifecycle():
    """Arrival / start / suspend / resume / kill / complete each leave the
    demand sets exactly matching a brute-force recount."""
    sch = FIFOScheduler(ClusterSpec(num_machines=2))
    js = sch.on_job_arrival(_job(1), 0.0)
    mv, rv = Phase.MAP.value, Phase.REDUCE.value
    assert set(sch._jobs_pending[mv]) == {1}
    assert set(sch._jobs_pending[rv]) == set()  # reduce locked (slowstart 1)
    assert sch.n_live_phase(Phase.MAP) == 1
    assert sch.n_live_phase(Phase.REDUCE) == 0

    from repro.core.types import SlotKey

    atts = [js.tasks[(1, "map", i)] for i in range(3)]
    slot = SlotKey(0, Phase.MAP, 0)
    for i, att in enumerate(atts):
        js.transition(att, TaskState.RUNNING)
        att.machine = 0
        sch.on_task_started(att, SlotKey(0, Phase.MAP, i))
    assert set(sch._jobs_pending[mv]) == set()  # all dispatched
    assert 1 in sch._jobs_running[mv]

    js.transition(atts[0], TaskState.SUSPENDED)
    sch.on_task_suspended(atts[0])
    assert set(sch._jobs_suspended[mv]) == {1}
    js.transition(atts[0], TaskState.RUNNING)
    sch.on_task_resumed(atts[0], slot)
    assert set(sch._jobs_suspended[mv]) == set()

    # KILL re-queues: pending demand reappears.
    js.transition(atts[1], TaskState.PENDING)
    sch.on_task_killed(atts[1])
    assert set(sch._jobs_pending[mv]) == {1}

    # Complete every MAP task: phase drains, REDUCE unlocks and registers.
    for i, att in enumerate(atts):
        if att.state is not TaskState.RUNNING:
            js.transition(att, TaskState.RUNNING)
            sch.on_task_started(att, SlotKey(1, Phase.MAP, i))
        js.transition(att, TaskState.DONE)
        sch.on_task_complete(1, att.spec.key, 10.0 + i)
    assert sch.n_live_phase(Phase.MAP) == 0
    assert set(sch._jobs_pending[mv]) == set()
    assert 1 not in sch._jobs_running[mv]
    assert sch.n_live_phase(Phase.REDUCE) == 1
    assert set(sch._jobs_pending[rv]) == {1}


def test_reduce_registration_is_once_and_respects_slowstart():
    """REDUCE demand registers exactly when the slow-start fraction is
    crossed, and only once."""
    sch = FIFOScheduler(ClusterSpec(num_machines=2))
    js = sch.on_job_arrival(_job(2, n_map=4, slowstart=0.5), 0.0)
    rv = Phase.REDUCE.value
    assert set(sch._jobs_pending[rv]) == set()

    from repro.core.types import SlotKey

    keys = [(2, "map", i) for i in range(4)]
    for i, key in enumerate(keys):
        att = js.tasks[key]
        js.transition(att, TaskState.RUNNING)
        sch.on_task_started(att, SlotKey(0, Phase.MAP, i))
    # First completion: fraction 0.25 < 0.5 -> still locked.
    js.transition(js.tasks[keys[0]], TaskState.DONE)
    sch.on_task_complete(2, keys[0], 1.0)
    assert set(sch._jobs_pending[rv]) == set()
    # Second completion crosses 0.5 -> registered.
    js.transition(js.tasks[keys[1]], TaskState.DONE)
    sch.on_task_complete(2, keys[1], 2.0)
    assert set(sch._jobs_pending[rv]) == {2}
    assert sch.n_live_phase(Phase.REDUCE) == 1
    # Further completions must not double-register (count stays 1).
    js.transition(js.tasks[keys[2]], TaskState.DONE)
    sch.on_task_complete(2, keys[2], 3.0)
    assert sch.n_live_phase(Phase.REDUCE) == 1

    # slowstart=0 (or no map tasks): registered at arrival.
    sch2 = FIFOScheduler(ClusterSpec(num_machines=2))
    sch2.on_job_arrival(_job(3, slowstart=0.0), 0.0)
    assert set(sch2._jobs_pending[rv]) == {3}
    sch3 = FIFOScheduler(ClusterSpec(num_machines=2))
    sch3.on_job_arrival(_job(4, n_map=0), 0.0)
    assert set(sch3._jobs_pending[rv]) == {4}


def test_live_jobs_served_from_demand_union():
    """live_jobs()/demand_union membership equals the brute-force
    recount at arbitrary points of a real simulation."""
    cluster = fb_cluster(num_machines=6)
    jobs, _ = fb_dataset(seed=1, num_jobs=15)
    sch = HFSPScheduler(cluster)
    sim = Simulator(cluster, sch, jobs)
    for until in (50.0, 200.0, 800.0, 3000.0):
        sim.run(until=until)
        for phase in (Phase.MAP, Phase.REDUCE):
            ref = {
                js.spec.job_id
                for js in sch._live.values()
                if js.n_unfinished(phase)
                and (phase is Phase.MAP or js.reduce_unlocked())
            }
            got = set(sch.demand_union(phase))
            assert got == ref, f"{phase} at t={until}: {got} != {ref}"
            assert sch.n_live_phase(phase) == len(ref)
            assert {j.spec.job_id for j in sch.live_jobs(phase)} == ref


def test_training_demand_indexes_track_sample_states():
    """The Training module's wanted / running-sample indexes must agree
    with a brute-force probe of every active job's sample-task states at
    arbitrary points of a real simulation."""
    cluster = fb_cluster(num_machines=6)
    jobs, _ = fb_dataset(seed=2, num_jobs=15)
    sch = HFSPScheduler(cluster)
    sim = Simulator(cluster, sch, jobs)
    for until in (30.0, 120.0, 600.0, 2500.0):
        sim.run(until=until)
        tm = sch.training
        for phase in (Phase.MAP, Phase.REDUCE):
            ref_wanted, ref_running = set(), {}
            for jid in tm.active_jobs(phase):
                js = sch.jobs[jid]
                st = tm._training[(jid, phase)]
                for key in st.sample_keys:
                    att = js.tasks[key]
                    if (
                        att.state is TaskState.PENDING
                        and key not in st.observed
                    ):
                        ref_wanted.add(jid)
                    elif att.state is TaskState.RUNNING:
                        ref_running.setdefault(jid, []).append(key)
            assert set(tm.wanted_jobs(phase)) == ref_wanted
            got_running = {
                j: list(ks) for j, ks in tm.running_sample_jobs(phase).items()
            }
            assert got_running == ref_running
            assert tm.n_running_samples(phase) == sum(
                len(v) for v in ref_running.values()
            )


def test_paranoid_covers_training_indexes():
    """Corrupting the Training module's wanted index must trip the
    paranoid pass (the training demand indexes share the hook-update
    contract and its safety net)."""
    cluster = fb_cluster(num_machines=4)
    jobs, _ = fb_dataset(seed=0, num_jobs=10)
    sch = HFSPScheduler(cluster, HFSPConfig(paranoid_indexes=True))

    orig = sch.on_task_started
    calls = {"n": 0}

    def corrupting_hook(att, slot):
        orig(att, slot)
        calls["n"] += 1
        if calls["n"] == 5:
            sch.training._wanted[Phase.MAP][10**6] = None

    sch.on_task_started = corrupting_hook
    with pytest.raises(AssertionError, match="training wanted"):
        Simulator(cluster, sch, jobs).run()


def test_fifo_requeues_on_kill():
    """The public on_task_killed hook re-adds pending demand; FIFO must
    re-enqueue the job even after its queue entry was compacted away."""
    from repro.core.types import SlotKey

    sch = FIFOScheduler(ClusterSpec(num_machines=2))
    js = sch.on_job_arrival(_job(9, n_map=2, n_reduce=0), 0.0)
    mv = Phase.MAP.value
    atts = [js.tasks[(9, "map", i)] for i in range(2)]
    for i, att in enumerate(atts):
        js.transition(att, TaskState.RUNNING)
        att.machine = 0
        sch.on_task_started(att, SlotKey(0, Phase.MAP, i))
    # Simulate compaction dropping the (now dead) entry.
    sch._queue[mv] = []
    sch._queued[mv] = set()
    # Kill one task: pending demand reappears and must be re-queued.
    js.transition(atts[0], TaskState.PENDING)
    sch.on_task_killed(atts[0])
    assert set(sch._jobs_pending[mv]) == {9}
    assert [e[1] for e in sch._queue[mv]] == [9]
    sch._check_queue(Phase.MAP)  # paranoid invariant holds
    # A second kill while the entry is live must not duplicate it.
    js.transition(atts[1], TaskState.PENDING)
    sch.on_task_killed(atts[1])
    assert [e[1] for e in sch._queue[mv]] == [9]


def test_fifo_queue_matches_full_resort():
    """FIFO's arrival-ordered queue (paranoid-checked in-run) must match
    a full re-sort, including weighted jobs and the REDUCE unlock path."""
    import dataclasses

    cluster = fb_cluster(num_machines=6)
    jobs, _ = fb_dataset(seed=0, num_jobs=15)
    # Give a few jobs higher weight so the queue order isn't pure arrival.
    jobs = [
        dataclasses.replace(j, weight=2.0) if j.job_id % 4 == 0 else j
        for j in jobs
    ]
    from repro.core import SchedulerConfig

    sch = FIFOScheduler(cluster, SchedulerConfig(paranoid_indexes=True))
    res = Simulator(cluster, sch, jobs).run()
    assert len(res.completion) == len(jobs)
