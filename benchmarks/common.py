"""Shared benchmark helpers: scheduler construction + CSV emission."""

from __future__ import annotations

import csv
import io
import sys
import time

from repro.core import (
    FairScheduler,
    FIFOScheduler,
    HFSPConfig,
    HFSPScheduler,
    Preemption,
    Simulator,
)
from repro.core.metrics import summarize
from repro.workload import fb_cluster, fb_dataset

SCHEDULERS = {
    "fifo": lambda c, **kw: FIFOScheduler(c),
    "fair": lambda c, **kw: FairScheduler(c),
    "hfsp": lambda c, **kw: HFSPScheduler(c, HFSPConfig(**kw)),
    "hfsp-wait": lambda c, **kw: HFSPScheduler(
        c, HFSPConfig(preemption=Preemption.WAIT, **kw)
    ),
    "hfsp-kill": lambda c, **kw: HFSPScheduler(
        c, HFSPConfig(preemption=Preemption.KILL, **kw)
    ),
}


def run_fb(name: str, *, machines: int = 100, seed: int = 0, num_jobs: int = 100,
           spec=None, track_timeline: bool = False, **sched_kw):
    """One FB-dataset run; returns (SimResult, class_of, scheduler, wall_s)."""
    cluster = fb_cluster(num_machines=machines)
    jobs, class_of = fb_dataset(seed=seed, num_jobs=num_jobs, spec=spec)
    sch = SCHEDULERS[name](cluster, **sched_kw)
    t0 = time.time()
    res = Simulator(cluster, sch, jobs, track_timeline=track_timeline).run()
    return res, class_of, sch, time.time() - t0


class CsvOut:
    """Collects rows and prints a CSV block per benchmark."""

    def __init__(self, bench: str, header: list[str]):
        self.bench = bench
        self.header = header
        self.rows: list[list] = []

    def add(self, *row) -> None:
        self.rows.append(list(row))

    def emit(self, file=None) -> None:
        file = file or sys.stdout
        buf = io.StringIO()
        w = csv.writer(buf)
        w.writerow(["bench"] + self.header)
        for r in self.rows:
            w.writerow([self.bench] + r)
        print(buf.getvalue(), end="", file=file)
