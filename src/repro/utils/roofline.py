"""Roofline analysis from compiled XLA artifacts (no hardware required).

Sources
-------
* ``compiled.cost_analysis()`` -> HLO FLOPs / bytes of the per-device
  program.  CAVEAT: XLA counts while-loop (lax.scan) bodies ONCE, so the
  dry-run measures costs on small *unrolled* depths (``scan_layers=False``)
  and extrapolates linearly in depth: cost(L) = base + L * body, with
  (base, body) solved from two compiles at depths u and 2u
  (u = the layer-pattern period).
* ``compiled.as_text()`` -> collective ops.  Operands are printed as %refs
  (no inline shapes), so we parse each collective's RESULT shape(s) and its
  replica group size n, and charge ring wire-bytes per device:

    all-reduce          2 * Z * (n-1)/n          (Z = result bytes)
    all-gather          Z * (n-1)/n
    reduce-scatter      Z * (n-1)                (operand = n * result)
    all-to-all          Z * (n-1)/n
    collective-permute  Z

Hardware constants (TPU v5e-like, per the assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

Terms (seconds, per chip):
  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes / HBM_BW
  collective = collective_wire_bytes / ICI_BW
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")
_RESULT_RE = re.compile(
    r"=\s+((?:\([^=]*?\))|(?:[a-z]+[0-9]*\[[0-9,]*\]\S*))\s+"
    r"([a-z0-9\-]+?)(-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # collective-permute etc.: conservative


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def scaled(self, factor_by: dict | None = None):
        return self


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Wire bytes per device per collective (see module docstring)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = _RESULT_RE.search(line)
        if not m:
            continue
        result_sig, op, async_suffix = m.group(1), m.group(2), m.group(3)
        if async_suffix == "-done":
            continue
        if op not in _COLLECTIVES:
            continue
        z = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_sig)
        )
        n = _group_size(line)
        if op == "all-reduce":
            wire = 2.0 * z * (n - 1) / max(n, 1)
        elif op == "all-gather":
            wire = z * (n - 1) / max(n, 1)
        elif op == "reduce-scatter":
            wire = z * (n - 1)
        elif op == "all-to-all":
            wire = z * (n - 1) / max(n, 1)
        else:  # collective-permute
            wire = z
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + wire
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    collective_bytes: float      # per device, wire model
    collectives: CollectiveStats | None = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "coll_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def measure_compiled(compiled) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        flops=flops,
        bytes_accessed=nbytes,
        collective_bytes=stats.total_bytes,
        collectives=stats,
    )


def extrapolate_depth(r1: Roofline, r2: Roofline, u: int, L: int) -> Roofline:
    """Linear-in-depth extrapolation from unrolled depths u and 2u to L:
    cost(L) = base + L*body with body = (r2 - r1)/u, base = r1 - u*body."""

    def ext(a: float, b: float) -> float:
        body = (b - a) / u
        base = a - u * body
        return max(base + L * body, 0.0)

    stats = CollectiveStats()
    ops = set(r1.collectives.bytes_by_op) | set(r2.collectives.bytes_by_op)
    for op in ops:
        a = r1.collectives.bytes_by_op.get(op, 0.0)
        b = r2.collectives.bytes_by_op.get(op, 0.0)
        stats.bytes_by_op[op] = ext(a, b)
        ca = r1.collectives.count_by_op.get(op, 0)
        cb = r2.collectives.count_by_op.get(op, 0)
        stats.count_by_op[op] = int(round(ext(float(ca), float(cb))))
    return Roofline(
        flops=ext(r1.flops, r2.flops),
        bytes_accessed=ext(r1.bytes_accessed, r2.bytes_accessed),
        collective_bytes=ext(r1.collective_bytes, r2.collective_bytes),
        collectives=stats,
    )


def model_flops(cfg, shape, *, backward: bool) -> float:
    """Analytic MODEL_FLOPS: 6·N·D (train) or 2·N·D (inference) with
    N = active params, D = tokens processed."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
