"""Grouped-query attention with every flavour the assigned archs need:

* GQA (kv_heads <= heads), RoPE, optional biases;
* sliding-window (local) masks and gemma2-style local/global alternation
  (the per-layer ``is_global`` flag is a *scanned input*, so one scan body
  serves both layer kinds);
* attention-logit softcap (gemma2);
* KV-cache decode (one query token against a ``seq_len`` cache);
* the compute path is pluggable: ``repro.kernels.flash_attention`` replaces
  the naive materialized-scores path on TPU.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, rope_freqs, softcap

NEG_INF = -2.0**30  # large-but-finite: keeps softmax NaN-free on masked rows


def init_attention(cfg: ModelConfig, key, *, layers: int | None = None) -> dict:
    d, h, kvh, hs = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.head_size
    pref = () if layers is None else (layers,)
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (*pref, d, h, hs), d, cfg.param_dtype),
        "wk": dense_init(kk, (*pref, d, kvh, hs), d, cfg.param_dtype),
        "wv": dense_init(kv, (*pref, d, kvh, hs), d, cfg.param_dtype),
        "wo": dense_init(ko, (*pref, h, hs, d), h * hs, cfg.param_dtype),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((*pref, h, hs), dtype=cfg.param_dtype)
        p["bk"] = jnp.zeros((*pref, kvh, hs), dtype=cfg.param_dtype)
        p["bv"] = jnp.zeros((*pref, kvh, hs), dtype=cfg.param_dtype)
        p["bo"] = jnp.zeros((*pref, d), dtype=cfg.param_dtype)
    return p


def _qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return q, k, v


def _out(cfg: ModelConfig, p: dict, o: jnp.ndarray) -> jnp.ndarray:
    dtype = o.dtype
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dtype))
    if "bo" in p:
        y = y + p["bo"].astype(dtype)
    return y


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def causal_mask(
    q_pos: jnp.ndarray,
    k_pos: jnp.ndarray,
    window: int | None,
    is_global,
) -> jnp.ndarray:
    """(q, k) boolean mask.  ``is_global`` may be a traced scalar (scanned
    layer flag): global layers see full causal context, local layers a
    sliding window."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if window is None:
        return causal
    local = causal & (q_pos[:, None] - k_pos[None, :] < window)
    return jnp.where(is_global, causal, local)


def mha(
    cfg: ModelConfig,
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    use_flash: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Core softmax attention.  q: (b, sq, h, hd), k/v: (b, sk, kvh, hd),
    mask: (sq, sk) bool (or (b, sq, sk)).

    Long queries are processed in q-chunks of ``cfg.attn_chunk`` — the XLA
    analogue of the flash kernel's blocking: scores materialize at
    (b, h, chunk, skv) fp32 instead of (b, h, sq, skv), which is what keeps
    the 4k-train and 32k-prefill cells inside HBM without Pallas."""
    groups = q.shape[2] // k.shape[2]
    scale = cfg.query_scale or (1.0 / math.sqrt(cfg.head_size))
    if use_flash and cfg.attn_softcap is None and mask.ndim == 2:
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(
            q, k, v, mask=mask, scale=scale, interpret=interpret
        )
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    sq = q.shape[1]
    chunk = cfg.attn_chunk
    if sq <= chunk or sq % chunk:
        return _mha_dense(cfg, q, k, v, mask, scale)
    nq = sq // chunk

    def one_chunk(i: int, q_c: jnp.ndarray) -> jnp.ndarray:
        if mask.ndim == 2:
            m_c = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 0)
        else:
            m_c = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 1)
        return _mha_dense(cfg, q_c, k, v, m_c, scale)

    if cfg.unroll_inner:
        outs = [
            one_chunk(i, q[:, i * chunk : (i + 1) * chunk]) for i in range(nq)
        ]
        return jnp.concatenate(outs, axis=1)

    q_chunks = q.reshape(q.shape[0], nq, chunk, *q.shape[2:])

    def body(i, q_c):
        return i + 1, one_chunk(i, q_c)

    _, outs = jax.lax.scan(body, 0, jnp.moveaxis(q_chunks, 1, 0))
    return jnp.moveaxis(outs, 0, 1).reshape(q.shape)


def _mha_dense(cfg, q, k, v, mask, scale):
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    if mask.ndim == 2:
        mask_b = mask[None, None, :, :]
    else:
        mask_b = mask[:, None, :, :]
    scores = jnp.where(mask_b, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def attention_block(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    is_global=True,
    *,
    use_flash: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Full self-attention over x (training / prefill path)."""
    b, s, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    cos, sin = rope_freqs(cfg, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    mask = causal_mask(positions[0], positions[0], cfg.sliding_window, is_global)
    o = mha(cfg, q, k, v, mask, use_flash=use_flash, interpret=interpret)
    return _out(cfg, p, o)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
def init_kv_cache(
    cfg: ModelConfig, batch: int, max_seq: int, *, layers: int
) -> dict:
    kvh, hs = cfg.kv_heads, cfg.head_size
    dt = cfg.activation_dtype()
    return {
        "k": jnp.zeros((layers, batch, max_seq, kvh, hs), dtype=dt),
        "v": jnp.zeros((layers, batch, max_seq, kvh, hs), dtype=dt),
    }


def kv_cache_specs(
    cfg: ModelConfig, batch: int, max_seq: int, *, layers: int
) -> dict:
    kvh, hs = cfg.kv_heads, cfg.head_size
    dt = cfg.activation_dtype()
    return {
        "k": jax.ShapeDtypeStruct((layers, batch, max_seq, kvh, hs), dt),
        "v": jax.ShapeDtypeStruct((layers, batch, max_seq, kvh, hs), dt),
    }


def decode_attention_block(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,          # (b, 1, d) — the new token
    positions: jnp.ndarray,  # (b,) — its position
    cache_k: jnp.ndarray,    # (b, S, kvh, hd) — this layer's cache
    cache_v: jnp.ndarray,
    is_global=True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against the cache; returns (out, new_k, new_v)."""
    b, _, _ = x.shape
    S = cache_k.shape[1]
    q, k, v = _qkv(cfg, p, x)  # (b,1,h,hd) / (b,1,kvh,hd)
    cos, sin = rope_freqs(cfg, positions[:, None])  # (b,1,hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # Insert the new kv at its position.
    onehot = jax.nn.one_hot(positions, S, dtype=cache_k.dtype)  # (b, S)
    cache_k = cache_k + onehot[:, :, None, None] * k.astype(cache_k.dtype)
    cache_v = cache_v + onehot[:, :, None, None] * v.astype(cache_v.dtype)
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] <= positions[:, None]  # (b, S)
    if cfg.sliding_window is not None:
        local = (positions[:, None] - k_pos[None, :]) < cfg.sliding_window
        valid_local = valid & local
        valid = jnp.where(is_global, valid, valid_local)
    mask = valid[:, None, :]  # (b, 1, S) -> broadcast as (b, q=1, S)
    o = mha(cfg, q, cache_k, cache_v, mask)
    return _out(cfg, p, o), cache_k, cache_v
