#!/usr/bin/env bash
# One-command gate: tier-1 tests + the quick scheduler benchmark.
#
#   scripts/check.sh            # tests + quick bench, JSON to BENCH_sched.json
#   scripts/check.sh --no-bench # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
  echo
  echo "== quick scheduler benchmark =="
  python -m benchmarks.run --quick --json BENCH_sched.json
fi
