#!/usr/bin/env python
"""Distributed-sweep smoke gate: 2 workers, one SIGKILLed mid-cell.

Launches two ``python -m repro.scenarios worker`` processes against one
shared store (sqlite by default; ``--backend jsonl`` for the reference
backend), slows every cell's first attempt via the sweep test hook so
the kill window is wide, SIGKILLs worker 1 while it provably holds a
lease on an unfinished cell, and then requires:

* **convergence** — the surviving worker completes the paper-fb@quick
  matrix despite the dead worker's abandoned lease (reclaimed after the
  TTL, no human intervention);
* **exactly-once** — every cell is stored exactly once (raw line scan
  for JSONL; key-set check for sqlite) with zero quarantines;
* **observable reclaim** — the store's reissue counter is > 0 (the dead
  worker's lease was expired and taken over, not silently lost).

Exit 0 on success, 1 with a diagnosis on any violation.  Runs in
scripts/check.sh after the service smoke.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.scenarios import get_preset, quick_sweep  # noqa: E402
from repro.scenarios.store import open_store  # noqa: E402
from repro.scenarios.worker import _TEST_HOOK_ENV  # noqa: E402


def _spawn_worker(name: str, store: Path, env: dict, ttl: float):
    return subprocess.Popen(
        [
            sys.executable, "-m", "repro.scenarios", "worker", "paper-fb",
            "--quick", "--store", str(store), "--worker-id", name,
            "--ttl", str(ttl), "--renew-every", str(ttl / 4.0),
            "--poll", "0.2", "--deadline", "240",
        ],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=("sqlite", "jsonl"), default="sqlite")
    ap.add_argument("--ttl", type=float, default=2.0,
                    help="lease TTL: how long the dead worker's cell stays "
                         "unreclaimable")
    ap.add_argument("--slow", type=float, default=3.0,
                    help="per-cell first-attempt delay (the kill window)")
    ap.add_argument("--timeout", type=float, default=240.0)
    args = ap.parse_args()

    tmp = Path(tempfile.mkdtemp(prefix="dist_sweep_smoke_"))
    store_path = tmp / ("store.sqlite" if args.backend == "sqlite" else "store.jsonl")
    hook = tmp / "hook.json"
    hook.write_text(json.dumps({
        "slow_once": {"cells": "*", "seconds": args.slow},
        "state_dir": str(tmp),
    }))
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}{os.pathsep}{env.get('PYTHONPATH', '')}"
    env[_TEST_HOOK_ENV] = str(hook)

    sweep = quick_sweep(get_preset("paper-fb"))
    expected = {(cid, spec.spec_hash()) for cid, spec in sweep.expand()}
    store = open_store(store_path)

    victim = _spawn_worker("smoke-victim", store_path, env, args.ttl)
    survivor = _spawn_worker("smoke-survivor", store_path, env, args.ttl)
    t0 = time.monotonic()
    killed = False
    try:
        # SIGKILL the victim once it provably holds a lease on a cell
        # whose result is not stored yet (i.e. it is mid-cell).
        while not killed:
            if time.monotonic() - t0 > args.timeout:
                print("FAIL: victim never claimed a cell", file=sys.stderr)
                return 1
            done = store.load()
            for key, lease in store.leases().items():
                if lease.worker == "smoke-victim" and key not in done:
                    victim.kill()  # SIGKILL: no cleanup, lease goes stale
                    victim.wait()
                    killed = True
                    print(
                        f"killed smoke-victim mid-cell {key[0]} "
                        f"(lease ttl {args.ttl}s)"
                    )
                    break
            time.sleep(0.05)
        try:
            out, _ = survivor.communicate(timeout=args.timeout)
        except subprocess.TimeoutExpired:
            survivor.kill()
            print("FAIL: survivor did not converge in time", file=sys.stderr)
            return 1
        if survivor.returncode != 0:
            print(
                f"FAIL: survivor exited rc={survivor.returncode}:\n{out}",
                file=sys.stderr,
            )
            return 1
    finally:
        for proc in (victim, survivor):
            if proc.poll() is None:
                proc.kill()

    # -- convergence + zero quarantines -------------------------------
    stored = store.load()
    missing = {cid for cid, _ in expected} - {cid for cid, _ in stored}
    if missing:
        print(f"FAIL: sweep did not converge, missing {missing}", file=sys.stderr)
        return 1
    quarantined = [cid for (cid, _), r in stored.items() if r.get("quarantined")]
    if quarantined:
        print(f"FAIL: quarantined cells {quarantined}", file=sys.stderr)
        return 1

    # -- exactly-once -------------------------------------------------
    if args.backend == "jsonl":
        keys = []
        for ln in store_path.read_text().splitlines():
            try:
                rec = json.loads(ln)
            except json.JSONDecodeError:
                continue
            keys.append((rec["cell_id"], rec["spec_hash"]))
        if len(keys) != len(set(keys)):
            print(f"FAIL: duplicate store lines: {keys}", file=sys.stderr)
            return 1
    if set(stored) != expected:
        print(
            f"FAIL: stored keys {sorted(stored)} != expected {sorted(expected)}",
            file=sys.stderr,
        )
        return 1

    # -- observable reclaim -------------------------------------------
    stats = store.stats()
    if stats["reissues"] < 1:
        print(
            f"FAIL: dead worker's lease was never reclaimed (stats {stats})",
            file=sys.stderr,
        )
        return 1

    wall = time.monotonic() - t0
    print(
        f"OK: {len(stored)} cells exactly-once on {args.backend}, "
        f"0 quarantined, reissues={stats['reissues']}, "
        f"duplicates={stats['duplicates']} ({wall:.1f}s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
