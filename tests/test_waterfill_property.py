"""Hypothesis property tests: the jax water_fill is allocation-equivalent
to the numpy reference loop over the whole (caps, weights, slots) space,
degenerate corners included.

Skips cleanly when hypothesis or jax is unavailable (see
requirements-dev.txt); the fixed-case coverage in tests/test_vcluster_jax.py
still runs there.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
pytest.importorskip("jax")

from hypothesis import example, given, settings, strategies as st  # noqa: E402

from repro.core import vcluster_jax  # noqa: E402
from repro.core.vcluster import _water_fill  # noqa: E402

# Bounded, sane magnitudes: the virtual cluster feeds task counts (caps),
# GPS weights, and slot counts — never denormals or 1e300-scale values.
_cap = st.floats(min_value=0.0, max_value=1e4, allow_nan=False, width=64)
_weight = st.one_of(
    st.just(0.0),  # zero-weight jobs must starve identically
    st.floats(min_value=1e-3, max_value=100.0, allow_nan=False, width=64),
)


@st.composite
def fill_problem(draw):
    n = draw(st.integers(min_value=0, max_value=24))
    caps = draw(
        st.lists(_cap, min_size=n, max_size=n).map(
            lambda xs: np.asarray(xs, dtype=np.float64)
        )
    )
    ws = draw(
        st.lists(_weight, min_size=n, max_size=n).map(
            lambda xs: np.asarray(xs, dtype=np.float64)
        )
    )
    slots = draw(st.floats(min_value=0.0, max_value=2e4, allow_nan=False, width=64))
    return caps, ws, slots


@settings(max_examples=150, deadline=None)  # first examples pay jit compiles
@given(fill_problem())
@example((np.zeros(0), np.zeros(0), 16.0))              # empty cluster
@example((np.array([9.0]), np.array([1.0]), 4.0))       # single job
@example((np.array([3.0, 5.0]), np.array([0.0, 0.0]), 8.0))   # zero weights
@example((np.array([1.0, 2.0]), np.array([1.0, 1.0]), 1e4))   # caps << slots
@example((np.array([0.0, 7.0]), np.array([2.0, 0.0]), 5.0))   # disjoint degeneracy
def test_water_fill_jax_equivalent_to_numpy(problem):
    caps, ws, slots = problem
    ref = _water_fill(caps, ws, slots)
    out = vcluster_jax.water_fill(caps, ws, slots)
    assert out.shape == ref.shape
    # Allocation equivalence: identical up to float-associativity noise
    # (the two algorithms order the arithmetic differently).
    np.testing.assert_allclose(out, ref, rtol=1e-9, atol=1e-6)
    # Both must satisfy the water-fill feasibility invariants exactly.
    for alloc in (ref, out):
        assert (alloc >= -1e-9).all()
        assert (alloc <= caps + 1e-6).all()
        assert alloc.sum() <= slots + 1e-6
        # Zero-weight jobs are starved (Sect. 5 GPS weights semantics).
        # Near-zero, not exact: the numpy loop's capping tolerance can
        # hand a zero-weight job its cap when that cap is itself <= 1e-12.
        if len(alloc):
            assert (np.abs(alloc[ws == 0.0]) <= 1e-9).all()
