"""Lease protocol for distributed sweep execution.

A *lease* is a store row saying "worker W is computing cell
``(cell_id, spec_hash)`` until epoch second ``expires``".  Workers claim
a pending cell before computing it, renew the lease on a heartbeat while
the attempt runs, and release it after storing the result.  A worker
that dies (SIGKILL, machine loss) simply stops renewing: once the TTL
passes, any other worker's ``claim`` takes the cell over — that takeover
is a **reissue** and is counted in the store's stats so chaos tests can
assert that dead workers' cells were observably reclaimed.

Leases are an *optimization*, never a correctness mechanism: the
``(cell_id, spec_hash)`` exactly-once contract lives in the result
append (first finisher wins; duplicate appends are detected, dropped,
and counted).  A worker that loses its lease mid-compute may keep going
— the worst case is a duplicate result that the store drops.

Clocks are wall-clock epoch seconds (``time.time()``): leases must be
comparable across machines sharing a store.  TTLs should therefore be
generous relative to expected clock skew (seconds, not milliseconds).

The JSONL backend persists lease traffic as an append-only event log
(``<store>.leases``); :class:`LeaseState` folds that log into current
leases / worker beats / counters.  The fold is deterministic from the
log alone: whether a claim was a reissue is decided by the *claiming*
writer under the store lock and recorded in the claim row, so readers
never need to re-judge expiry with their own clock.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

#: Default lease TTL in seconds.  Three missed renewals (renew_every
#: defaults to ttl/3) before a cell is up for reclaim.
DEFAULT_TTL = 30.0

#: Counter names every backend's ``stats()`` reports (always all
#: present, zero-initialized).
COUNTERS = ("claims", "reissues", "renews", "releases", "duplicates")


def _now(now: float | None) -> float:
    return time.time() if now is None else now


@dataclass(frozen=True)
class Lease:
    """One held (or expired-but-unreclaimed) cell lease."""

    cell_id: str
    spec_hash: str
    worker: str
    expires: float  # epoch seconds

    def expired(self, now: float | None = None) -> bool:
        return _now(now) >= self.expires

    def remaining(self, now: float | None = None) -> float:
        return self.expires - _now(now)


@dataclass
class LeaseState:
    """Folded view of a lease event log.

    ``leases``: {(cell_id, spec_hash): Lease} still on the books
    (claimed or renewed, not yet released; may be expired).
    ``workers``: {worker: {"last_seen": epoch_s, "info": dict}}.
    ``counters``: see :data:`COUNTERS`.
    """

    leases: dict[tuple[str, str], Lease] = field(default_factory=dict)
    workers: dict[str, dict] = field(default_factory=dict)
    counters: dict[str, int] = field(
        default_factory=lambda: {k: 0 for k in COUNTERS}
    )

    def _beat(self, worker: str, t: float, info: dict | None = None) -> None:
        rec = self.workers.setdefault(worker, {"last_seen": t, "info": {}})
        rec["last_seen"] = max(rec["last_seen"], t)
        if info:
            rec["info"].update(info)

    def apply(self, rec: dict) -> None:
        """Fold one event-log record (unknown ops are ignored so future
        log schema additions stay readable by old coordinators)."""
        op = rec.get("op")
        worker = rec.get("worker", "")
        t = float(rec.get("t", 0.0))
        key = (rec.get("cell_id", ""), rec.get("spec_hash", ""))
        if op == "claim":
            self.leases[key] = Lease(key[0], key[1], worker, float(rec["expires"]))
            self.counters["claims"] += 1
            if rec.get("reissue"):
                self.counters["reissues"] += 1
            self._beat(worker, t)
        elif op == "renew":
            cur = self.leases.get(key)
            if cur is not None and cur.worker == worker:
                self.leases[key] = Lease(key[0], key[1], worker, float(rec["expires"]))
            self.counters["renews"] += 1
            self._beat(worker, t)
        elif op == "release":
            cur = self.leases.get(key)
            if cur is not None and cur.worker == worker:
                del self.leases[key]
            self.counters["releases"] += 1
            self._beat(worker, t)
        elif op == "dup":
            self.counters["duplicates"] += 1
            self._beat(worker, t)
        elif op == "beat":
            self._beat(worker, t, rec.get("info"))


def fold_lease_log(records) -> LeaseState:
    """Fold an iterable of event-log dicts into a :class:`LeaseState`."""
    state = LeaseState()
    for rec in records:
        state.apply(rec)
    return state


class LeaseKeeper:
    """Renews one held lease while its cell computes.

    The worker calls :meth:`tick` from its supervision loop (it polls
    the attempt pipe a few times a second); renewal actually happens
    only every ``renew_every`` seconds.  A failed renewal means the
    lease was lost (expired and reclaimed, or released elsewhere) —
    recorded in :attr:`lost`, but the keeper keeps renewing its
    heartbeat-side effects and the worker keeps computing: the result
    append is the arbiter, a lost lease at worst yields a dropped
    duplicate.
    """

    def __init__(
        self,
        store,
        cell_id: str,
        spec_hash: str,
        worker: str,
        ttl: float,
        renew_every: float | None = None,
    ):
        self.store = store
        self.cell_id = cell_id
        self.spec_hash = spec_hash
        self.worker = worker
        self.ttl = ttl
        self.renew_every = (
            renew_every if renew_every is not None else max(ttl / 3.0, 0.05)
        )
        self.lost = False
        self.renewals = 0
        self._next = time.monotonic() + self.renew_every

    def tick(self) -> None:
        if time.monotonic() < self._next:
            return
        self._next = time.monotonic() + self.renew_every
        if self.store.renew(self.cell_id, self.spec_hash, self.worker, self.ttl):
            self.renewals += 1
        else:
            self.lost = True
