"""Equivalence tests for the incremental scheduler-state engine.

The scheduler base keeps live run-state indexes (``_slot_of``,
``_run_by_job``, ``_run_by_machine``) updated in O(1) per executor event
instead of rebuilding them every pass.  These tests pin the contract:

* ``SchedulerConfig.paranoid_indexes=True`` rebuilds the indexes from the
  executor view on every pass and asserts they match the incremental ones
  (content and per-bucket order) — any drift raises inside the run;
* a paranoid run must produce byte-for-byte the same schedule as a normal
  run: identical completions, locality counters, and preemption stats;
* the lazy virtual-cluster aging must be observationally identical to
  eager per-event aging (the replay applies the same floating-point
  operations in the same order).
"""

import pytest

from conformance import TRACE_SCHEDULERS, assert_traces_equal, run_trace
from repro.core import (
    ClusterSpec,
    FIFOScheduler,
    HFSPConfig,
    HFSPScheduler,
    Phase,
    Simulator,
)
from repro.core.vcluster import VirtualCluster, discrete_allocation
from repro.workload import fb_cluster, fb_dataset


@pytest.mark.parametrize("name", TRACE_SCHEDULERS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_matches_rebuild_reference(name, seed):
    """The cross-checked (rebuild-from-scratch reference) run and the
    plain incremental run must produce identical schedules.  The paranoid
    run itself asserts index equality inside every scheduling pass."""
    fast = run_trace(name, seed, paranoid=False)
    checked = run_trace(name, seed, paranoid=True)
    assert_traces_equal(fast, checked)


def test_paranoid_mode_detects_corruption():
    """Sanity-check that the paranoid cross-check actually fires: corrupt
    the incremental index mid-run and expect the assertion."""
    cluster = fb_cluster(num_machines=4)
    jobs, _ = fb_dataset(seed=0, num_jobs=10)
    sch = HFSPScheduler(cluster, HFSPConfig(paranoid_indexes=True))

    orig = sch.on_task_started
    calls = {"n": 0}

    def corrupting_hook(att, slot):
        orig(att, slot)
        calls["n"] += 1
        if calls["n"] == 5:
            # Move the entry to the wrong machine bucket.  Counts still
            # match (so the cheap resync fallback cannot repair it) — only
            # the paranoid cross-check can catch this.
            pv = slot.phase.value
            sch._run_by_machine[(slot.machine, pv)].pop(att.spec.key)
            sch._run_by_machine.setdefault(
                (slot.machine + 1, pv), {}
            )[att.spec.key] = att

    sch.on_task_started = corrupting_hook
    with pytest.raises(AssertionError):
        Simulator(cluster, sch, jobs).run()


def test_unclaimed_pending_counter():
    """_unclaimed_pending must agree with a direct recount of claimed
    PENDING tasks, across claim kinds."""
    from repro.core.types import JobSpec, TaskSpec, TaskState

    cluster = ClusterSpec(num_machines=2)
    sch = FIFOScheduler(cluster)
    spec = JobSpec(
        job_id=7,
        arrival_time=0.0,
        map_tasks=tuple(TaskSpec(7, Phase.MAP, i, 5.0) for i in range(6)),
        reduce_tasks=(),
    )
    js = sch.on_job_arrival(spec, 0.0)
    sch._begin_pass()
    assert sch._unclaimed_pending(js, Phase.MAP) == 6
    atts = list(js.tasks.values())
    sch._claim(atts[0])
    sch._claim(atts[1])
    assert sch._unclaimed_pending(js, Phase.MAP) == 4
    # A claim of a non-PENDING task must not decrement the counter.
    js.transition(atts[2], TaskState.RUNNING)
    sch._claim(atts[2])
    assert sch._unclaimed_pending(js, Phase.MAP) == 3  # 5 pending - 2 claimed
    sch._begin_pass()
    assert sch._unclaimed_pending(js, Phase.MAP) == 5


def test_lazy_aging_is_exact():
    """Deferred aging + replay must equal eager per-event aging, including
    the mid-sequence reallocation when a job's virtual tail shrinks."""
    def build():
        vc = VirtualCluster(phase=Phase.MAP, slots=8)
        vc.add_job(1, 40.0, 4)    # task_time 10, ecap 4
        vc.add_job(2, 100.0, 10)  # task_time 10, ecap 10
        return vc

    dts = [0.7, 1.3, 2.0, 5.0, 0.1, 3.3, 4.0, 8.0, 1.1]

    eager = build()
    for dt in dts:
        eager.age(dt)
        eager.allocation()  # force materialization after every event

    lazy = build()
    for dt in dts:
        lazy.age(dt)  # all deferred; replayed by the queries below

    for j in (1, 2):
        assert lazy.remaining(j) == eager.remaining(j)
        assert lazy.jobs[j].done == eager.jobs[j].done
        assert lazy.jobs[j].effective_cap() == eager.jobs[j].effective_cap()
    assert lazy.allocation() == eager.allocation()


def test_lazy_aging_order_cache_served_without_flush():
    """schedule_order() on a warm cache must not flush deferred aging
    (aging preserves the projected-finish order)."""
    vc = VirtualCluster(phase=Phase.MAP, slots=4)
    vc.add_job(1, 100.0, 10)
    vc.add_job(2, 40.0, 10)
    before = vc.schedule_order(0.0)
    vc.age(5.0)
    assert vc._pending_dts  # still deferred
    assert vc.schedule_order(5.0) == before
    assert vc._pending_dts  # the cached query did not force a replay
    assert vc.remaining(1) < 100.0  # an explicit query does
    assert not vc._pending_dts


def test_discrete_allocation_leftovers_match_scalar_round_robin():
    """The vectorized leftover distribution must equal the one-slot-at-a-
    time round-robin it replaced."""
    import numpy as np

    def scalar_reference(demands, slots, rank):
        ids = sorted(demands, key=lambda j: (rank.get(j, 0), j))
        caps = np.array([demands[j][0] for j in ids])
        from repro.core.vcluster import _water_fill
        ws = np.array([demands[j][1] for j in ids])
        cont = _water_fill(caps.astype(float), ws.astype(float), float(slots))
        base = np.minimum(np.floor(cont + 1e-9), caps).astype(np.int64)
        free = int(slots) - int(base.sum())
        if free > 0:
            headroom = (caps - base).astype(np.int64)
            while free > 0 and (headroom > 0).any():
                for i in range(len(ids)):
                    if free <= 0:
                        break
                    if headroom[i] > 0:
                        base[i] += 1
                        headroom[i] -= 1
                        free -= 1
        return {j: int(b) for j, b in zip(ids, base)}

    rng = __import__("numpy").random.default_rng(0)
    for trial in range(200):
        n = int(rng.integers(1, 12))
        demands = {
            j: (float(rng.integers(0, 30)), float(rng.uniform(0.1, 4.0)))
            for j in range(n)
        }
        rank = {j: int(rng.integers(0, 10)) for j in range(n)}
        slots = int(rng.integers(0, 80))
        assert discrete_allocation(demands, slots, rank) == scalar_reference(
            demands, slots, rank
        ), (demands, slots, rank)
