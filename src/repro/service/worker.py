"""Worker agents: heartbeat + advisory task execution.

A worker represents one machine of the cluster.  It registers with the
master, heartbeats on a wall-clock period, and *mimes* the tasks the
master dispatches to it (sleeping ``duration / time_scale`` wall
seconds, then reporting ``task_done``).  The mime is advisory by
design: the engine's discrete-event completions are authoritative (the
simulator is the source of truth the twin replays), so a slow, dead or
lying worker can never corrupt scheduling state — it can only *fail to
heartbeat*, which the master turns into a journaled scripted ``crash``
(and a later rejoin into ``recover``), exactly the fault model the
offline suite tests.

Two deployments of the same agent:

* :class:`WorkerAgent` — in-process asyncio task (tests, smoke runs);
  ``die()`` kills it silently (no unregister) to exercise the
  dead-worker path.
* ``python -m repro.service worker --connect HOST:PORT --machine M``
  — subprocess runner wrapping the same class (see __main__.py).
"""

from __future__ import annotations

import asyncio

from repro.service import protocol


class WorkerAgent:
    def __init__(
        self,
        host: str,
        port: int,
        machine: int,
        *,
        heartbeat_wall: float = 0.05,
    ):
        self.host, self.port, self.machine = host, port, machine
        self.heartbeat_wall = heartbeat_wall
        self._tasks: dict[tuple, asyncio.Task] = {}
        self._runner: asyncio.Task | None = None
        self._writer = None
        self.launched = 0
        self.done = 0
        self.preempted = 0

    async def start(self) -> None:
        reader, writer = await asyncio.open_connection(self.host, self.port)
        self._writer = writer
        await protocol.send(writer, {"op": "register", "machine": self.machine})
        self._runner = asyncio.gather(
            self._heartbeats(writer), self._serve(reader, writer)
        )

    async def _heartbeats(self, writer) -> None:
        while True:
            await asyncio.sleep(self.heartbeat_wall)
            await protocol.send(
                writer, {"op": "heartbeat", "machine": self.machine}
            )

    async def _serve(self, reader, writer) -> None:
        while True:
            msg = await protocol.recv(reader)
            if msg is None:
                break
            op = msg.get("op")
            key = tuple(msg.get("key", ()))
            if op == "launch":
                self.launched += 1
                self._tasks[key] = asyncio.ensure_future(
                    self._mime(writer, key, float(msg.get("wall_s", 0.0)))
                )
            elif op in ("suspend", "kill"):
                t = self._tasks.pop(key, None)
                if t is not None:
                    t.cancel()
                    self.preempted += 1
            # "resume" arrives as a fresh launch (the master re-sends
            # the remaining wall time), so no separate handler.

    async def _mime(self, writer, key: tuple, wall_s: float) -> None:
        try:
            await asyncio.sleep(wall_s)
            self.done += 1
            await protocol.send(
                writer,
                {"op": "task_done", "machine": self.machine, "key": list(key)},
            )
        except asyncio.CancelledError:
            pass
        finally:
            self._tasks.pop(key, None)

    async def stop(self) -> None:
        """Graceful stop: cancel everything and close the connection."""
        await self.die()

    async def die(self) -> None:
        """Silent death — no unregister, heartbeats just stop, and the
        master's deadline check turns the silence into a crash event."""
        if self._runner is not None:
            self._runner.cancel()
            try:
                await self._runner
            except (asyncio.CancelledError, Exception):
                pass
            self._runner = None
        for t in self._tasks.values():
            t.cancel()
        self._tasks.clear()
        if self._writer is not None:
            self._writer.close()
            self._writer = None


async def run_worker(
    host: str, port: int, machine: int, heartbeat_wall: float = 0.05
) -> None:
    """Subprocess entry: run one agent until the connection drops."""
    agent = WorkerAgent(host, port, machine, heartbeat_wall=heartbeat_wall)
    await agent.start()
    try:
        await agent._runner
    except (asyncio.CancelledError, ConnectionError):
        pass
