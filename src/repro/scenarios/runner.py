"""Materialize and run one scenario cell.

``run_scenario(spec)`` is the single choke point between the declarative
layer and the simulator: it synthesizes (or replays) the workload, builds
the cluster and scheduler from the spec's axes, runs the discrete-event
simulation, and returns a machine-readable report dict (see
:mod:`repro.scenarios.report`).  Every benchmark, sweep cell, and CLI
invocation goes through here, so a scenario's meaning cannot drift
between consumers.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core import (
    ClusterSpec,
    FaultModel,
    SimConfig,
    SimResult,
    Simulator,
    disciplines,
)
from repro.core.types import JobSpec
from repro.scenarios.report import scenario_report
from repro.scenarios.spec import ScenarioSpec
from repro.workload import (
    WorkloadSpec,
    fb_dataset,
    fb_scaled_dataset,
    job_class,
    ml_dataset,
)


def build_workload(spec: ScenarioSpec) -> tuple[list[JobSpec], dict[int, str]]:
    """Jobs + class_of for the spec's workload axis."""
    w = spec.workload
    num_hosts = w.num_hosts or spec.cluster.num_machines
    if w.kind == "fb":
        wspec = WorkloadSpec(
            num_machines=num_hosts, task_jitter=w.task_jitter
        )
        jobs, class_of = fb_dataset(
            seed=w.seed, num_jobs=w.num_jobs, spec=wspec
        )
    elif w.kind == "fb_scaled":
        wspec = WorkloadSpec(task_jitter=w.task_jitter)
        jobs, class_of = fb_scaled_dataset(
            seed=w.seed,
            num_jobs=w.num_jobs,
            num_machines=num_hosts,
            spec=wspec,
        )
    elif w.kind == "ml":
        jobs, class_of = ml_dataset(seed=w.seed, num_jobs=w.num_jobs)
    elif w.kind == "trace":
        from repro.scenarios.trace import load_trace

        jobs, class_of, _ = load_trace(w.trace_path)
        if not class_of:
            class_of = {
                j.job_id: job_class(len(j.map_tasks)) for j in jobs
            }
    else:  # pragma: no cover - WorkloadAxis validates
        raise ValueError(f"unknown workload kind {w.kind!r}")
    if w.map_only:
        jobs = [dataclasses.replace(j, reduce_tasks=()) for j in jobs]
    return jobs, class_of


def build_cluster(spec: ScenarioSpec) -> ClusterSpec:
    c = spec.cluster
    return ClusterSpec(
        num_machines=c.num_machines,
        map_slots_per_machine=c.map_slots,
        reduce_slots_per_machine=c.reduce_slots,
        dma_bandwidth=c.dma_bandwidth,
    )


def build_scheduler(spec: ScenarioSpec, cluster: ClusterSpec):
    """Resolve the spec's policy name against the discipline registry
    (:mod:`repro.core.disciplines`) and build the scheduler.

    This is where policy names are validated: an unknown name raises
    ``KeyError`` listing the registered disciplines — specs themselves
    are plain data and accept any name, so disciplines registered from
    user code sweep like the built-ins.
    """
    s = spec.scheduler
    return disciplines.build_scheduler(
        s.policy,
        cluster,
        preemption=s.preemption,
        sample_set_size=s.sample_set_size,
        delta=s.delta,
        error_alpha=s.error_alpha,
        error_seed=s.error_seed,
        vc_backend=s.vc_backend,
        psbs_late_factor=s.psbs_late_factor,
        psbs_max_spread=s.psbs_max_spread,
    )


def _materialize_and_run(
    spec: ScenarioSpec,
) -> tuple[SimResult, dict[int, str], object, list[JobSpec]]:
    """The one cell-materialization sequence (every consumer goes
    through here so a scenario's meaning cannot fork)."""
    cluster = build_cluster(spec)
    jobs, class_of = build_workload(spec)
    sch = build_scheduler(spec, cluster)
    # FaultAxis mirrors FaultModel field-for-field; only an enabled axis
    # reaches the simulator (a disabled one must leave the executor
    # bit-identical to a pre-fault build).
    fm = (
        FaultModel(**dataclasses.asdict(spec.faults))
        if spec.faults.enabled
        else None
    )
    res = Simulator(
        cluster,
        sch,
        jobs,
        config=SimConfig(
            heartbeat=spec.heartbeat,
            event_epsilon=spec.event_epsilon,
            faults=fm,
        ),
    ).run()
    return res, class_of, sch, jobs


def simulate(spec: ScenarioSpec) -> tuple[SimResult, dict[int, str], object]:
    """Run the cell; returns (SimResult, class_of, scheduler)."""
    res, class_of, sch, _ = _materialize_and_run(spec)
    return res, class_of, sch


def run_scenario(spec: ScenarioSpec) -> dict:
    """Run one cell and reduce it to the machine-readable report dict."""
    t0 = time.time()
    res, class_of, sch, jobs = _materialize_and_run(spec)
    wall = time.time() - t0
    return scenario_report(spec, res, jobs, class_of, sch, wall)
