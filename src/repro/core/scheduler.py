"""Scheduler framework.

A scheduler is *pure decision logic*: it is driven by events
(`on_job_arrival`, `on_task_complete`, ...) and, when asked, emits a list of
:class:`Action` that an executor applies to the physical cluster.  The same
scheduler object runs unmodified under

* :mod:`repro.core.simulator` — the discrete-event simulator (the paper's
  Mumak analogue), and
* :mod:`repro.runtime`       — the JAX gang-scheduling runtime (the paper's
  Amazon-cluster analogue).

The executor exposes the physical state through the read-only
:class:`ClusterView` protocol; schedulers keep their own per-job bookkeeping
in :class:`~repro.core.types.JobState`.

Every helper here is written to be cheap per scheduling pass: O(free slots
+ live jobs + emitted actions), never O(total tasks) — schedulers run on
every simulator event.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Iterable, Protocol

from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    SchedulerStats,
    SlotKey,
    TaskAttempt,
    TaskState,
)


# ---------------------------------------------------------------------------
# Executor-side view & actions
# ---------------------------------------------------------------------------
class ClusterView(Protocol):
    """Read-only physical cluster state, implemented by each executor."""

    spec: ClusterSpec

    def free_slots(self, phase: Phase) -> list[SlotKey]: ...
    def slot_occupant(self, slot: SlotKey) -> TaskAttempt | None: ...
    def occupied_slots(self, phase: Phase) -> dict[SlotKey, TaskAttempt]: ...
    def machine_suspended_count(self, machine: int) -> int: ...
    def machine_suspended_bytes(self, machine: int) -> int: ...
    def total_suspended_bytes(self) -> int: ...


@dataclass
class Action:
    pass


@dataclass
class Start(Action):
    attempt: TaskAttempt
    slot: SlotKey
    local: bool = True


@dataclass
class Resume(Action):
    attempt: TaskAttempt
    slot: SlotKey


@dataclass
class Suspend(Action):
    attempt: TaskAttempt


@dataclass
class Kill(Action):
    attempt: TaskAttempt


# ---------------------------------------------------------------------------
# Base scheduler
# ---------------------------------------------------------------------------
@dataclass
class SchedulerConfig:
    # Delay scheduling (Sect. 3.1 "Data locality"): how many scheduling
    # opportunities a job may skip waiting for a data-local MAP slot.
    locality_max_skips: int = 3
    locality_enabled: bool = True


class Scheduler(abc.ABC):
    """Common machinery: job registry, locality-aware slot matching."""

    name = "base"

    def __init__(self, cluster: ClusterSpec, config: SchedulerConfig | None = None):
        self.cluster = cluster
        self.config = config or SchedulerConfig()
        self.jobs: dict[int, JobState] = {}
        self.stats = SchedulerStats()
        self._skip_counts: dict[int, int] = {}
        self._skip_marked: dict[int, int] = {}  # job -> pass seq of last skip
        self._pass_seq = 0
        # Live-job index (jobs with completion_time None), kept incrementally.
        self._live: dict[int, JobState] = {}
        # Tasks already given an action in the *current* pass (the executor
        # has not applied the actions yet, so JobState still shows them as
        # PENDING/SUSPENDED — helpers must not hand them out twice).
        self._claimed: set[tuple] = set()

    def _begin_pass(self) -> None:
        self._claimed.clear()
        self._pass_seq += 1

    # -- events (executor -> scheduler) -------------------------------------
    def on_job_arrival(self, spec: JobSpec, now: float) -> JobState:
        js = JobState(spec=spec)
        self.jobs[spec.job_id] = js
        self._live[spec.job_id] = js
        return js

    def on_task_complete(self, job_id: int, key: tuple, now: float) -> None:
        pass

    def on_task_progress(
        self, job_id: int, key: tuple, fraction: float, elapsed: float, now: float
    ) -> None:
        pass

    def on_job_complete(self, job_id: int, now: float) -> None:
        self._live.pop(job_id, None)

    def on_tick(self, now: float) -> None:
        """Periodic heartbeat (executors call this every few sim-seconds)."""

    # -- decisions -----------------------------------------------------------
    @abc.abstractmethod
    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        """Return the actions to apply given current physical state."""

    # -- shared helpers --------------------------------------------------------
    def live_jobs(self, phase: Phase) -> list[JobState]:
        out = []
        for js in self._live.values():
            if phase is Phase.REDUCE and not js.reduce_unlocked():
                continue
            if js.n_unfinished(phase):
                out.append(js)
        return out

    def _demand(self, js: JobState, phase: Phase) -> int:
        """Slots the job could use *right now* in this phase."""
        return js.n_pending(phase) + js.n_suspended(phase) + js.n_running(phase)

    def _unclaimed_pending(self, js: JobState, phase: Phase) -> int:
        """Pending tasks not yet claimed this pass (exact when the claimed
        set is small, which it is — it only holds this pass's actions)."""
        n = js.n_pending(phase)
        if not self._claimed:
            return n
        jid = js.spec.job_id
        claimed_here = sum(
            1
            for k in self._claimed
            if k[0] == jid
            and k[1] == phase.value
            and js.tasks[k].state is TaskState.PENDING
        )
        return n - claimed_here

    # .. locality-aware assignment of pending tasks to free slots ...........
    def _assign_pending(
        self,
        js: JobState,
        phase: Phase,
        free: list[SlotKey],
        budget: int,
        now: float,
        only_keys: Iterable[tuple] | None = None,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Assign up to ``budget`` pending tasks of ``js`` to ``free`` slots.

        MAP tasks use delay scheduling: prefer slots on machines that hold
        the task's input; a job may skip ``locality_max_skips`` scheduling
        opportunities before accepting a non-local slot.  Returns the
        actions plus the still-free slots.  ``only_keys`` restricts the
        candidate tasks (used by the HFSP Training module to dispatch just
        the sample set).
        """
        actions: list[Action] = []
        if budget <= 0 or not free:
            return actions, free
        jid = js.spec.job_id
        restrict: set[tuple] | None = set(only_keys) if only_keys is not None else None

        def eligible(att: TaskAttempt) -> bool:
            k = att.spec.key
            if att.state is not TaskState.PENDING or k in self._claimed:
                return False
            return restrict is None or k in restrict

        if phase is Phase.MAP and self.config.locality_enabled:
            rest_slots: list[SlotKey] = []
            for slot in free:
                if budget <= 0:
                    rest_slots.append(slot)
                    continue
                att = next(
                    (a for a in js.local_pending(slot.machine) if eligible(a)),
                    None,
                )
                if att is not None:
                    self._claimed.add(att.spec.key)
                    actions.append(Start(att, slot, local=True))
                    js.locality_hits += 1
                    budget -= 1
                    self._skip_counts[jid] = 0
                else:
                    rest_slots.append(slot)
            free = rest_slots
            if budget > 0 and free:
                remaining = [a for a in js.iter_pending(phase) if eligible(a)]
                # Tasks with no locality information cannot benefit from
                # waiting — assign them immediately (ML step quanta, or
                # jobs whose replicas are all dead).
                free = list(free)
                for att in [a for a in remaining if not a.spec.input_hosts]:
                    if budget <= 0 or not free:
                        break
                    slot = free.pop(0)
                    self._claimed.add(att.spec.key)
                    actions.append(Start(att, slot, local=True))
                    budget -= 1
                remaining = [a for a in remaining if a.spec.input_hosts]
                if remaining and budget > 0 and free:
                    skips = self._skip_counts.get(jid, 0)
                    if skips < self.config.locality_max_skips:
                        # Delay: skip this opportunity hoping for a local
                        # slot.  Counted at most once per scheduling pass
                        # (the Training module and the job scheduler may
                        # both consider the same job in one pass).
                        if self._skip_marked.get(jid) != self._pass_seq:
                            self._skip_counts[jid] = skips + 1
                            self._skip_marked[jid] = self._pass_seq
                            self.stats.delay_sched_waits += 1
                    else:
                        while remaining and budget > 0 and free:
                            att = remaining.pop(0)
                            slot = free.pop(0)
                            self._claimed.add(att.spec.key)
                            actions.append(Start(att, slot, local=False))
                            js.locality_misses += 1
                            budget -= 1
                        self._skip_counts[jid] = 0
        else:
            # REDUCE tasks (or locality disabled): any slot will do.
            free = list(free)
            for att in js.iter_pending(phase):
                if budget <= 0 or not free:
                    break
                if not eligible(att):
                    continue
                slot = free.pop(0)
                self._claimed.add(att.spec.key)
                actions.append(Start(att, slot, local=True))
                budget -= 1
        return actions, free

    def _resume_suspended(
        self,
        js: JobState,
        phase: Phase,
        free: list[SlotKey],
        budget: int,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Resume suspended tasks on their *own* machines (Sect. 3.3 —
        suspended state is materialized locally and must resume in place)."""
        actions: list[Action] = []
        if budget <= 0:
            return actions, free
        free_by_machine: dict[int, list[SlotKey]] = {}
        for s in free:
            free_by_machine.setdefault(s.machine, []).append(s)
        for att in js.suspended(phase):
            if budget <= 0:
                break
            if att.spec.key in self._claimed:
                continue
            slots = free_by_machine.get(att.machine if att.machine is not None else -1)
            if slots:
                slot = slots.pop(0)
                self._claimed.add(att.spec.key)
                actions.append(Resume(att, slot))
                budget -= 1
        used = {a.slot for a in actions if isinstance(a, Resume)}
        return actions, [s for s in free if s not in used]


def job_sort_key_fifo(js: JobState) -> tuple:
    return (-js.spec.weight, js.spec.arrival_time, js.spec.job_id)
