"""Pluggable result stores for sweep execution.

A store holds finished sweep cells keyed by ``(cell_id, spec_hash)``
plus the coordination rows the distributed fabric needs: TTL'd cell
leases, worker heartbeats, and monotonic counters.  Two backends:

* :class:`ResultStore` — the reference backend: append-only fsync'd
  JSONL for results (unchanged on-disk format since PR 3; a crash loses
  at most a torn trailing line, repaired before the next append) plus an
  append-only ``<path>.leases`` event log for coordination, both guarded
  by ``flock`` so concurrent writers on one (locally shared) filesystem
  interleave safely.
* :class:`SqliteResultStore` — sqlite file safe for concurrent writers
  on a shared filesystem.  ``BEGIN IMMEDIATE`` transactions + busy
  timeout serialize writers; the default rollback journal (not WAL —
  WAL requires shared memory and is explicitly unsafe over NFS) with
  ``synchronous=FULL`` makes commits crash-atomic: a SIGKILL mid-append
  loses at most the uncommitted record, never a committed one.  Each
  operation opens its own short-lived connection, so SIGKILLing a
  worker never wedges the database (sqlite's POSIX locks die with the
  process and the next opener rolls the journal back).

Shared contract (pinned by the backend-parametrized crash-consistency
tests in ``tests/test_dist_sweep.py``):

* ``append`` is **exactly-once** per ``(cell_id, spec_hash)`` across any
  number of concurrent writer processes: the first finisher wins,
  duplicates are detected, dropped, and counted in ``stats()``.
* After any crash, ``load()`` parses cleanly and returns a
  duplicate-free map containing every acknowledged append.
* ``claim`` over another worker's *expired* lease succeeds and counts
  as a ``reissue``; over a live lease it fails.

``open_store`` picks the backend from the path (``.sqlite``/
``.sqlite3``/``.db`` suffix or a ``sqlite:`` prefix -> sqlite, anything
else -> JSONL), so every CLI ``--store`` flag accepts either.
"""

from __future__ import annotations

import json
import os
import sqlite3
import time
from contextlib import closing
from pathlib import Path

from repro.scenarios.lease import COUNTERS, Lease, LeaseState

try:  # pragma: no cover - import guard, exercised only off-POSIX
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX fallback: no locking
    fcntl = None


def _now(now: float | None) -> float:
    return time.time() if now is None else now


def _flock(f) -> None:
    if fcntl is not None:
        fcntl.flock(f.fileno(), fcntl.LOCK_EX)


def _funlock(f) -> None:
    if fcntl is not None:
        fcntl.flock(f.fileno(), fcntl.LOCK_UN)


class SweepStore:
    """Backend interface (see module docstring for the contract)."""

    path: Path

    # -- results ------------------------------------------------------
    def load(self) -> dict[tuple[str, str], dict]:
        """{(cell_id, spec_hash): result} for every stored cell."""
        raise NotImplementedError

    def append(self, cell_id: str, spec_hash: str, result: dict) -> bool:
        """Store one finished cell; False = duplicate detected and
        dropped (the first finisher's record is untouched)."""
        raise NotImplementedError

    # -- leases -------------------------------------------------------
    def claim(
        self,
        cell_id: str,
        spec_hash: str,
        worker: str,
        ttl: float,
        now: float | None = None,
    ) -> bool:
        """Atomically claim a cell for ``ttl`` seconds.  Fails if another
        worker holds an unexpired lease; claiming over an *expired*
        foreign lease succeeds and is counted as a reissue.  Does not
        check whether the result is already stored — racing a stored
        cell is benign (the duplicate append is dropped)."""
        raise NotImplementedError

    def renew(
        self,
        cell_id: str,
        spec_hash: str,
        worker: str,
        ttl: float,
        now: float | None = None,
    ) -> bool:
        """Extend a held lease; False if this worker no longer holds it."""
        raise NotImplementedError

    def release(self, cell_id: str, spec_hash: str, worker: str) -> None:
        """Drop a held lease (no-op if this worker does not hold it)."""
        raise NotImplementedError

    def leases(self) -> dict[tuple[str, str], Lease]:
        """All leases on the books, including expired-but-unreclaimed
        ones (callers filter with ``lease.expired(now)``)."""
        raise NotImplementedError

    # -- worker liveness ---------------------------------------------
    def heartbeat(
        self, worker: str, info: dict | None = None, now: float | None = None
    ) -> None:
        raise NotImplementedError

    def workers(self) -> dict[str, dict]:
        """{worker: {"last_seen": epoch_s, "info": dict}}."""
        raise NotImplementedError

    # -- observability ------------------------------------------------
    def stats(self) -> dict[str, int]:
        """Coordination counters (all :data:`~repro.scenarios.lease.COUNTERS`
        keys always present)."""
        raise NotImplementedError


class ResultStore(SweepStore):
    """Append-only JSONL store of finished sweep cells (reference backend).

    One line per finished cell::

        {"cell_id": ..., "spec_hash": ..., "result": {scenario_report}}

    Append-only + line-granular means a crash mid-write loses at most the
    last line (a torn trailing line is detected and ignored on load).
    Appends take an exclusive ``flock`` and re-scan the file's new bytes
    (incrementally, from a per-process offset cache) before writing, so
    concurrent writers racing the same cell keep the store exactly-once.

    Coordination rows live in a sidecar event log ``<path>.leases``
    (same torn-line-tolerant JSONL discipline), folded through
    :class:`~repro.scenarios.lease.LeaseState`.
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.lease_path = Path(str(self.path) + ".leases")
        # Incremental duplicate-scan cache: keys seen up to byte offset
        # _scan_pos.  Only complete lines advance the offset; under the
        # append lock the cache is refreshed from the new bytes first.
        self._seen: set[tuple[str, str]] = set()
        self._scan_pos = 0
        # Incremental lease-log fold cache, same discipline.
        self._lease_state = LeaseState()
        self._lease_pos = 0

    # -- results ------------------------------------------------------
    def load(self) -> dict[tuple[str, str], dict]:
        """{(cell_id, spec_hash): result} for every intact stored line."""
        out: dict[tuple[str, str], dict] = {}
        if not self.path.exists():
            return out
        with self.path.open() as f:
            for ln in f:
                ln = ln.strip()
                if not ln:
                    continue
                try:
                    rec = json.loads(ln)
                except json.JSONDecodeError:
                    continue  # torn trailing line from an interrupted run
                out[(rec["cell_id"], rec["spec_hash"])] = rec["result"]
        return out

    def _refresh_seen(self, f) -> set[tuple[str, str]]:
        """Fold bytes appended since the last scan into the seen-keys
        cache (caller holds the lock).  A complete-JSON tail missing its
        newline (torn by a crash after the JSON but before the ``\\n``)
        is counted as seen but does not advance the offset — the next
        append's newline repair completes it.  An out-of-band truncation
        (file shorter than the cached offset — e.g. an operator resetting
        a damaged store under a live process) invalidates the cache, so
        rebuild it from byte 0."""
        f.seek(0, os.SEEK_END)
        if f.tell() < self._scan_pos:
            self._seen.clear()
            self._scan_pos = 0
        f.seek(self._scan_pos)
        data = f.read()
        end = data.rfind(b"\n")
        lines = data[: end + 1].splitlines() if end >= 0 else []
        tail = data[end + 1 :] if end >= 0 else data
        if tail:
            lines = [*lines, tail]
        for ln in lines:
            try:
                rec = json.loads(ln)
                self._seen.add((rec["cell_id"], rec["spec_hash"]))
            except (json.JSONDecodeError, KeyError, TypeError, UnicodeDecodeError):
                continue
        if end >= 0:
            self._scan_pos += end + 1
        return self._seen

    def append(self, cell_id: str, spec_hash: str, result: dict) -> bool:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        rec = {"cell_id": cell_id, "spec_hash": spec_hash, "result": result}
        with self.path.open("a+b") as f:
            _flock(f)
            try:
                if (cell_id, spec_hash) in self._refresh_seen(f):
                    self._count_dup(cell_id, spec_hash)
                    return False
                # A crash can lose the previous record's trailing newline
                # while its JSON survived (load() still recovers it);
                # appending onto that unterminated line would corrupt
                # BOTH records, so repair the newline first.
                f.seek(0, os.SEEK_END)
                lead = b""
                if f.tell() > 0:
                    f.seek(-1, os.SEEK_END)
                    if f.read(1) != b"\n":
                        lead = b"\n"
                f.seek(0, os.SEEK_END)
                f.write(lead + (json.dumps(rec, sort_keys=True) + "\n").encode())
                f.flush()
                os.fsync(f.fileno())
                self._seen.add((cell_id, spec_hash))
            finally:
                _funlock(f)
        return True

    # -- lease event log ----------------------------------------------
    def _with_leases(self, fn):
        """Run ``fn(f)`` with the lease log open, locked, and the fold
        cache refreshed to its current end."""
        self.lease_path.parent.mkdir(parents=True, exist_ok=True)
        with self.lease_path.open("a+b") as f:
            _flock(f)
            try:
                self._refresh_lease_state(f)
                return fn(f)
            finally:
                _funlock(f)

    def _refresh_lease_state(self, f) -> None:
        f.seek(0, os.SEEK_END)
        if f.tell() < self._lease_pos:
            self._lease_state = LeaseState()
            self._lease_pos = 0
        f.seek(self._lease_pos)
        data = f.read()
        end = data.rfind(b"\n")
        if end < 0:
            return
        for ln in data[: end + 1].splitlines():
            try:
                self._lease_state.apply(json.loads(ln))
            except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                continue
        self._lease_pos += end + 1

    def _lease_append(self, f, rec: dict) -> None:
        f.seek(0, os.SEEK_END)
        lead = b""
        if f.tell() > 0:
            f.seek(-1, os.SEEK_END)
            if f.read(1) != b"\n":
                lead = b"\n"
        f.seek(0, os.SEEK_END)
        f.write(lead + (json.dumps(rec, sort_keys=True) + "\n").encode())
        f.flush()
        os.fsync(f.fileno())
        self._lease_state.apply(rec)
        self._lease_pos = f.tell()

    def _count_dup(self, cell_id: str, spec_hash: str) -> None:
        self._with_leases(
            lambda f: self._lease_append(
                f,
                {
                    "op": "dup",
                    "cell_id": cell_id,
                    "spec_hash": spec_hash,
                    "worker": "",
                    "t": time.time(),
                },
            )
        )

    # -- leases -------------------------------------------------------
    def claim(self, cell_id, spec_hash, worker, ttl, now=None) -> bool:
        t = _now(now)

        def do(f):
            key = (cell_id, spec_hash)
            cur = self._lease_state.leases.get(key)
            reissue = False
            if cur is not None and cur.worker != worker:
                if not cur.expired(t):
                    return False
                reissue = True
            self._lease_append(
                f,
                {
                    "op": "claim",
                    "cell_id": cell_id,
                    "spec_hash": spec_hash,
                    "worker": worker,
                    "expires": t + ttl,
                    "t": t,
                    "reissue": reissue,
                },
            )
            return True

        return self._with_leases(do)

    def renew(self, cell_id, spec_hash, worker, ttl, now=None) -> bool:
        t = _now(now)

        def do(f):
            cur = self._lease_state.leases.get((cell_id, spec_hash))
            if cur is None or cur.worker != worker:
                return False
            self._lease_append(
                f,
                {
                    "op": "renew",
                    "cell_id": cell_id,
                    "spec_hash": spec_hash,
                    "worker": worker,
                    "expires": t + ttl,
                    "t": t,
                },
            )
            return True

        return self._with_leases(do)

    def release(self, cell_id, spec_hash, worker) -> None:
        def do(f):
            cur = self._lease_state.leases.get((cell_id, spec_hash))
            if cur is None or cur.worker != worker:
                return
            self._lease_append(
                f,
                {
                    "op": "release",
                    "cell_id": cell_id,
                    "spec_hash": spec_hash,
                    "worker": worker,
                    "t": time.time(),
                },
            )

        self._with_leases(do)

    def leases(self) -> dict[tuple[str, str], Lease]:
        if not self.lease_path.exists():
            return {}
        self._with_leases(lambda f: None)
        return dict(self._lease_state.leases)

    # -- worker liveness ---------------------------------------------
    def heartbeat(self, worker, info=None, now=None) -> None:
        t = _now(now)
        rec = {"op": "beat", "worker": worker, "t": t}
        if info:
            rec["info"] = info
        self._with_leases(lambda f: self._lease_append(f, rec))

    def workers(self) -> dict[str, dict]:
        if not self.lease_path.exists():
            return {}
        self._with_leases(lambda f: None)
        return {
            w: {"last_seen": rec["last_seen"], "info": dict(rec["info"])}
            for w, rec in self._lease_state.workers.items()
        }

    # -- observability ------------------------------------------------
    def stats(self) -> dict[str, int]:
        if self.lease_path.exists():
            self._with_leases(lambda f: None)
        return dict(self._lease_state.counters)


class SqliteResultStore(SweepStore):
    """Sqlite-backed store safe for concurrent writers on a shared
    filesystem (see module docstring for the crash/concurrency model).
    """

    _SCHEMA = """
    CREATE TABLE IF NOT EXISTS results (
        cell_id   TEXT NOT NULL,
        spec_hash TEXT NOT NULL,
        result    TEXT NOT NULL,
        PRIMARY KEY (cell_id, spec_hash)
    );
    CREATE TABLE IF NOT EXISTS leases (
        cell_id   TEXT NOT NULL,
        spec_hash TEXT NOT NULL,
        worker    TEXT NOT NULL,
        expires   REAL NOT NULL,
        PRIMARY KEY (cell_id, spec_hash)
    );
    CREATE TABLE IF NOT EXISTS workers (
        worker    TEXT PRIMARY KEY,
        last_seen REAL NOT NULL,
        info      TEXT NOT NULL DEFAULT '{}'
    );
    CREATE TABLE IF NOT EXISTS counters (
        name  TEXT PRIMARY KEY,
        value INTEGER NOT NULL DEFAULT 0
    );
    """

    def __init__(self, path: str | Path, busy_timeout: float = 30.0):
        self.path = Path(path)
        self.busy_timeout = busy_timeout

    def _connect(self) -> sqlite3.Connection:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        conn = sqlite3.connect(str(self.path), timeout=self.busy_timeout)
        conn.isolation_level = None  # explicit BEGIN/COMMIT below
        conn.execute("PRAGMA synchronous=FULL")
        conn.executescript(self._SCHEMA)
        return conn

    @staticmethod
    def _bump(conn: sqlite3.Connection, name: str, by: int = 1) -> None:
        conn.execute(
            "INSERT INTO counters (name, value) VALUES (?, ?) "
            "ON CONFLICT(name) DO UPDATE SET value = value + ?",
            (name, by, by),
        )

    @staticmethod
    def _beat(conn, worker: str, t: float, info: dict | None = None) -> None:
        row = conn.execute(
            "SELECT last_seen, info FROM workers WHERE worker = ?", (worker,)
        ).fetchone()
        merged = json.loads(row[1]) if row else {}
        if info:
            merged.update(info)
        last = max(t, row[0]) if row else t
        conn.execute(
            "INSERT OR REPLACE INTO workers (worker, last_seen, info) "
            "VALUES (?, ?, ?)",
            (worker, last, json.dumps(merged, sort_keys=True)),
        )

    # -- results ------------------------------------------------------
    def load(self) -> dict[tuple[str, str], dict]:
        if not self.path.exists():
            return {}
        with closing(self._connect()) as conn:
            rows = conn.execute(
                "SELECT cell_id, spec_hash, result FROM results"
            ).fetchall()
        return {(cid, h): json.loads(res) for cid, h, res in rows}

    def append(self, cell_id, spec_hash, result) -> bool:
        with closing(self._connect()) as conn:
            conn.execute("BEGIN IMMEDIATE")
            cur = conn.execute(
                "INSERT OR IGNORE INTO results (cell_id, spec_hash, result) "
                "VALUES (?, ?, ?)",
                (cell_id, spec_hash, json.dumps(result, sort_keys=True)),
            )
            stored = cur.rowcount == 1
            if not stored:
                self._bump(conn, "duplicates")
            conn.execute("COMMIT")
        return stored

    # -- leases -------------------------------------------------------
    def claim(self, cell_id, spec_hash, worker, ttl, now=None) -> bool:
        t = _now(now)
        with closing(self._connect()) as conn:
            conn.execute("BEGIN IMMEDIATE")
            row = conn.execute(
                "SELECT worker, expires FROM leases "
                "WHERE cell_id = ? AND spec_hash = ?",
                (cell_id, spec_hash),
            ).fetchone()
            reissue = False
            if row is not None and row[0] != worker:
                if row[1] > t:
                    conn.execute("COMMIT")
                    return False
                reissue = True
            conn.execute(
                "INSERT OR REPLACE INTO leases "
                "(cell_id, spec_hash, worker, expires) VALUES (?, ?, ?, ?)",
                (cell_id, spec_hash, worker, t + ttl),
            )
            self._bump(conn, "claims")
            if reissue:
                self._bump(conn, "reissues")
            self._beat(conn, worker, t)
            conn.execute("COMMIT")
        return True

    def renew(self, cell_id, spec_hash, worker, ttl, now=None) -> bool:
        t = _now(now)
        with closing(self._connect()) as conn:
            conn.execute("BEGIN IMMEDIATE")
            cur = conn.execute(
                "UPDATE leases SET expires = ? "
                "WHERE cell_id = ? AND spec_hash = ? AND worker = ?",
                (t + ttl, cell_id, spec_hash, worker),
            )
            renewed = cur.rowcount == 1
            if renewed:
                self._bump(conn, "renews")
                self._beat(conn, worker, t)
            conn.execute("COMMIT")
        return renewed

    def release(self, cell_id, spec_hash, worker) -> None:
        with closing(self._connect()) as conn:
            conn.execute("BEGIN IMMEDIATE")
            cur = conn.execute(
                "DELETE FROM leases "
                "WHERE cell_id = ? AND spec_hash = ? AND worker = ?",
                (cell_id, spec_hash, worker),
            )
            if cur.rowcount == 1:
                self._bump(conn, "releases")
            conn.execute("COMMIT")

    def leases(self) -> dict[tuple[str, str], Lease]:
        if not self.path.exists():
            return {}
        with closing(self._connect()) as conn:
            rows = conn.execute(
                "SELECT cell_id, spec_hash, worker, expires FROM leases"
            ).fetchall()
        return {(c, h): Lease(c, h, w, e) for c, h, w, e in rows}

    # -- worker liveness ---------------------------------------------
    def heartbeat(self, worker, info=None, now=None) -> None:
        t = _now(now)
        with closing(self._connect()) as conn:
            conn.execute("BEGIN IMMEDIATE")
            self._beat(conn, worker, t, info)
            conn.execute("COMMIT")

    def workers(self) -> dict[str, dict]:
        if not self.path.exists():
            return {}
        with closing(self._connect()) as conn:
            rows = conn.execute(
                "SELECT worker, last_seen, info FROM workers"
            ).fetchall()
        return {
            w: {"last_seen": t, "info": json.loads(info)} for w, t, info in rows
        }

    # -- observability ------------------------------------------------
    def stats(self) -> dict[str, int]:
        out = {k: 0 for k in COUNTERS}
        if not self.path.exists():
            return out
        with closing(self._connect()) as conn:
            rows = conn.execute("SELECT name, value FROM counters").fetchall()
        out.update(dict(rows))
        return out


#: Path suffixes routed to the sqlite backend by :func:`open_store`.
_SQLITE_SUFFIXES = (".sqlite", ".sqlite3", ".db")


def open_store(
    path: SweepStore | str | Path, backend: str | None = None
) -> SweepStore:
    """Coerce a path (or pass through an existing store) to a backend.

    ``backend`` forces ``"jsonl"`` or ``"sqlite"``; otherwise a
    ``sqlite:`` prefix or a ``.sqlite``/``.sqlite3``/``.db`` suffix
    selects sqlite and anything else gets the JSONL reference backend.
    """
    if isinstance(path, SweepStore):
        return path
    p = str(path)
    if backend is None:
        if p.startswith("sqlite:"):
            backend, p = "sqlite", p[len("sqlite:") :]
        elif Path(p).suffix.lower() in _SQLITE_SUFFIXES:
            backend = "sqlite"
        else:
            backend = "jsonl"
    if backend == "sqlite":
        return SqliteResultStore(p)
    if backend == "jsonl":
        return ResultStore(p)
    raise ValueError(f"unknown store backend {backend!r}")
