"""Scenario engine demo: a small estimator-error x discipline grid.

Builds an ad-hoc sweep (no preset needed) over the reduced-scale FB
trace: FIFO and FAIR as error-independent references, HFSP across three
size-estimation error levels (Fig. 6's alpha axis), plus the Discipline
API's SRPT / LAS / PSBS (resolved by name through the registry,
``repro.core.disciplines``) — then prints the sojourn comparison table
from the paper's evaluation — mean / median / p95 per cell — and the
per-class means that make the "size-based wins on every class" claim
visible.

The full discipline x error matrix (SRPT degrading under error while the
FSP family tolerates it) is the ``paper-estimation-error-disciplines``
preset:  ``python -m repro.scenarios run paper-estimation-error-disciplines --quick``.

Run:  PYTHONPATH=src python examples/scenario_sweep.py [--workers N]
"""

import argparse

from repro.scenarios import SweepSpec, paper_fb_base, run_sweep
from repro.scenarios.spec import parse_cell_id


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes (0 = inline)")
    args = ap.parse_args()

    base = paper_fb_base().quick()
    sweep = SweepSpec(
        name="error-x-scheduler",
        base=base,
        grids=(
            # Error-independent references.
            SweepSpec.grid(**{"scheduler.policy": ("fifo", "fair")}),
            # HFSP under increasing size-estimation error (Fig. 6 axis).
            SweepSpec.grid(**{"scheduler.error_alpha": (0.0, 0.5, 1.0)}),
            # The new registry disciplines at zero error (the full
            # discipline x error grid is the
            # paper-estimation-error-disciplines preset).
            SweepSpec.grid(**{"scheduler.policy": ("srpt", "las", "psbs")}),
        ),
    )
    print(f"sweep {sweep.name}: {len(sweep.expand())} cells "
          f"on the {base.workload.num_jobs}-job FB trace, "
          f"{base.cluster.num_machines} machines\n")
    results = run_sweep(sweep, workers=args.workers)

    def label(cid: str) -> str:
        kv = parse_cell_id(cid)
        if "scheduler.policy" in kv:
            return kv["scheduler.policy"].upper()
        return f"HFSP a={kv['scheduler.error_alpha']}"

    print(f"{'scenario':14s} {'mean_s':>8s} {'median_s':>9s} {'p95_s':>8s}   "
          f"per-class mean (small/medium/large)")
    for cid, rep in sorted(
        results.items(), key=lambda kv: -kv[1]["mean_sojourn_s"]
    ):
        s = rep["sojourn"]
        per = rep["per_class"]
        cls = "/".join(
            f"{per[c]['mean_s']:.0f}" if c in per else "-"
            for c in ("small", "medium", "large")
        )
        print(f"{label(cid):14s} {s['mean_s']:8.1f} {s['median_s']:9.1f} "
              f"{s['p95_s']:8.1f}   {cls}")

    hfsp_worst = max(
        rep["mean_sojourn_s"]
        for cid, rep in results.items() if "error_alpha" in cid
    )
    fair = results["scheduler.policy=fair"]["mean_sojourn_s"]
    print(f"\nHFSP at full estimation error ({hfsp_worst:.1f}s mean) still "
          f"beats FAIR ({fair:.1f}s): {hfsp_worst < fair} — the paper's "
          f"robustness claim (Sect. 4.3).")


if __name__ == "__main__":
    main()
