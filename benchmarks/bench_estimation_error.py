"""Fig. 6 — robustness to job-size estimation errors.

A "wrong" estimate is drawn uniformly in [s*(1-a), s*(1+a)] for
a in [0.1, 1.0]; the paper uses a MAP-only variant of the FB-dataset and
finds mean sojourn nearly flat in a (HFSP is robust), with FAIR as the
error-independent reference.

Thin wrapper over the ``paper-estimation-error`` scenario preset — the
alpha x error-seed grid plus the FAIR reference cell are declared there;
this module only averages the per-cell reports over error seeds."""

from __future__ import annotations

import numpy as np

from benchmarks.common import CsvOut
from repro.scenarios import get_preset, run_sweep
from repro.scenarios.spec import parse_cell_id


def main(out=None) -> dict:
    results = run_sweep(get_preset("paper-estimation-error"))

    # hfsp cells: "scheduler.error_alpha=<a>,scheduler.error_seed=<s>";
    # the FAIR reference cell: "scheduler.policy=fair".
    by_alpha: dict[float, list[float]] = {}
    fair = None
    for cid, rep in results.items():
        kv = parse_cell_id(cid)
        if kv.get("scheduler.policy") == "fair":
            fair = rep["mean_sojourn_s"]
        else:
            a = float(kv["scheduler.error_alpha"])
            by_alpha.setdefault(a, []).append(rep["mean_sojourn_s"])

    table = CsvOut("fig6_estimation_error", [
        "alpha", "mean_sojourn_s", "std_over_seeds",
    ])
    res = {}
    for a in sorted(by_alpha):
        ms = by_alpha[a]
        res[a] = float(np.mean(ms))
        table.add(a, round(float(np.mean(ms)), 1), round(float(np.std(ms)), 1))
    table.add("fair-ref", round(fair, 1), 0.0)
    table.emit(out)

    alphas = sorted(res)
    lo, hi = min(alphas), max(alphas)
    degradation = res[hi] / res[lo]
    print(f"# fig6: mean sojourn at alpha={lo:g}: {res[lo]:.0f}s, at "
          f"alpha={hi:g}: {res[hi]:.0f}s ({degradation:.2f}x) — "
          f"FAIR ref {fair:.0f}s; HFSP stays below FAIR for all alpha: "
          f"{all(res[a] < fair for a in alphas)}")
    return {"results": res, "fair": fair}


if __name__ == "__main__":
    main()
