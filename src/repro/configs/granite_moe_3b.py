"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite; hf]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                    # kept for reference; experts use expert_d_ff
    vocab_size=49155,
    vocab_pad=13,         # 49168 = 16*3073: vocab-shardable
    act="silu_glu",
    norm="rmsnorm",
    num_experts=40,
    top_k=8,
    expert_d_ff=512,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = reduced(CONFIG)
