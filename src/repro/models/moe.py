"""Mixture-of-Experts FFN (granite-moe: 40e top-8; llama4-scout: 16e top-1
with a shared expert).

Dispatch is capacity-based scatter/gather (GSPMD-friendly, linear memory):

* router -> top-k experts per token;
* position-in-expert via one-hot cumsum; tokens beyond capacity
  ``C = ceil(tokens * k / E * capacity_factor)`` are dropped (their gate
  contribution is zero — residual carries them, the standard Switch
  behaviour);
* dispatch to a dense ``(E, C, d)`` buffer via scatter-add, run every
  expert's FFN as a batched einsum (experts axis shardable over 'model' —
  expert parallelism), gather-combine weighted by the gates.

An auxiliary load-balancing loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import _act_fn, dense_init, init_mlp, apply_mlp


def _pin_groups(cfg: ModelConfig, x: jnp.ndarray, capacity_dim: int | None = None) -> jnp.ndarray:
    """Anchor the leading group dim to the DP mesh axes.  GSPMD does not
    reliably propagate shardings through the (b,s,d)->(G,g,d) reshape, and
    an unsharded dispatch buffer costs TB-scale all-gathers.  When
    ``cfg.moe_capacity_axis`` is set, the dispatch buffer's capacity dim is
    sharded too (see configs.base)."""
    if cfg.moe_group_axis is None:
        return x
    dims = [None] * (x.ndim - 1)
    if capacity_dim is not None and cfg.moe_capacity_axis is not None:
        dims[capacity_dim - 1] = cfg.moe_capacity_axis
    spec = P(cfg.moe_group_axis, *dims)
    return jax.lax.with_sharding_constraint(x, spec)


def init_moe(cfg: ModelConfig, key, *, layers: int | None = None) -> dict:
    d, e, dff = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    pref = () if layers is None else (layers,)
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, (*pref, d, e), d, cfg.param_dtype),
        "wi": dense_init(k1, (*pref, e, d, dff), d, cfg.param_dtype),
        "wo": dense_init(k2, (*pref, e, dff, d), dff, cfg.param_dtype),
    }
    if cfg.act.endswith("_glu"):
        p["wg"] = dense_init(k3, (*pref, e, d, dff), d, cfg.param_dtype)
    if cfg.shared_expert_d_ff:
        import dataclasses

        shared_cfg = dataclasses.replace(cfg, d_ff=cfg.shared_expert_d_ff)
        p["shared"] = init_mlp(shared_cfg, ks, cfg.shared_expert_d_ff, layers=layers)
    return p


def moe_ffn(
    cfg: ModelConfig, p: dict, x: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (out, aux_loss).

    Group-limited routing (GShard-style): tokens are reshaped to
    ``(G, g, d)`` with G = cfg.moe_groups aligned to the data-parallel
    shards, so the position-in-expert cumsum, the dispatch scatter and the
    combine gather are all LOCAL to a shard.  A single global dispatch
    would make GSPMD materialize an unsharded (E, C, d) buffer and TB-scale
    all-gathers (observed in the dry-run before this restructure)."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.num_experts, cfg.top_k
    g_count = cfg.moe_groups if n % max(cfg.moe_groups, 1) == 0 else 1
    g = n // g_count
    dtype = x.dtype
    xg = _pin_groups(cfg, x.reshape(g_count, g, d))

    router_logits = jnp.einsum(
        "Gnd,de->Gne", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)   # (G, g, e)
    gates, idx = jax.lax.top_k(probs, k)             # (G, g, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss: e * sum_e f_e * p_e.
    me = probs.mean(axis=(0, 1))                     # (e,) mean router prob
    sel = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (G, g, k, e)
    ce = sel.mean(axis=(0, 1, 2))                    # dispatch fraction
    aux = e * jnp.sum(me * ce)

    capacity = max(1, int(math.ceil(g * k / e * cfg.capacity_factor)))

    flat_e = idx.reshape(g_count, g * k)                       # (G, gk)
    flat_gate = gates.reshape(g_count, g * k).astype(dtype)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (G, gk, e)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1    # pos in expert
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, 0)

    # Token rows for the k choices are contiguous (token i -> rows
    # i*k..i*k+k-1), so dispatch input is a broadcast (no gather) and the
    # final combine is a reshape-sum (no scatter).
    contrib = jnp.broadcast_to(
        xg[:, :, None, :], (g_count, g, k, d)
    ).reshape(g_count, g * k, d)
    contrib = jnp.where(keep[..., None], contrib, 0).astype(dtype)

    # Dispatch: per-group scatter-add via vmap — the G batch dim stays a
    # sharded batch dim of the scatter (indexing G explicitly makes GSPMD
    # all-gather the buffer).
    def scatter_g(e_g, pos_g, c_g):
        return jnp.zeros((e, capacity, d), dtype=dtype).at[e_g, pos_g].add(c_g)

    buf = _pin_groups(cfg, jax.vmap(scatter_g)(flat_e, safe_pos, contrib),
                      capacity_dim=2)

    # Expert FFNs as batched einsums — hidden dim shardable over 'model',
    # G over 'data' (expert-parallel variant: shard e instead; §Perf).
    act = _act_fn(cfg.act)
    h = jnp.einsum("Gecd,edf->Gecf", buf, p["wi"].astype(dtype))
    h = act(h)
    if "wg" in p:
        h = h * jnp.einsum("Gecd,edf->Gecf", buf, p["wg"].astype(dtype))
    out_buf = _pin_groups(
        cfg, jnp.einsum("Gecf,efd->Gecd", h, p["wo"].astype(dtype)),
        capacity_dim=2,
    )

    # Combine: per-group gather of each kept choice, gate-weight, then
    # reshape-sum over the k contiguous rows per token.
    picked = jax.vmap(lambda ob, e_g, pos_g: ob[e_g, pos_g])(
        out_buf, flat_e, safe_pos
    )                                                           # (G, gk, d)
    picked = picked * (flat_gate * keep.astype(dtype))[..., None]
    out = _pin_groups(cfg, picked.reshape(g_count, g, k, d).sum(axis=2))

    if "shared" in p:
        out = out + apply_mlp(cfg, p["shared"], xg)
    return out.reshape(b, s, d), aux
