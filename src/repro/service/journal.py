"""Write-ahead journal: a live session recorded as a repro-trace.

Every external stimulus the master applies to its engine is appended
here *before* the effect is acknowledged (fsync per append — a crash
after the ack can always be replayed past).  The file is a valid
:mod:`repro.scenarios.trace` JSONL trace — header line, then job lines
in the exact ``_job_record`` schema — interleaved with event lines:

* ``{"event": "advance", "t": T}`` — the engine ran ``run(until=T)``
  and processed at least one event.  Advance barriers are part of the
  determinism contract: with ``event_epsilon > 0`` a barrier flushes
  the open coalescing window, so pass placement depends on where the
  barriers fell — the twin must replay the recorded barriers, not
  recompute them from a clock.
* ``{"event": "crash"|"recover", "t": T, "machine": M}`` — scripted
  machine fault (worker death / rejoin), mapped onto
  ``Simulator.inject_fault``.
* ``{"event": "eps", "t": T, "value": E}`` — the auto-epsilon
  controller retuned the coalescing window.

Job lines may carry two extra keys the trace loader ignores:
``"user"`` (admission accounting) and ``"tag"`` (client-supplied
idempotency token — the restore path rebuilds its dedup map from
these, which is what makes submit exactly-once across a master crash).

Because :func:`repro.scenarios.trace.load_trace` skips event lines, a
journal also doubles as a plain workload trace: the recorded arrivals
can be re-run offline as a scenario cell
(``WorkloadAxis(kind="trace", trace_path=<journal>)``).
"""

from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.types import JobSpec
from repro.scenarios.trace import TRACE_KIND, TRACE_VERSION, job_record

#: Event kinds a journal may contain, in the schema above.
EVENT_KINDS = ("advance", "crash", "recover", "eps")


def read_journal(path: str | Path) -> tuple[dict, list[dict]]:
    """Read a journal; returns ``(meta, entries)``.

    ``entries`` preserves file order and mixes job records with event
    records (distinguished by the ``"event"`` key).  A torn final line
    (partial write from a crash mid-append) is dropped — write-ahead
    ordering guarantees a torn line was never acknowledged.
    """
    path = Path(path)
    with path.open() as f:
        first = f.readline()
        if not first.strip():
            raise ValueError(f"{path}: empty journal")
        header = json.loads(first)
        if header.get("kind") != TRACE_KIND:
            raise ValueError(
                f"{path}: not a {TRACE_KIND} file (kind={header.get('kind')!r})"
            )
        if header.get("version") != TRACE_VERSION:
            raise ValueError(
                f"{path}: version {header.get('version')!r} != "
                f"supported {TRACE_VERSION}"
            )
        meta = header.get("meta", {})
        if not meta.get("journal"):
            raise ValueError(f"{path}: trace is not a service journal")
        entries = []
        for ln in f:
            if not ln.endswith("\n"):
                break  # torn tail: never acknowledged, never replayed
            ln = ln.strip()
            if not ln:
                continue
            try:
                d = json.loads(ln)
            except json.JSONDecodeError:
                break  # torn tail with a trailing newline from a later write
            ev = d.get("event")
            if ev is not None and ev not in EVENT_KINDS:
                raise ValueError(f"{path}: unknown journal event {ev!r}")
            entries.append(d)
    return meta, entries


class Journal:
    """Append-side of the journal (the read side is :func:`read_journal`).

    Opening a fresh path writes the header; opening an existing journal
    *repairs* it — the torn tail, if any, is truncated away so appends
    continue on a clean line boundary — and continues appending.
    """

    def __init__(self, path: str | Path, *, meta: dict | None = None):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        if self.path.exists() and self.path.stat().st_size > 0:
            self._repair()
            # Validate + remember the existing header's meta.
            self.meta, _ = read_journal(self.path)
            self._f = self.path.open("a")
        else:
            if meta is None:
                raise ValueError("new journal needs meta (policy/cluster/...)")
            self.meta = dict(meta)
            self.meta["journal"] = True
            self._f = self.path.open("w")
            self._append(
                {
                    "kind": TRACE_KIND,
                    "version": TRACE_VERSION,
                    "meta": self.meta,
                }
            )

    def _repair(self) -> None:
        """Truncate a torn final line left by a crash mid-append."""
        with self.path.open("r+b") as f:
            data = f.read()
            keep = len(data)
            nl = data.rfind(b"\n")
            if nl != len(data) - 1:
                keep = nl + 1  # drop the partial line (or everything if no \n)
            else:
                # Complete lines only — but the last one may still be
                # syntactically torn if the crash interleaved writes;
                # drop trailing lines until the remainder parses.
                lines = data.decode().splitlines(keepends=True)
                while lines:
                    try:
                        json.loads(lines[-1])
                        break
                    except json.JSONDecodeError:
                        keep -= len(lines.pop().encode())
            if keep != len(data):
                f.truncate(keep)

    def _append(self, obj: dict) -> None:
        self._f.write(json.dumps(obj, sort_keys=True) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    # -- write-ahead appends (each durable before the caller proceeds) --
    def append_job(
        self, spec: JobSpec, *, user: str | None = None, tag: str | None = None
    ) -> None:
        rec = job_record(spec)
        if user is not None:
            rec["user"] = user
        if tag is not None:
            rec["tag"] = tag
        self._append(rec)

    def append_event(self, event: dict) -> None:
        if event.get("event") not in EVENT_KINDS:
            raise ValueError(f"unknown journal event {event.get('event')!r}")
        self._append(event)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()
