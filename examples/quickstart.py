"""Quickstart: the paper in one file.

1. Reproduce the core claim: HFSP beats FAIR and FIFO on mean job sojourn
   time on an FB-like trace (discrete-event simulation, 100 machines).
2. Train a reduced assigned-architecture model for a few steps with the
   full substrate (data pipeline, AdamW, checkpointing).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.core import FairScheduler, FIFOScheduler, HFSPScheduler, Simulator
from repro.core.metrics import summarize
from repro.workload import fb_cluster, fb_dataset


def scheduling_demo() -> None:
    print("=== 1. HFSP vs FAIR vs FIFO (paper Sect. 4.2) " + "=" * 20)
    cluster = fb_cluster(num_machines=100)
    for name, mk in (
        ("FIFO", FIFOScheduler),
        ("FAIR", FairScheduler),
        ("HFSP", HFSPScheduler),
    ):
        jobs, class_of = fb_dataset(seed=0, num_jobs=100)
        res = Simulator(cluster, mk(cluster), jobs).run()
        summ = summarize(res, class_of)
        per_cls = "  ".join(
            f"{c}:{s.mean:6.0f}s" for c, s in summ.items() if c != "all"
        )
        print(f"  {name}: mean sojourn {res.mean_sojourn():6.1f}s   {per_cls}")
    print("  -> size-based scheduling wins on every class.\n")


def training_demo() -> None:
    print("=== 2. Train a reduced olmo-1b for 10 steps " + "=" * 22)
    from repro.configs import get_smoke
    from repro.checkpoint import CheckpointStore
    from repro.data import DataConfig, SyntheticLM
    from repro.train import (
        OptimizerConfig, TrainConfig, init_train_state, make_train_step,
    )

    cfg = get_smoke("olmo_1b")
    step = jax.jit(make_train_step(
        cfg, OptimizerConfig(lr=1e-3, warmup_steps=3, total_steps=100),
        TrainConfig(),
    ))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8))
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        for i in range(10):
            import jax.numpy as jnp

            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, m = step(state, batch)
            if i % 3 == 0:
                store.save_async("quickstart", i, state)
                print(f"  step {i}: loss {float(m['loss']):.3f} "
                      f"lr {float(m['lr']):.2e}")
        store.wait()
        restored_step, _ = store.restore("quickstart")
        print(f"  restored checkpoint from step {restored_step}\n")


if __name__ == "__main__":
    scheduling_demo()
    training_demo()
    print("quickstart done")
