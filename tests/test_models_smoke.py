"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward/train step and one decode step on CPU; output shapes + no NaNs.
(The FULL configs are exercised only via the dry-run.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke, SHAPES, shape_applicable
from repro.models import decode_step, forward, init_cache, init_model, loss_fn
from repro.train import OptimizerConfig, TrainConfig, init_train_state, make_train_step


def _batch(cfg, b=2, s=16, key=0):
    kt, kl = jax.random.split(jax.random.PRNGKey(key))
    batch = {
        "tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones(
            (b, cfg.num_patches, cfg.d_model), cfg.activation_dtype()
        )
    if cfg.family == "encdec":
        batch["frame_embeds"] = jnp.ones(
            (b, cfg.num_frames, cfg.d_model), cfg.activation_dtype()
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke(arch)
        params = init_model(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
        s_total = 16 + (cfg.num_patches if cfg.family == "vlm" else 0)
        assert logits.shape == (2, s_total, cfg.padded_vocab)
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
        assert np.isfinite(float(aux))

    def test_train_step_decreases_loss(self, arch):
        cfg = get_smoke(arch)
        step = jax.jit(
            make_train_step(
                cfg, OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                TrainConfig(),
            )
        )
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg, key=0)  # fixed batch: memorization must work
        losses = []
        for i in range(8):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
            assert np.isfinite(losses[-1])
        assert losses[-1] < losses[0]

    def test_decode_step(self, arch):
        cfg = get_smoke(arch)
        params = init_model(cfg, jax.random.PRNGKey(0))
        b, max_seq = 2, 8
        cache = init_cache(cfg, b, max_seq)
        step = jax.jit(lambda p, t, pos, c: decode_step(cfg, p, t, pos, c))
        toks = jnp.ones((b, 1), jnp.int32)
        for t in range(3):
            pos = jnp.full((b,), t, jnp.int32)
            logits, cache = step(params, toks, pos, cache)
            assert logits.shape == (b, 1, cfg.padded_vocab)
            assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
            toks = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)

    def test_decode_matches_forward(self, arch):
        """Token-by-token decode logits == teacher-forced forward logits
        (cache correctness), for decoder-only archs."""
        cfg = get_smoke(arch)
        if cfg.family in ("encdec", "vlm"):
            pytest.skip("prefill path differs (context stubs)")
        if cfg.family == "moe":
            pytest.skip(
                "capacity dropping differs between batched prefill and "
                "single-token decode (expected Switch-style semantics)"
            )
        params = init_model(cfg, jax.random.PRNGKey(0))
        b, s = 1, 6
        toks = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab_size)
        full_logits, _ = forward(cfg, params, {"tokens": toks})
        cache = init_cache(cfg, b, s)
        outs = []
        for t in range(s):
            pos = jnp.full((b,), t, jnp.int32)
            lg, cache = decode_step(cfg, params, toks[:, t : t + 1], pos, cache)
            outs.append(lg[:, 0])
        dec_logits = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(full_logits, np.float32),
            atol=0.25, rtol=0.05,  # bf16 activations; fp32 state paths differ
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_metadata(arch):
    """The FULL configs expose the exact assigned hyper-parameters and
    sensible param counts (no allocation here)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 1e7, (arch, n)
    shapes_run = [
        s for s in SHAPES.values() if shape_applicable(cfg, s)[0]
    ]
    expected = 4 if cfg.supports_long_context else 3
    assert len(shapes_run) == expected
