"""Live scheduler service: async master-worker runtime with the
discrete-event :class:`~repro.core.simulator.Simulator` as its
deterministic replay twin.

The package turns the offline reproduction into a long-running service
without forking the scheduling logic:

* :mod:`repro.service.engine` — ``LiveEngine`` drives one ``Simulator``
  against wall-clock time (``virtual_now = v0 + (wall - w0) *
  time_scale``) and write-ahead journals every external stimulus;
* :mod:`repro.service.journal` — the journal file *is* a repro-trace
  (jobs in the exact :mod:`repro.scenarios.trace` schema, interleaved
  with ``{"event": ...}`` lines for advance barriers, scripted faults
  and epsilon retunes), so a recorded session replays bit-identically
  through the Simulator — the twin property every test asserts;
* :mod:`repro.service.master` — asyncio master: line-JSON protocol,
  admission control, worker heartbeats/death/rejoin, checkpointing;
* :mod:`repro.service.worker` — in-process worker agents plus the
  ``python -m repro.service worker`` subprocess runner;
* :mod:`repro.service.admission` — per-user queues, token-bucket rate
  limits, max-live-jobs backpressure;
* :mod:`repro.service.telemetry` — live counters in the
  ``scenario_report`` vocabulary (sojourn/slowdown tails, Jain index,
  goodput, decision latency).

See docs/service.md for the architecture and the determinism contract.
"""

from repro.service.admission import AdmissionConfig, AdmissionControl
from repro.service.engine import LiveEngine, live_fingerprint, replay_journal
from repro.service.journal import Journal, read_journal
from repro.service.master import Master, MasterConfig
from repro.service.telemetry import Telemetry
from repro.service.worker import WorkerAgent

__all__ = [
    "AdmissionConfig",
    "AdmissionControl",
    "Journal",
    "LiveEngine",
    "Master",
    "MasterConfig",
    "Telemetry",
    "WorkerAgent",
    "live_fingerprint",
    "read_journal",
    "replay_journal",
]
