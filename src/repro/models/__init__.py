from repro.models.api import (
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
)

__all__ = ["decode_step", "forward", "init_cache", "init_model", "loss_fn"]
