"""Blockwise (flash) attention Pallas TPU kernel.

Online-softmax attention with explicit BlockSpec VMEM tiling:

* grid = (batch, q_heads, num_q_blocks, num_kv_blocks), kv innermost — the
  TPU executes the grid sequentially, so the (m, l, acc) running statistics
  live in VMEM scratch and carry across kv blocks;
* q/k/v tiles are (block_q x head_dim) / (block_kv x head_dim) — 128-aligned
  on both matmul dims so the MXU is fed full tiles;
* GQA is handled in the k/v index maps (kv_head = q_head // group);
* causal and sliding-window masks are applied in-kernel; fully-masked kv
  blocks are skipped with ``pl.when`` (halves the causal FLOPs and, on real
  hardware, the HBM->VMEM traffic).

VMEM budget per grid step (defaults block_q=block_kv=512, hd=128, bf16):
q 128 KiB + k 128 KiB + v 128 KiB + acc(f32) 256 KiB + m/l ~4 KiB < 1 MiB,
comfortably inside the ~16 MiB/core VMEM with double buffering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None,
    block_q: int, block_kv: int, num_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)

    # Block-level skip: causal blocks entirely above the diagonal, or
    # entirely outside the sliding window, contribute nothing.
    run = jnp.bool_(True)
    if causal:
        # oldest k in block must not exceed the newest q in block
        run = jnp.logical_and(run, ki * block_kv <= qi * block_q + block_q - 1)
    if window is not None:
        # Fully outside only when even the CLOSEST pair (oldest q, newest k)
        # is at distance >= window.
        run = jnp.logical_and(
            run,
            (qi * block_q) - (ki * block_kv + block_kv - 1) < window,
        )

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (bkv, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bkv)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_scr[...] = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "block_q", "block_kv", "interpret",
    ),
)
def flash_attention_bhsd(
    q: jnp.ndarray,   # (b, h, sq, hd)
    k: jnp.ndarray,   # (b, kvh, skv, hd)
    v: jnp.ndarray,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    _, kvh, skv, _ = k.shape
    groups = h // kvh
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    nq = q.shape[2] // block_q
    nkv = k.shape[2] // block_kv
    # Padded kv columns must never win the max: rely on causal mask (padded
    # positions sit beyond every real q position) or explicit window; for
    # non-causal full attention pad_kv must be 0.
    assert causal or pad_kv == 0, "non-causal padding unsupported"

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, num_kv_blocks=nkv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, hd),
                lambda b_, h_, qi, ki, g=groups: (b_, h_ // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_kv, hd),
                lambda b_, h_, qi, ki, g=groups: (b_, h_ // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, q.shape[2], hd), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),
            _vmem((block_q, 1), jnp.float32),
            _vmem((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :sq]
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
