"""Discrete-event cluster simulator — the paper's Mumak analogue (Sect. 4.1).

Executes any :class:`~repro.core.scheduler.Scheduler` against a simulated
cluster with per-machine MAP/REDUCE slots, data locality, preemption
primitives (SUSPEND / RESUME / KILL) and an optional DMA cost model for the
TPU adaptation (suspend state must cross HBM<->host DRAM; in the paper the
analogous cost is OS swap I/O, which Sect. 5 argues is bounded).

Semantics:

* a RUNNING task progresses at unit rate; progress is frozen on SUSPEND;
* RESUME charges ``ClusterSpec.suspend_cost(state_bytes)`` by *rolling back*
  progress (the swapped-in context must be re-materialized before useful
  work continues — the paper's "Resume operation may introduce further
  delays");
* KILL discards all progress and re-queues the task (Sect. 3.3);
* REDUCE sample tasks report progress to the scheduler after ``delta``
  seconds of execution (supports the sigma = Delta/p estimator, Sect. 3.2.1);
* the scheduler is consulted on every event and on a periodic heartbeat.

The simulator is deterministic given the job list.

Epsilon-window event coalescing
-------------------------------
By default (``event_epsilon=0``) a scheduling pass runs after every event,
with only exact-timestamp ARRIVAL/COMPLETE batches sharing one pass.  With
``event_epsilon=eps > 0`` the loop instead pops *every* heap event within
``eps`` of the window head (the first event after the previous pass),
applies each event's state mutation at its own timestamp, and runs ONE
scheduling pass at the window-end timestamp — the event-batching design of
"A Simulator for Data-Intensive Job Scheduling" (arXiv 1306.6023), which
cuts pass counts by an order of magnitude on bursty traces.

Determinism contract (see docs/scheduler_internals.md):

* events inside a window apply in stable ``(time, kind, seq)`` heap order
  — the same total order the eps=0 loop uses, so a window is just the
  eps=0 mutation sequence with intermediate passes elided;
* each mutation sees ``now`` = its own event time (completion times,
  progress fractions, and virtual-cluster aging are unchanged); only the
  *pass* moves, to the window's last event time;
* eps=0 is bit-identical to the legacy loop (enforced by the conformance
  suite), and any eps is reproducible across runs and processes — the
  window boundaries are a pure function of the event stream and the
  ``run(until=...)`` barriers;
* ``until`` is a simulation-time barrier: a window never spans it — the
  pending pass is flushed before ``run`` returns, so callers always
  observe fully-scheduled state at ``until`` (decisions due by the
  barrier are not deferred past it).  ``run(until=T)`` + ``run()`` may
  therefore place passes differently than one unsliced ``run()`` — by
  design, like any other choice of barrier.  ``max_events`` slicing, by
  contrast, is placement-neutral: an open window persists across the
  budget exception and resumes identically.
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field

from repro.core.scheduler import Action, Kill, Resume, Scheduler, Start, Suspend
from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    SlotKey,
    TaskAttempt,
    TaskState,
)

_ARRIVAL, _COMPLETE, _PROGRESS, _TICK = 0, 1, 2, 3


@dataclass
class SimConfig:
    """Executor knobs, bundled so scenario specs and benchmarks can pass
    one object (`Simulator(..., config=SimConfig(...))`)."""

    heartbeat: float = 3.0
    track_timeline: bool = False
    #: Delta after which a running REDUCE sample task reports progress;
    #: None defers to the scheduler's TrainingModule delta.
    progress_delta: float | None = None
    #: Epsilon-window event coalescing (seconds): 0 = a pass per event
    #: (legacy, bit-identical); eps > 0 = one pass per event window (see
    #: module docstring for the determinism contract).
    event_epsilon: float = 0.0


class EventLimitReached(RuntimeError):
    """run(max_events=N) processed N events without draining the heap.

    Subclasses RuntimeError for backward compatibility with callers that
    use max_events as a livelock guard; callers that use it as a
    deliberate slicing budget (the scheduler-overhead benchmarks) catch
    this type specifically so a genuine error can't masquerade as an
    exhausted budget."""


@dataclass
class SimResult:
    """Everything the benchmarks need."""

    arrival: dict[int, float] = field(default_factory=dict)
    completion: dict[int, float] = field(default_factory=dict)
    first_dispatch: dict[int, float] = field(default_factory=dict)
    locality_hits: int = 0
    locality_misses: int = 0
    stats: object | None = None
    # (time, job_id, phase, running-slot-count) samples for Fig. 7 graphs.
    timeline: list[tuple[float, int, str, int]] = field(default_factory=list)
    makespan: float = 0.0
    # Scheduler passes run / events processed — the epsilon-window
    # sojourn-vs-overhead tradeoff reads per pass counts per cell.
    passes: int = 0
    events: int = 0

    @property
    def sojourn(self) -> dict[int, float]:
        return {
            j: self.completion[j] - self.arrival[j]
            for j in self.completion
            if j in self.arrival
        }

    def mean_sojourn(self) -> float:
        s = self.sojourn
        return sum(s.values()) / len(s) if s else 0.0

    @property
    def locality_fraction(self) -> float:
        tot = self.locality_hits + self.locality_misses
        return self.locality_hits / tot if tot else 1.0


class Simulator:
    """ClusterView implementation + event loop."""

    def __init__(
        self,
        cluster: ClusterSpec,
        scheduler: Scheduler,
        jobs: list[JobSpec],
        heartbeat: float | None = None,
        track_timeline: bool | None = None,
        progress_delta: float | None = None,
        event_epsilon: float | None = None,
        config: SimConfig | None = None,
    ):
        # The knob kwargs default to None sentinels and resolve through
        # SimConfig, so the defaults live in exactly one place.  A config
        # bundle replaces the individual knobs — mixing both would
        # silently drop one side, so explicit kwargs alongside a config
        # are rejected.  (progress_delta=None is itself the "defer to the
        # scheduler's TrainingModule delta" value, so passing it
        # explicitly is indistinguishable from omitting it — harmless.)
        explicit = {
            name: val
            for name, val in (
                ("heartbeat", heartbeat),
                ("track_timeline", track_timeline),
                ("progress_delta", progress_delta),
                ("event_epsilon", event_epsilon),
            )
            if val is not None
        }
        if config is not None:
            if explicit:
                raise ValueError(
                    "pass executor knobs either via config=SimConfig(...) "
                    f"or as keyword arguments, not both: {sorted(explicit)}"
                )
        else:
            config = SimConfig(**explicit)
        self.spec = cluster
        self.scheduler = scheduler
        self.heartbeat = config.heartbeat
        self.track_timeline = config.track_timeline
        progress_delta = config.progress_delta
        event_epsilon = config.event_epsilon
        if event_epsilon < 0:
            raise ValueError(f"event_epsilon must be >= 0, got {event_epsilon}")
        self.event_epsilon = float(event_epsilon)
        # End timestamp of the open coalescing window (None = no window
        # open); persists across incremental run() calls so a window split
        # by an event-budget slice closes identically.
        self._window_end: float | None = None
        # Delta after which a running REDUCE sample task reports progress;
        # defaults to the scheduler's TrainingModule delta if present.
        if progress_delta is None:
            progress_delta = getattr(
                getattr(scheduler, "training", None), "delta", 60.0
            )
        self.progress_delta = progress_delta

        self._jobs = sorted(jobs, key=lambda j: (j.arrival_time, j.job_id))
        self._heap: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self._now = 0.0
        # Physical slot state.  Free slots are insertion-ordered dicts:
        # same iteration/removal order as a list, but O(1) release/claim
        # (the scheduler pass consults free_slots on every event).
        self._free: dict[Phase, dict[SlotKey, None]] = {
            Phase.MAP: {}, Phase.REDUCE: {},
        }
        for m in range(cluster.num_machines):
            for i in range(cluster.map_slots_per_machine):
                self._free[Phase.MAP][SlotKey(m, Phase.MAP, i)] = None
            for i in range(cluster.reduce_slots_per_machine):
                self._free[Phase.REDUCE][SlotKey(m, Phase.REDUCE, i)] = None
        self._occupied: dict[SlotKey, TaskAttempt] = {}
        self._occupied_by_phase: dict[Phase, dict[SlotKey, TaskAttempt]] = {
            Phase.MAP: {}, Phase.REDUCE: {},
        }
        self._slot_by_task: dict[tuple, SlotKey] = {}
        # Epochs invalidate stale COMPLETE/PROGRESS events after preemption.
        self._epoch: dict[tuple, int] = {}
        self._susp_bytes: dict[int, int] = {}
        self._susp_count: dict[int, int] = {}
        self._susp_total = 0
        self._tick_pending = False
        self.result = SimResult()
        # Total events processed / scheduling passes run across all
        # (possibly incremental) run() calls — consumed by the
        # scheduler-overhead benchmarks and the epsilon-sweep reports.
        self.events_processed = 0
        self.passes = 0

    # ------------------------------------------------------------------
    # ClusterView protocol
    # ------------------------------------------------------------------
    def free_slots(self, phase: Phase) -> list[SlotKey]:
        return list(self._free[phase])

    def slot_occupant(self, slot: SlotKey) -> TaskAttempt | None:
        return self._occupied.get(slot)

    def occupied_slots(self, phase: Phase) -> dict[SlotKey, TaskAttempt]:
        # Returned dict is live state — schedulers must treat it read-only.
        return self._occupied_by_phase[phase]

    def machine_suspended_count(self, machine: int) -> int:
        return self._susp_count.get(machine, 0)

    def machine_suspended_bytes(self, machine: int) -> int:
        return self._susp_bytes.get(machine, 0)

    def total_suspended_bytes(self) -> int:
        return self._susp_total

    # ------------------------------------------------------------------
    # Event helpers
    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, payload: object) -> None:
        heapq.heappush(self._heap, (t, kind, next(self._seq), payload))

    def _bump(self, key: tuple) -> int:
        self._epoch[key] = self._epoch.get(key, 0) + 1
        return self._epoch[key]

    def _job_state(self, job_id: int) -> JobState:
        return self.scheduler.jobs[job_id]

    # ------------------------------------------------------------------
    # Action application
    # ------------------------------------------------------------------
    def _apply(self, action: Action) -> None:
        now = self._now
        if isinstance(action, Start):
            att, slot = action.attempt, action.slot
            assert att.state is TaskState.PENDING, (att.spec.key, att.state)
            assert slot in self._free[slot.phase], slot
            del self._free[slot.phase][slot]
            js = self._job_state(att.spec.job_id)
            js.transition(att, TaskState.RUNNING)
            att.machine = slot.machine
            att.started_at = now
            att.attempts += 1
            self._occupied[slot] = att
            self._occupied_by_phase[slot.phase][slot] = att
            self._slot_by_task[att.spec.key] = slot
            if js.first_dispatch_time is None:
                js.first_dispatch_time = now
                self.result.first_dispatch[att.spec.job_id] = now
            ep = self._bump(att.spec.key)
            self._push(now + att.remaining, _COMPLETE, (att, ep))
            if (
                att.spec.phase is Phase.REDUCE
                and att.remaining > self.progress_delta
            ):
                self._push(now + self.progress_delta, _PROGRESS, (att, ep))
            self.scheduler.on_task_started(att, slot)
        elif isinstance(action, Resume):
            att, slot = action.attempt, action.slot
            assert att.state is TaskState.SUSPENDED, (att.spec.key, att.state)
            assert att.machine == slot.machine, "resume must be local (Sect 3.3)"
            assert slot in self._free[slot.phase], slot
            del self._free[slot.phase][slot]
            # Swap-in cost: roll back progress by the DMA latency.
            cost = self.spec.suspend_cost(att.spec.state_bytes)
            att.progress = max(0.0, att.progress - cost)
            self._job_state(att.spec.job_id).transition(att, TaskState.RUNNING)
            att.started_at = now
            att.attempts += 1
            self._occupied[slot] = att
            self._occupied_by_phase[slot.phase][slot] = att
            self._slot_by_task[att.spec.key] = slot
            self._susp_bytes[slot.machine] = self._susp_bytes.get(
                slot.machine, 0
            ) - att.spec.state_bytes
            self._susp_count[slot.machine] = (
                self._susp_count.get(slot.machine, 0) - 1
            )
            self._susp_total -= att.spec.state_bytes
            ep = self._bump(att.spec.key)
            self._push(now + att.remaining, _COMPLETE, (att, ep))
            self.scheduler.on_task_resumed(att, slot)
        elif isinstance(action, Suspend):
            att = action.attempt
            assert att.state is TaskState.RUNNING, (att.spec.key, att.state)
            slot = self._slot_by_task.pop(att.spec.key)
            del self._occupied[slot]
            del self._occupied_by_phase[slot.phase][slot]
            self._free[slot.phase][slot] = None
            att.progress = min(
                att.spec.duration, att.progress + (now - att.started_at)
            )
            self._job_state(att.spec.job_id).transition(att, TaskState.SUSPENDED)
            att.suspended_at = now
            self._bump(att.spec.key)
            m = att.machine if att.machine is not None else -1
            self._susp_bytes[m] = self._susp_bytes.get(m, 0) + att.spec.state_bytes
            self._susp_count[m] = self._susp_count.get(m, 0) + 1
            self._susp_total += att.spec.state_bytes
            self.scheduler.on_task_suspended(att)
        elif isinstance(action, Kill):
            att = action.attempt
            assert att.state is TaskState.RUNNING, (att.spec.key, att.state)
            slot = self._slot_by_task.pop(att.spec.key)
            del self._occupied[slot]
            del self._occupied_by_phase[slot.phase][slot]
            self._free[slot.phase][slot] = None
            att.progress = 0.0
            self._job_state(att.spec.job_id).transition(att, TaskState.PENDING)
            att.machine = None
            att.started_at = None
            self._bump(att.spec.key)
            self.scheduler.on_task_killed(att)
        else:  # pragma: no cover - defensive
            raise TypeError(f"unknown action {action!r}")

    # ------------------------------------------------------------------
    # Event processing
    # ------------------------------------------------------------------
    def _on_arrival(self, spec: JobSpec) -> None:
        self.result.arrival[spec.job_id] = self._now
        self.scheduler.on_job_arrival(spec, self._now)
        # Jobs with no tasks at all complete immediately.
        js = self._job_state(spec.job_id)
        if js.is_done():
            self._complete_job(js)

    def _on_complete(self, att: TaskAttempt, epoch: int) -> None:
        if self._epoch.get(att.spec.key) != epoch:
            return  # stale (task was suspended/killed since)
        if att.state is not TaskState.RUNNING:
            return
        slot = self._slot_by_task.pop(att.spec.key)
        del self._occupied[slot]
        del self._occupied_by_phase[slot.phase][slot]
        self._free[slot.phase][slot] = None
        att.progress = att.spec.duration
        self._job_state(att.spec.job_id).transition(att, TaskState.DONE)
        self._bump(att.spec.key)
        self.scheduler.on_task_complete(att.spec.job_id, att.spec.key, self._now)
        js = self._job_state(att.spec.job_id)
        if js.is_done() and js.completion_time is None:
            self._complete_job(js)

    def _on_progress(self, att: TaskAttempt, epoch: int) -> None:
        if self._epoch.get(att.spec.key) != epoch:
            return
        if att.state is not TaskState.RUNNING:
            return
        elapsed = self._now - att.started_at
        # Fraction of this task's input processed so far (unit rate).
        worked = att.progress + elapsed
        fraction = min(1.0, worked / att.spec.duration)
        self.scheduler.on_task_progress(
            att.spec.job_id, att.spec.key, fraction, elapsed, self._now
        )

    def _complete_job(self, js: JobState) -> None:
        js.completion_time = self._now
        self.result.completion[js.spec.job_id] = self._now
        self.result.locality_hits += js.locality_hits
        self.result.locality_misses += js.locality_misses
        self.scheduler.on_job_complete(js.spec.job_id, self._now)

    def _live_jobs_exist(self) -> bool:
        return bool(self.scheduler._live)

    def _sample_timeline(self) -> None:
        if not self.track_timeline:
            return
        counts: dict[tuple[int, Phase], int] = {}
        for att in self._occupied.values():
            k = (att.spec.job_id, att.spec.phase)
            counts[k] = counts.get(k, 0) + 1
        for (jid, phase), n in sorted(counts.items()):
            self.result.timeline.append((self._now, jid, phase.value, n))

    def _run_pass(self) -> None:
        """Close any open coalescing window, run one scheduling pass at
        the current time, apply its actions, and keep the heartbeat
        armed."""
        self._window_end = None
        self.passes += 1
        for action in self.scheduler.schedule(self, self._now):
            self._apply(action)
        self._sample_timeline()
        if self._live_jobs_exist() and not self._tick_pending:
            self._push(self._now + self.heartbeat, _TICK, None)
            self._tick_pending = True

    # ------------------------------------------------------------------
    def run(self, until: float = math.inf, max_events: int | None = None) -> SimResult:
        """Run (or incrementally continue) the simulation up to ``until``."""
        if not getattr(self, "_arrivals_seeded", False):
            self._arrivals_seeded = True
            for spec in self._jobs:
                self._push(spec.arrival_time, _ARRIVAL, spec)
        n_events = 0
        eps = self.event_epsilon
        while self._heap:
            # Barrier check first: it processes no event, so it neither
            # consumes the max_events budget nor may the budget preempt
            # the flush — callers always observe fully-scheduled state
            # at `until`.
            if self._heap[0][0] > until:
                if self._window_end is not None:
                    # A prior slice left a window open and this run's
                    # barrier is before the window's next event: flush
                    # the deferred pass, exactly where an unsliced
                    # run(until) would have placed it.
                    self._run_pass()
                break
            n_events += 1
            if max_events is not None and n_events > max_events:
                raise EventLimitReached(
                    f"simulator exceeded {max_events} events at t={self._now}"
                    " — scheduler livelock?"
                )
            t, kind, _, payload = heapq.heappop(self._heap)
            self.events_processed += 1
            if eps > 0.0 and self._window_end is None:
                # New coalescing window, anchored at its head event.
                self._window_end = t + eps
            self._now = max(self._now, t)
            # State mutations apply at their own event time, in stable
            # (time, kind, seq) heap order — identical to the eps=0 loop.
            if kind == _ARRIVAL:
                self._on_arrival(payload)
            elif kind == _COMPLETE:
                self._on_complete(*payload)
            elif kind == _PROGRESS:
                self._on_progress(*payload)
            elif kind == _TICK:
                self._tick_pending = False
                self.scheduler.on_tick(self._now)
            # Coalesce before scheduling a pass: with eps > 0, any event
            # inside the open window; with eps = 0 (legacy), only
            # same-timestamp ARRIVAL/COMPLETE batches.
            if self._heap and self._heap[0][0] <= until:
                if eps > 0.0:
                    if self._heap[0][0] <= self._window_end:
                        continue
                elif self._heap[0][0] <= self._now and (
                    self._heap[0][1] in (_ARRIVAL, _COMPLETE)
                ):
                    continue
            self._run_pass()
        self.result.stats = self.scheduler.stats
        self.result.makespan = self._now
        self.result.passes = self.passes
        self.result.events = self.events_processed
        return self.result
