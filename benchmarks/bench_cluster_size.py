"""Fig. 5 — mean sojourn vs cluster size (10..100 machines), FAIR vs HFSP.

Paper claim: when resources are scarce, HFSP's advantage grows — the same
workload needs a smaller cluster for equal sojourn times.

Thin wrapper over the ``paper-cluster-size`` scenario preset."""

from __future__ import annotations

from benchmarks.common import CsvOut
from repro.scenarios import get_preset, run_sweep
from repro.scenarios.spec import parse_cell_id


def main(out=None) -> dict:
    results = run_sweep(get_preset("paper-cluster-size"))

    # cell_id = "cluster.num_machines=<m>,scheduler.policy=<name>"
    by_cell: dict[tuple[int, str], dict] = {}
    for cid, rep in results.items():
        kv = parse_cell_id(cid)
        by_cell[(int(kv["cluster.num_machines"]), kv["scheduler.policy"])] = rep

    sizes = sorted({m for m, _ in by_cell})
    table = CsvOut("fig5_cluster_size", [
        "machines", "scheduler", "mean_sojourn_s", "makespan_s",
    ])
    gains = {}
    for m in sizes:
        means = {}
        for name in ("fair", "hfsp"):
            rep = by_cell[(m, name)]
            means[name] = rep["mean_sojourn_s"]
            table.add(m, name, round(means[name], 1), round(rep["makespan_s"], 1))
        gains[m] = means["fair"] / means["hfsp"]
    table.emit(out)
    print("# fig5: FAIR/HFSP mean-sojourn ratio by cluster size: "
          + " ".join(f"{m}m={gains[m]:.2f}x" for m in sizes))
    assert gains[min(sizes)] >= gains[max(sizes)] * 0.8, (
        "HFSP advantage should not shrink drastically as resources shrink"
    )
    return {"gains": gains}


if __name__ == "__main__":
    main()
