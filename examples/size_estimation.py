"""The Training module in action (paper Sect. 3.2).

Shows online size estimation converging: initial xi-weighted guesses from
recent-task statistics, provisional refits on every sample observation,
Delta-based early estimates for long REDUCE tasks, and the Fig. 6
robustness experiment in miniature.

Run:  PYTHONPATH=src python examples/size_estimation.py
"""

from repro.core import (
    ClusterSpec,
    HFSPConfig,
    HFSPScheduler,
    JobSpec,
    Phase,
    Simulator,
    TaskSpec,
)


def estimation_trace() -> None:
    print("=== estimate convergence " + "=" * 40)
    cluster = ClusterSpec(num_machines=4, map_slots_per_machine=2,
                          reduce_slots_per_machine=2)
    job = JobSpec(
        job_id=0, arrival_time=0.0,
        map_tasks=tuple(TaskSpec(0, Phase.MAP, i, 12.0) for i in range(20)),
        reduce_tasks=tuple(TaskSpec(0, Phase.REDUCE, i, 90.0) for i in range(4)),
    )
    sch = HFSPScheduler(cluster, HFSPConfig(delta=30.0))
    sim = Simulator(cluster, sch, [job])

    # Sample the estimate as the simulation advances.
    checkpoints = [1.0, 13.0, 40.0, 80.0, 200.0]
    for t in checkpoints:
        sim.run(until=t)
        js = sch.jobs.get(0)
        if js is None:
            continue
        est_m = js.est_size.get(Phase.MAP)
        est_r = js.est_size.get(Phase.REDUCE)
        print(f"  t={t:6.1f}s  MAP est {est_m and round(est_m):>6} "
              f"(true 240)   REDUCE est {est_r and round(est_r)} (true 360)")
    sim.run()
    print(f"  job completed at t={sim.result.completion[0]:.1f}s\n")


def robustness_mini() -> None:
    print("=== Fig. 6 in miniature: error injection " + "=" * 24)
    from repro.workload import fb_cluster, fb_dataset
    import dataclasses

    for alpha in (0.0, 0.5, 1.0):
        cluster = fb_cluster(num_machines=50)
        jobs, _ = fb_dataset(seed=3, num_jobs=50)
        jobs = [dataclasses.replace(j, reduce_tasks=()) for j in jobs]
        sch = HFSPScheduler(cluster, HFSPConfig(error_alpha=alpha))
        res = Simulator(cluster, sch, jobs).run()
        print(f"  alpha={alpha:.1f}: mean sojourn {res.mean_sojourn():7.1f}s")
    print("  -> sojourn times degrade only mildly with huge estimate errors")


if __name__ == "__main__":
    estimation_trace()
    robustness_mini()
