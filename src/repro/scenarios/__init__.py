"""Scenario engine: declarative experiment matrix, trace replay, sweeps.

The subsystem that owns "an experiment" (see docs/scenarios.md):

* :mod:`repro.scenarios.spec`    — ScenarioSpec / SweepSpec (the axes);
* :mod:`repro.scenarios.presets` — named sweeps (the paper's matrix);
* :mod:`repro.scenarios.trace`   — versioned JSONL trace export/replay;
* :mod:`repro.scenarios.runner`  — one cell -> simulator -> report;
* :mod:`repro.scenarios.sweep`   — parallel, resumable grid execution;
* :mod:`repro.scenarios.store`   — pluggable shared-store backends
  (fsync'd JSONL reference, sqlite for concurrent writers);
* :mod:`repro.scenarios.lease`   — TTL'd cell-claim protocol;
* :mod:`repro.scenarios.worker`  — distributed lease-claiming worker;
* :mod:`repro.scenarios.coordinator` — ``sweep-status`` progress view;
* :mod:`repro.scenarios.report`  — machine-readable JSON reductions.

CLI: ``python -m repro.scenarios run paper-fb --quick``; distributed:
``python -m repro.scenarios worker paper-fb --store shared.sqlite``.
"""

from repro.scenarios.presets import (
    get_preset,
    list_presets,
    paper_fb_base,
    quick_sweep,
    register_preset,
)
from repro.scenarios.report import matrix_report, scenario_report
from repro.scenarios.runner import run_scenario, simulate
from repro.scenarios.spec import (
    ClusterAxis,
    FaultAxis,
    ScenarioSpec,
    SchedulerAxis,
    SweepSpec,
    WorkloadAxis,
)
from repro.scenarios.coordinator import sweep_status
from repro.scenarios.store import ResultStore, SqliteResultStore, open_store
from repro.scenarios.sweep import run_sweep
from repro.scenarios.trace import export_trace, load_trace
from repro.scenarios.worker import run_worker

__all__ = [
    "ClusterAxis",
    "FaultAxis",
    "ResultStore",
    "SqliteResultStore",
    "ScenarioSpec",
    "SchedulerAxis",
    "SweepSpec",
    "WorkloadAxis",
    "export_trace",
    "get_preset",
    "list_presets",
    "load_trace",
    "matrix_report",
    "open_store",
    "paper_fb_base",
    "quick_sweep",
    "register_preset",
    "run_scenario",
    "run_sweep",
    "run_worker",
    "scenario_report",
    "simulate",
    "sweep_status",
]
