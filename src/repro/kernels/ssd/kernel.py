"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid = (batch, heads, num_chunks), chunks innermost; the (P x N) recurrent
state lives in VMEM scratch across chunk steps (sequential TPU grid).

Per chunk (length c, per-head scalar decays a_t, fp32):

    cum     = cumsum(log a)                              # (c,)
    ratio   = exp(cum_i - cum_j) lower-triangular (j<=i)
    scores  = ratio * (C B^T) * dt_j
    y       = scores @ x  +  exp(cum) * (C @ S^T)
    S       = exp(total) * S + x^T diag(dt * exp(total - cum)) B

Matmul shapes: (c x n)x(n x c), (c x c)x(c x p), (c x p)^T x (c x n) — MXU
tiles with c = 64..256, p = 64, n = 64..128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref,
    y_ref, sout_ref,
    s_scr,
    *, num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)       # (c, p)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (c, 1)
    a = a_ref[0, 0].astype(jnp.float32)       # (c, 1)
    B = b_ref[0].astype(jnp.float32)          # (c, n)
    C = c_ref[0].astype(jnp.float32)          # (c, n)
    S = s_scr[...]                            # (p, n)

    loga = jnp.log(jnp.maximum(a, 1e-38))
    cum = jnp.cumsum(loga, axis=0)            # (c, 1) inclusive
    total = cum[-1:, :]                       # (1, 1)

    cb = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (c_i, c_j)
    ratio = jnp.exp(cum - cum.T)              # (c_i, c_j)
    c = x.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    scores = jnp.where(col <= row, ratio * cb * dt.T, 0.0)
    y_intra = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (c, p)
    y_inter = jnp.exp(cum) * jax.lax.dot_general(
        C, S, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (c, p) — note (C @ S^T)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    xw = x * (dt * jnp.exp(total - cum))      # (c, p)
    s_new = jax.lax.dot_general(
        xw, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (p, n)
    s_scr[...] = jnp.exp(total) * S + s_new

    @pl.when(ci == num_chunks - 1)
    def _flush():
        sout_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_bhtp(
    x: jnp.ndarray,    # (b, h, t, p)
    dt: jnp.ndarray,   # (b, h, t)
    a: jnp.ndarray,    # (b, h, t)   per-step scalar decay
    B: jnp.ndarray,    # (b, t, n)
    C: jnp.ndarray,    # (b, t, n)
    s0: jnp.ndarray,   # (b, h, p, n)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, h, t, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[2] // chunk
    dt4 = dt[..., None]
    a4 = a[..., None]
    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, x.shape[2], p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt4, a4, B, C, s0)
    if pad:
        y = y[:, :, :t]
    return y, s_out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
