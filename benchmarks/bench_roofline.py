"""§Roofline table: reads the dry-run JSON (produced by
``python -m repro.launch.dryrun --all --out dryrun_1pod.json``) and prints
the per-(arch x shape) roofline terms + dominant bottleneck."""

from __future__ import annotations

import json
import os

from benchmarks.common import CsvOut


def main(out=None, path: str = "dryrun_1pod.json") -> dict:
    if not os.path.exists(path):
        print(f"# roofline: {path} not found — run "
              "`python -m repro.launch.dryrun --all --out dryrun_1pod.json`")
        return {}
    reports = json.load(open(path))
    table = CsvOut("roofline", [
        "arch", "shape", "status", "compute_ms", "memory_ms",
        "collective_ms", "dominant", "useful_ratio", "temp_gb",
    ])
    worst = None
    for r in reports:
        if r["status"] == "SKIP":
            table.add(r["arch"], r["shape"], "SKIP", "", "", "", "", "", "")
            continue
        if r["status"] != "OK" or "compute_s" not in r:
            table.add(r["arch"], r["shape"], r["status"], "", "", "", "", "", "")
            continue
        table.add(
            r["arch"], r["shape"], "OK",
            round(r["compute_s"] * 1e3, 2),
            round(r["memory_s"] * 1e3, 2),
            round(r["collective_s"] * 1e3, 2),
            r["dominant"],
            round(r.get("useful_ratio") or 0.0, 3),
            round((r.get("temp_bytes") or 0) / 2**30, 1),
        )
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / bound if bound else 0.0
        if worst is None or frac < worst[2]:
            worst = (r["arch"], r["shape"], frac)
    table.emit(out)
    if worst:
        print(f"# roofline: worst compute-fraction cell: {worst[0]} x "
              f"{worst[1]} ({worst[2]*100:.1f}% of bound is compute)")
    return {"cells": len(reports)}


if __name__ == "__main__":
    main()
