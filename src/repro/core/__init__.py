"""The paper's primary contribution: size-based scheduling (HFSP) with
online size estimation, a virtual PS cluster, and preemption primitives,
plus the FIFO/FAIR baselines and the discrete-event simulator."""

from repro.core import disciplines
from repro.core.disciplines import Discipline, DisciplineRegistry
from repro.core.estimator import (
    DistributionFitEstimator,
    FirstOrderEstimator,
    TrainingModule,
)
from repro.core.fair import FairScheduler
from repro.core.faults import FaultInjector, FaultModel, FirstFinisherWins
from repro.core.fifo import FIFOScheduler
from repro.core.hfsp import HFSPConfig, HFSPScheduler
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.simulator import SimConfig, SimResult, Simulator
from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    Preemption,
    TaskSpec,
)
from repro.core.vcluster import VirtualCluster, max_min_allocation, project_finish_times

__all__ = [
    "ClusterSpec",
    "Discipline",
    "DisciplineRegistry",
    "DistributionFitEstimator",
    "disciplines",
    "FIFOScheduler",
    "FairScheduler",
    "FaultInjector",
    "FaultModel",
    "FirstFinisherWins",
    "FirstOrderEstimator",
    "HFSPConfig",
    "HFSPScheduler",
    "JobSpec",
    "JobState",
    "Phase",
    "Preemption",
    "Scheduler",
    "SchedulerConfig",
    "SimConfig",
    "SimResult",
    "Simulator",
    "TaskSpec",
    "TrainingModule",
    "VirtualCluster",
    "max_min_allocation",
    "project_finish_times",
]
