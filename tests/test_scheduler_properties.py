"""Property-based tests (hypothesis) on scheduler/simulator invariants."""

import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    ClusterSpec,
    FairScheduler,
    FIFOScheduler,
    HFSPConfig,
    HFSPScheduler,
    JobSpec,
    Phase,
    Preemption,
    Simulator,
    TaskSpec,
)
from repro.core.types import TaskState
from repro.core.vcluster import discrete_allocation, max_min_allocation


# -- strategies ---------------------------------------------------------------
@st.composite
def workload(draw, max_jobs=6, max_tasks=12):
    n_jobs = draw(st.integers(1, max_jobs))
    jobs = []
    t = 0.0
    for jid in range(n_jobs):
        t += draw(st.floats(0.0, 20.0))
        n_map = draw(st.integers(1, max_tasks))
        n_red = draw(st.integers(0, 4))
        map_dur = draw(st.floats(1.0, 60.0))
        red_dur = draw(st.floats(1.0, 120.0))
        jobs.append(
            JobSpec(
                job_id=jid,
                arrival_time=t,
                map_tasks=tuple(
                    TaskSpec(jid, Phase.MAP, i, map_dur) for i in range(n_map)
                ),
                reduce_tasks=tuple(
                    TaskSpec(jid, Phase.REDUCE, i, red_dur)
                    for i in range(n_red)
                ),
            )
        )
    return jobs


SCHEDS = {
    "fifo": lambda c: FIFOScheduler(c),
    "fair": lambda c: FairScheduler(c),
    "hfsp-eager": lambda c: HFSPScheduler(c),
    "hfsp-wait": lambda c: HFSPScheduler(
        c, HFSPConfig(preemption=Preemption.WAIT)
    ),
    "hfsp-kill": lambda c: HFSPScheduler(
        c, HFSPConfig(preemption=Preemption.KILL)
    ),
}


@given(jobs=workload(), name=st.sampled_from(sorted(SCHEDS)))
@settings(max_examples=40, deadline=None)
def test_every_job_completes_and_conservation(jobs, name):
    """Liveness + work conservation: every job completes; completion is
    never before arrival + serialized_size / total_slots; and no task is
    left in a non-DONE state."""
    cluster = ClusterSpec(
        num_machines=2, map_slots_per_machine=2, reduce_slots_per_machine=1
    )
    sch = SCHEDS[name](cluster)
    res = Simulator(cluster, sch, jobs).run(max_events=500_000)
    assert set(res.completion) == {j.job_id for j in jobs}
    for j in jobs:
        soj = res.sojourn[j.job_id]
        assert soj > 0
        # Work conservation lower bound: a job cannot finish faster than
        # its critical path (longest single task) nor faster than its
        # serialized size over all slots.
        lower = max(
            max((t.duration for t in j.map_tasks), default=0.0),
            j.size_map / cluster.slots(Phase.MAP)
            if j.map_tasks
            else 0.0,
        )
        assert soj >= lower - 1e-6
    js_states = sch.jobs
    for js in js_states.values():
        for att in js.tasks.values():
            assert att.state is TaskState.DONE


@given(jobs=workload())
@settings(max_examples=25, deadline=None)
def test_fifo_completion_order_matches_arrival(jobs):
    """FIFO with uniform priorities completes MAP-only jobs in arrival
    order (same-duration tasks; ignoring multi-wave interleaving ties)."""
    jobs = [
        JobSpec(
            job_id=j.job_id,
            arrival_time=j.arrival_time,
            map_tasks=j.map_tasks,
            reduce_tasks=(),
        )
        for j in jobs
    ]
    cluster = ClusterSpec(
        num_machines=1, map_slots_per_machine=1, reduce_slots_per_machine=0
    )
    res = Simulator(cluster, FIFOScheduler(cluster), jobs).run(max_events=500_000)
    finish = [res.completion[j.job_id] for j in jobs]
    assert finish == sorted(finish)


@given(
    demands=st.dictionaries(
        st.integers(0, 10),
        st.tuples(st.floats(0, 50), st.floats(0.1, 4.0)),
        min_size=1,
        max_size=8,
    ),
    slots=st.floats(0.5, 64.0),
)
@settings(max_examples=100, deadline=None)
def test_max_min_is_feasible_and_exhaustive(demands, slots):
    alloc = max_min_allocation(demands, slots)
    total_cap = sum(c for c, _ in demands.values())
    assert sum(alloc.values()) <= slots + 1e-6
    # Exhaustive: either all slots used or every job is at its cap.
    if total_cap >= slots:
        assert sum(alloc.values()) >= slots - 1e-6
    for j, a in alloc.items():
        assert -1e-9 <= a <= demands[j][0] + 1e-6


@given(
    caps=st.lists(st.integers(0, 30), min_size=1, max_size=8),
    slots=st.integers(0, 64),
)
@settings(max_examples=100, deadline=None)
def test_discrete_allocation_integral(caps, slots):
    demands = {i: (c, 1.0) for i, c in enumerate(caps)}
    rank = {i: c for i, c in enumerate(caps)}
    alloc = discrete_allocation(demands, slots, rank)
    assert all(isinstance(v, int) for v in alloc.values())
    assert sum(alloc.values()) <= slots
    assert sum(alloc.values()) == min(slots, sum(caps))
    for i, c in enumerate(caps):
        assert 0 <= alloc[i] <= c


@given(jobs=workload(max_jobs=4))
@settings(max_examples=20, deadline=None)
def test_hfsp_determinism(jobs):
    """Same workload twice => identical completions (the scheduler and
    simulator are deterministic)."""
    def run():
        cluster = ClusterSpec(
            num_machines=2, map_slots_per_machine=2, reduce_slots_per_machine=1
        )
        return Simulator(cluster, HFSPScheduler(cluster), jobs).run(max_events=500_000)

    a, b = run(), run()
    assert a.completion == b.completion
