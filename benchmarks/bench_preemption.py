"""Fig. 7 — preemption primitives on the paper's synthetic REDUCE workload.

4 machines x 2 reduce slots; j1 = 11 reduce tasks x ~500 s arriving at
2:20; j2..j5 arrive at 2:30 (j2 has two tasks, j3..j5 one each, all much
shorter).  Paper: EAGER ~= 9 min mean sojourn vs WAIT ~= 15 min (~40%
larger), and KILL wastes j1's work."""

from __future__ import annotations

from benchmarks.common import CsvOut
from repro.core import (
    ClusterSpec,
    HFSPConfig,
    HFSPScheduler,
    JobSpec,
    Phase,
    Preemption,
    Simulator,
    TaskSpec,
)


def _workload():
    jobs = [
        JobSpec(
            job_id=1,
            arrival_time=140.0,  # 2 min 20 s
            map_tasks=(TaskSpec(1, Phase.MAP, 0, 1.0),),
            reduce_tasks=tuple(
                TaskSpec(1, Phase.REDUCE, i, 500.0) for i in range(11)
            ),
        )
    ]
    for jid in (2, 3, 4, 5):
        n = 2 if jid == 2 else 1
        # "Reduce task times are smaller than that of j1" (500 s) — the
        # paper gives no exact value; 240 s reproduces its 9-vs-15-min
        # landscape.
        jobs.append(
            JobSpec(
                job_id=jid,
                arrival_time=150.0,  # 2 min 30 s
                map_tasks=(TaskSpec(jid, Phase.MAP, 0, 1.0),),
                reduce_tasks=tuple(
                    TaskSpec(jid, Phase.REDUCE, i, 240.0) for i in range(n)
                ),
            )
        )
    return jobs


def main(out=None) -> dict:
    cluster = ClusterSpec(
        num_machines=4, map_slots_per_machine=1, reduce_slots_per_machine=2
    )
    table = CsvOut("fig7_preemption", [
        "primitive", "mean_sojourn_min", "j1_sojourn_min", "suspensions",
        "kills", "waits",
    ])
    results = {}
    for mode in (Preemption.EAGER, Preemption.WAIT, Preemption.KILL):
        sch = HFSPScheduler(cluster, HFSPConfig(preemption=mode, delta=60.0))
        res = Simulator(cluster, sch, _workload()).run()
        mean_min = res.mean_sojourn() / 60.0
        results[mode.value] = mean_min
        table.add(
            mode.value, round(mean_min, 1),
            round(res.sojourn[1] / 60.0, 1),
            sch.stats.suspensions, sch.stats.kills, sch.stats.waits,
        )
    table.emit(out)
    gap = results["wait"] / results["eager"]
    print(f"# fig7: EAGER {results['eager']:.1f} min vs WAIT "
          f"{results['wait']:.1f} min ({(gap-1)*100:.0f}% larger; paper: "
          f"~9 vs ~15 min, ~40%); KILL {results['kill']:.1f} min")
    return results


if __name__ == "__main__":
    main()
