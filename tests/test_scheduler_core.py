"""Scheduler-core behaviour tests: the paper's mechanisms in isolation."""

import math

import pytest

from repro.core import (
    ClusterSpec,
    FairScheduler,
    FIFOScheduler,
    HFSPConfig,
    HFSPScheduler,
    JobSpec,
    Phase,
    Preemption,
    Simulator,
    TaskSpec,
)
from repro.core.vcluster import (
    VirtualCluster,
    discrete_allocation,
    max_min_allocation,
    project_finish_times,
)


def mk_job(jid, arrival, n_map, dur, n_red=0, red_dur=0.0, hosts=()):
    return JobSpec(
        job_id=jid,
        arrival_time=arrival,
        map_tasks=tuple(
            TaskSpec(jid, Phase.MAP, i, dur, input_hosts=hosts)
            for i in range(n_map)
        ),
        reduce_tasks=tuple(
            TaskSpec(jid, Phase.REDUCE, i, red_dur) for i in range(n_red)
        ),
    )


def small_cluster(machines=2, mslots=2, rslots=1):
    return ClusterSpec(
        num_machines=machines,
        map_slots_per_machine=mslots,
        reduce_slots_per_machine=rslots,
    )


# ---------------------------------------------------------------------------
# Virtual cluster / PS math
# ---------------------------------------------------------------------------
class TestMaxMin:
    def test_uncapped_equal_share(self):
        alloc = max_min_allocation({1: (10, 1.0), 2: (10, 1.0)}, 10)
        assert alloc[1] == pytest.approx(5.0)
        assert alloc[2] == pytest.approx(5.0)

    def test_capped_redistribution(self):
        alloc = max_min_allocation({1: (2, 1.0), 2: (100, 1.0)}, 10)
        assert alloc[1] == pytest.approx(2.0)
        assert alloc[2] == pytest.approx(8.0)

    def test_weights(self):
        alloc = max_min_allocation({1: (100, 3.0), 2: (100, 1.0)}, 8)
        assert alloc[1] == pytest.approx(6.0)
        assert alloc[2] == pytest.approx(2.0)

    def test_discrete_small_first_leftovers(self):
        # 3 jobs, 4 slots: continuous share 4/3 -> floor 1 each, leftover
        # goes to the smallest job first.
        alloc = discrete_allocation(
            {1: (10, 1.0), 2: (10, 1.0), 3: (10, 1.0)},
            4,
            {1: 5, 2: 1, 3: 9},
        )
        assert sum(alloc.values()) == 4
        assert alloc[2] == 2  # smallest rank gets the leftover

    def test_discrete_never_exceeds_cap(self):
        alloc = discrete_allocation({1: (1, 1.0), 2: (3, 1.0)}, 10, {1: 1, 2: 3})
        assert alloc[1] == 1
        assert alloc[2] == 3


class TestProjectedFinish:
    def test_fsp_paper_example(self):
        """The paper's Fig. 1 example: j1 (30 s), j2 (10 s), j3 (10 s) on a
        unit-speed single server; arrivals 0/10/15.  Under PS, j2 finishes
        first, then j3, then j1."""
        # At t=15: j1 has ~22.5s left (ran alone 10s, shared 5s), j2 has
        # 7.5s left, j3 has 10s.  PS finish order must be j2, j3, j1.
        fin = project_finish_times(
            {1: (22.5, 1, 1.0), 2: (7.5, 1, 1.0), 3: (10.0, 1, 1.0)},
            1.0,
            15.0,
        )
        order = sorted(fin, key=fin.get)
        assert order == [2, 3, 1]

    def test_infinite_size_sorts_last(self):
        fin = project_finish_times(
            {1: (math.inf, 5, 1.0), 2: (10.0, 5, 1.0)}, 4, 0.0
        )
        assert math.isinf(fin[1])
        assert math.isfinite(fin[2])

    def test_aging_preserves_order(self):
        vc = VirtualCluster(phase=Phase.MAP, slots=4)
        vc.add_job(1, 100.0, 10)
        vc.add_job(2, 40.0, 10)
        before = vc.schedule_order(0.0)
        vc.age(5.0)
        assert vc.schedule_order(5.0) == before

    def test_virtual_cap_shrinks_with_tail(self):
        vc = VirtualCluster(phase=Phase.MAP, slots=8)
        vc.add_job(1, 100.0, 10)   # 10 tasks x 10 s
        v = vc.jobs[1]
        assert v.effective_cap() == 10
        vc.age(8.0)  # 8 slots x 8 s = 64 s of virtual work done
        assert v.effective_cap() == math.ceil((100 - 64) / 10)


# ---------------------------------------------------------------------------
# End-to-end simulator behaviour
# ---------------------------------------------------------------------------
class TestSimulator:
    def test_single_job_runs_to_completion(self):
        cluster = small_cluster()
        jobs = [mk_job(0, 0.0, 4, 10.0)]
        res = Simulator(cluster, FIFOScheduler(cluster), jobs).run()
        assert res.completion[0] == pytest.approx(10.0, abs=1.0)

    def test_waves(self):
        """8 tasks x 10 s on 4 slots = two waves = 20 s."""
        cluster = small_cluster()
        jobs = [mk_job(0, 0.0, 8, 10.0)]
        res = Simulator(cluster, FIFOScheduler(cluster), jobs).run()
        assert res.completion[0] == pytest.approx(20.0, abs=1.0)

    def test_fifo_head_of_line_blocking(self):
        """FIFO: a tiny job behind a big one waits for the whole big job."""
        cluster = small_cluster()
        jobs = [mk_job(0, 0.0, 8, 50.0), mk_job(1, 1.0, 1, 1.0)]
        res = Simulator(cluster, FIFOScheduler(cluster), jobs).run()
        assert res.sojourn[1] > 40.0

    def test_hfsp_rescues_small_job(self):
        """HFSP: the tiny job preempts and finishes quickly."""
        cluster = small_cluster()
        jobs = [mk_job(0, 0.0, 8, 50.0), mk_job(1, 1.0, 1, 1.0)]
        res = Simulator(cluster, HFSPScheduler(cluster), jobs).run()
        assert res.sojourn[1] < 15.0

    @pytest.mark.parametrize("mode", [Preemption.EAGER, Preemption.WAIT,
                                      Preemption.KILL])
    def test_all_preemption_modes_complete(self, mode):
        cluster = small_cluster()
        jobs = [
            mk_job(0, 0.0, 8, 30.0),
            mk_job(1, 5.0, 2, 5.0),
            mk_job(2, 6.0, 2, 5.0),
        ]
        sch = HFSPScheduler(cluster, HFSPConfig(preemption=mode))
        res = Simulator(cluster, sch, jobs).run()
        assert len(res.completion) == 3

    def test_kill_wastes_work(self):
        """KILL restarts tasks from scratch => makespan of the big job is
        strictly worse than with EAGER suspend/resume."""
        def run(mode):
            cluster = small_cluster()
            jobs = [mk_job(0, 0.0, 4, 100.0), mk_job(1, 50.0, 4, 5.0)]
            sch = HFSPScheduler(cluster, HFSPConfig(preemption=mode))
            return Simulator(cluster, sch, jobs).run()

        eager = run(Preemption.EAGER)
        kill = run(Preemption.KILL)
        assert kill.completion[0] > eager.completion[0] + 20.0

    def test_reduce_phase_runs(self):
        cluster = small_cluster()
        jobs = [mk_job(0, 0.0, 2, 5.0, n_red=2, red_dur=10.0)]
        res = Simulator(cluster, HFSPScheduler(cluster), jobs).run()
        assert res.completion[0] == pytest.approx(15.0, abs=2.0)

    def test_delay_scheduling_prefers_local(self):
        cluster = small_cluster(machines=4, mslots=1)
        # All tasks' data lives on machine 0 only.
        jobs = [mk_job(0, 0.0, 3, 5.0, hosts=(0,))]
        sch = HFSPScheduler(cluster)
        res = Simulator(cluster, sch, jobs).run()
        # Delay scheduling waits (bounded) for the local slot: most tasks
        # run locally, and at least one scheduling opportunity was skipped.
        assert res.locality_fraction >= 2 / 3
        assert sch.stats.delay_sched_waits > 0
        assert res.locality_hits >= 2

    def test_hysteresis_fallback(self):
        cluster = ClusterSpec(
            num_machines=2, map_slots_per_machine=2,
            reduce_slots_per_machine=0,
            suspend_bytes_hi=100, suspend_bytes_lo=10,
        )
        big = JobSpec(
            job_id=0, arrival_time=0.0,
            map_tasks=tuple(
                TaskSpec(0, Phase.MAP, i, 100.0, state_bytes=90)
                for i in range(4)
            ),
            reduce_tasks=(),
        )
        small = mk_job(1, 5.0, 4, 1.0)
        small2 = mk_job(2, 6.0, 4, 1.0)
        sch = HFSPScheduler(cluster)
        Simulator(cluster, sch, [big, small, small2]).run()
        assert sch.stats.hysteresis_fallbacks >= 1

    def test_eager_dma_cost_charged(self):
        """With a DMA cost model, every resume rolls progress back by
        state_bytes / dma_bw — total runtime grows by the swap cost."""
        cluster = ClusterSpec(
            num_machines=2, map_slots_per_machine=1,
            reduce_slots_per_machine=0, dma_bandwidth=1.0,  # 1 byte/s
        )
        big = JobSpec(
            job_id=0, arrival_time=0.0,
            map_tasks=tuple(
                TaskSpec(0, Phase.MAP, i, 50.0, state_bytes=10)
                for i in range(2)
            ),
            reduce_tasks=(),
        )
        small = mk_job(1, 5.0, 1, 5.0)
        sch = HFSPScheduler(cluster)
        res = Simulator(cluster, sch, [big, small]).run()
        assert sch.stats.suspensions >= 1
        # The suspended task loses its pre-suspension progress to the
        # 10-byte swap-in at 1 B/s: the job takes > 55 s.
        assert res.completion[0] >= 55.0


# ---------------------------------------------------------------------------
# Size estimation (Training module)
# ---------------------------------------------------------------------------
class TestEstimation:
    def test_estimate_converges_to_truth(self):
        cluster = small_cluster(machines=4, mslots=4)
        jobs = [mk_job(0, 0.0, 20, 7.0)]
        sch = HFSPScheduler(cluster)
        Simulator(cluster, sch, jobs).run()
        est = sch.jobs[0].est_size[Phase.MAP]
        assert est == pytest.approx(20 * 7.0, rel=0.01)

    def test_xi_infinite_parks_job(self):
        cluster = small_cluster()
        sch = HFSPScheduler(cluster, HFSPConfig(xi=math.inf))
        jobs = [mk_job(0, 0.0, 4, 5.0)]
        res = Simulator(cluster, sch, jobs).run()
        # Training still runs the sample set, so the job completes.
        assert 0 in res.completion

    def test_reduce_progress_estimation(self):
        """REDUCE tasks longer than Delta are estimated via sigma=Delta/p
        before completion (Sect. 3.2.1)."""
        cluster = small_cluster()
        sch = HFSPScheduler(cluster, HFSPConfig(delta=10.0))
        jobs = [mk_job(0, 0.0, 1, 1.0, n_red=2, red_dur=100.0)]
        sim = Simulator(cluster, sch, jobs)
        sim.run(until=30.0)
        est = sch.jobs[0].est_size.get(Phase.REDUCE)
        assert est == pytest.approx(200.0, rel=0.05)

    def test_fair_scheduler_shares(self):
        cluster = small_cluster(machines=2, mslots=2)  # 4 slots
        jobs = [mk_job(0, 0.0, 8, 10.0), mk_job(1, 0.5, 8, 10.0)]
        res = Simulator(cluster, FairScheduler(cluster), jobs).run()
        # Equal shares: both finish around 40 s (8 tasks x 10 s / 2 slots).
        assert abs(res.sojourn[0] - res.sojourn[1]) < 12.0
