"""The virtual cluster (Sect. 3.1).

HFSP ranks jobs by the time at which they *would* finish if the cluster were
running a max-min-fair processor-sharing (PS) discipline.  The virtual
cluster simulates exactly that: it mirrors the real cluster's slot counts,
allocates virtual slots to jobs with max-min fairness (round-robin, starting
from the smallest jobs), and *ages* jobs between scheduler events by
subtracting `dt x allocated_slots` from their serialized remaining work.

Job size is serialized (sum of task runtimes on one slot), so aging is
independent of the real cluster's state — the paper's trick for tolerating
failures and elastic width (DESIGN.md §2, §7).

One VirtualCluster instance exists per phase (MAP and REDUCE are scheduled
independently, Sect. 3.1).

Performance notes (the scheduler runs on every executor event):

* the discrete max-min allocation depends only on (caps, weights, slots) —
  NOT on remaining work — so it is recomputed lazily, only after
  membership/cap changes;
* the projected-finish ORDER is invariant under aging (in continuous PS all
  jobs age exactly at their allocated rate, so absolute projected finish
  times are constant between structural events); the order is therefore
  cached and recomputed only on job add/remove and size re-estimates.
  Cap changes (task completions) can only *accelerate* the affected job's
  PS finish; we accept the momentarily stale order until the next
  structural event, which in practice arrives within one heartbeat.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.types import Phase


@dataclass
class _VJob:
    job_id: int
    remaining: float          # serialized seconds (estimated)
    cap: int                  # parallelism at arrival = task count
    weight: float = 1.0       # GPS weight (Sect. 5)
    size_rank: int = 0        # number of tasks at arrival; round-robin order
    done: float = 0.0         # virtual work already aged away (for estimate updates)
    task_time: float = 1.0    # estimated serialized seconds per task

    def effective_cap(self) -> int:
        """Virtual parallelism: the number of *virtual* tasks still
        unfinished.  The virtual cluster is a pure PS simulation — its
        parallelism shrinks as virtual work depletes (the job's "tail"),
        NOT as real tasks complete.  Coupling it to real completions makes
        a focused job's projected PS finish time rise while it runs, which
        flips the schedule order and causes preemption thrash."""
        if math.isinf(self.remaining):
            return self.cap
        if self.task_time <= 0:
            return self.cap
        return max(1, min(self.cap, int(math.ceil(self.remaining / self.task_time - 1e-9))))


def max_min_allocation(
    demands: dict[int, tuple[float, float]], slots: float
) -> dict[int, float]:
    """Weighted max-min fair (water-filling) allocation.

    ``demands`` maps job_id -> (cap, weight).  Returns continuous slot
    shares summing to at most ``slots`` (less if total cap is smaller).
    """
    ids = list(demands)
    caps = np.array([demands[j][0] for j in ids], dtype=np.float64)
    ws = np.array([demands[j][1] for j in ids], dtype=np.float64)
    alloc = _water_fill(caps, ws, float(slots))
    return {j: float(a) for j, a in zip(ids, alloc)}


def _water_fill(caps: np.ndarray, ws: np.ndarray, slots: float) -> np.ndarray:
    """Vectorized weighted water-filling: fill proportionally to weight,
    clamp at cap, redistribute, repeat.  O(#cap-levels) rounds."""
    n = len(caps)
    alloc = np.zeros(n)
    active = caps > 0
    free = float(slots)
    while free > 1e-12 and active.any():
        total_w = ws[active].sum()
        if total_w <= 0:
            break
        share = np.zeros(n)
        share[active] = free * ws[active] / total_w
        headroom = caps - alloc
        capped = active & (share >= headroom - 1e-12)
        if not capped.any():
            alloc[active] += share[active]
            break
        grant = np.where(capped, headroom, 0.0)
        alloc += grant
        free -= float(grant.sum())
        active &= ~capped
    return alloc


def discrete_allocation(
    demands: dict[int, tuple[float, float]],
    slots: int,
    size_rank: dict[int, int],
) -> dict[int, int]:
    """Integer max-min allocation via round-robin, small jobs first.

    "Max-min fairness is achieved using a round-robin mechanism that starts
    allocating virtual cluster resources to small jobs (in terms of their
    number of tasks)." (Sect. 3.1)

    Implemented as floor(water-fill) + leftover slots granted one-by-one in
    small-job-first order among jobs with headroom — equivalent to the
    round-robin description but O(J log J).
    """
    ids = sorted(demands, key=lambda j: (size_rank.get(j, 0), j))
    caps = np.array([demands[j][0] for j in ids], dtype=np.float64)
    ws = np.array([demands[j][1] for j in ids], dtype=np.float64)
    cont = _water_fill(caps, ws, float(slots))
    base = np.minimum(np.floor(cont + 1e-9), caps).astype(np.int64)
    free = int(slots) - int(base.sum())
    if free > 0:
        # Leftovers: small-first round-robin over jobs with headroom.
        headroom = (caps - base).astype(np.int64)
        while free > 0 and (headroom > 0).any():
            for i in range(len(ids)):
                if free <= 0:
                    break
                if headroom[i] > 0:
                    base[i] += 1
                    headroom[i] -= 1
                    free -= 1
    return {j: int(b) for j, b in zip(ids, base)}


def project_finish_times(
    jobs: dict[int, tuple[float, float, float]], slots: float, now: float
) -> dict[int, float]:
    """Forward-simulate weighted max-min PS; return absolute finish times.

    ``jobs`` maps job_id -> (remaining_serialized, cap, weight).  Piecewise
    constant allocations: at each step the job with the minimal
    remaining/allocation finishes, its slots are redistributed, repeat.
    Jobs with infinite remaining (xi = inf initial estimates, Sect. 3.1.1)
    get finish time +inf and therefore sort last.
    """
    ids = list(jobs)
    rem = np.array([jobs[j][0] for j in ids], dtype=np.float64)
    caps = np.array([jobs[j][1] for j in ids], dtype=np.float64)
    ws = np.array([jobs[j][2] for j in ids], dtype=np.float64)
    fin = np.full(len(ids), np.inf)
    live = (rem > 0) & (caps > 0)
    fin[~live] = now
    t = now
    while live.any():
        alloc = np.zeros(len(ids))
        alloc[live] = _water_fill(caps[live], ws[live], float(slots))
        with np.errstate(divide="ignore", invalid="ignore"):
            dt = np.where(live & (alloc > 0), rem / np.maximum(alloc, 1e-300), np.inf)
        dt_min = dt.min()
        if not np.isfinite(dt_min):
            break  # only infinite-size jobs left -> they never finish in PS
        t += float(dt_min)
        rem = np.where(live, np.maximum(rem - alloc * dt_min, 0.0), rem)
        done = live & (dt <= dt_min + 1e-12)
        fin[done] = t
        live &= ~done
    return {j: float(f) for j, f in zip(ids, fin)}


@dataclass
class VirtualCluster:
    """Mirror of the real cluster for one phase (Sect. 3.1)."""

    phase: Phase
    slots: int
    jobs: dict[int, _VJob] = field(default_factory=dict)
    _alloc_cache: dict | None = field(default=None, repr=False)
    _order_cache: list | None = field(default=None, repr=False)

    # -- cache control --------------------------------------------------------
    def _invalidate_alloc(self) -> None:
        self._alloc_cache = None

    def _invalidate_order(self) -> None:
        self._order_cache = None

    # -- membership ---------------------------------------------------------
    def add_job(
        self,
        job_id: int,
        est_size: float,
        num_tasks: int,
        weight: float = 1.0,
    ) -> None:
        tt = est_size / num_tasks if (num_tasks and math.isfinite(est_size)) else 1.0
        self.jobs[job_id] = _VJob(
            job_id=job_id,
            remaining=est_size,
            cap=num_tasks,
            weight=weight,
            size_rank=num_tasks,
            task_time=max(tt, 1e-9),
        )
        self._invalidate_alloc()
        self._invalidate_order()

    def remove_job(self, job_id: int) -> None:
        if self.jobs.pop(job_id, None) is not None:
            self._invalidate_alloc()
            self._invalidate_order()

    def __contains__(self, job_id: int) -> bool:
        return job_id in self.jobs

    # -- estimate updates (Training module, Sect. 3.2) ----------------------
    def set_remaining(self, job_id: int, remaining: float) -> None:
        if job_id in self.jobs:
            self.jobs[job_id].remaining = remaining
            self._invalidate_order()

    def set_size(self, job_id: int, size: float) -> None:
        """Re-estimate total size: 'the job scheduler *updates* the remaining
        amount of work to be done for the job' (Sect. 3.1.1) — the virtual
        work already done is preserved."""
        if job_id in self.jobs:
            v = self.jobs[job_id]
            v.remaining = max(0.0, size - v.done)
            if v.cap and math.isfinite(size):
                v.task_time = max(size / v.cap, 1e-9)
            self._invalidate_alloc()
            self._invalidate_order()

    def set_cap(self, job_id: int, cap: int) -> None:
        if job_id in self.jobs and self.jobs[job_id].cap != cap:
            self.jobs[job_id].cap = cap
            self._invalidate_alloc()
            # Order kept: a cap drop only accelerates this job's PS finish
            # (see module docstring); next structural event refreshes it.

    def remaining(self, job_id: int) -> float:
        return self.jobs[job_id].remaining if job_id in self.jobs else 0.0

    # -- aging (Sect. 3.1, "Job aging") --------------------------------------
    def age(self, dt: float) -> None:
        """Distribute ``dt`` of progress to every allocated virtual task."""
        if dt <= 0 or not self.jobs:
            return
        alloc = self.allocation()
        cap_changed = False
        for j, vjob in self.jobs.items():
            a = alloc.get(j, 0)
            if a > 0:
                before = vjob.effective_cap()
                vjob.done += a * dt
                if not math.isinf(vjob.remaining):
                    vjob.remaining = max(0.0, vjob.remaining - a * dt)
                if vjob.effective_cap() != before:
                    cap_changed = True
        if cap_changed:
            # A virtual tail shrank below its allocation: redistribute.
            self._invalidate_alloc()
        # Aging preserves the projected finish ORDER (continuous-PS
        # invariance): the order cache stays valid.

    # -- queries --------------------------------------------------------------
    def allocation(self) -> dict[int, int]:
        if self._alloc_cache is None:
            demands = {
                j: (v.effective_cap(), v.weight) for j, v in self.jobs.items()
            }
            rank = {j: v.size_rank for j, v in self.jobs.items()}
            self._alloc_cache = discrete_allocation(demands, self.slots, rank)
        return self._alloc_cache

    def projected_finish(self, now: float) -> dict[int, float]:
        """Absolute PS finish time per job — HFSP's sort key (Sect. 3.1)."""
        return project_finish_times(
            {
                j: (v.remaining, v.effective_cap(), v.weight)
                for j, v in self.jobs.items()
            },
            self.slots,
            now,
        )

    def schedule_order(self, now: float) -> list[int]:
        """Job ids sorted by projected finish time, ties by id (FIFO-ish)."""
        if self._order_cache is None:
            fin = self.projected_finish(now)
            self._order_cache = sorted(
                fin, key=lambda j: (fin[j], self.jobs[j].size_rank, j)
            )
        return self._order_cache
