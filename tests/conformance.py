"""Reusable golden-trace equivalence harness.

Runs a scheduler over the FB trace workload and reduces the outcome to a
comparable summary (completion times, locality counters, preemption and
delay-scheduling stats).  Two runs that should be behaviorally identical —
incremental vs paranoid-cross-checked indexes, numpy vs jax virtual-cluster
backend, lazy vs eager aging — must produce *equal* summaries, floats
included: the contract everywhere is bit-identical schedules, not
approximately-similar ones.

Used by tests/test_incremental_engine.py (engine equivalence) and
tests/test_conformance.py (vcluster backend conformance).
"""

from __future__ import annotations

import hashlib

from repro.core import (
    FairScheduler,
    FIFOScheduler,
    HFSPConfig,
    HFSPScheduler,
    Preemption,
    SchedulerConfig,
    Simulator,
    disciplines,
)
from repro.workload import fb_cluster, fb_dataset

#: Scheduler variants the golden-trace suites cover.
TRACE_SCHEDULERS = ("fifo", "fair", "hfsp", "hfsp-kill")

#: The registry disciplines added by the Discipline API, covered by the
#: same golden-trace contract (tests/test_disciplines.py).
DISCIPLINE_SCHEDULERS = ("srpt", "las", "psbs")

#: Seeds of the golden traces.
GOLDEN_SEEDS = (0, 1, 2)


def run_trace(
    name: str,
    seed: int,
    *,
    paranoid: bool = False,
    vc_backend: str | None = None,
    vc_auto_threshold: int | None = None,
    num_jobs: int = 30,
    num_machines: int = 20,
    demand_indexed: bool = True,
    event_epsilon: float = 0.0,
    via_registry: bool = False,
    faults=None,
) -> dict:
    """One FB-trace simulation; returns the comparable outcome summary.

    ``vc_backend`` selects the virtual-cluster kernel backend for the HFSP
    variants (fifo/fair have no virtual cluster and ignore it);
    ``vc_auto_threshold`` sets the "auto" backend's numpy->jax latch point
    (None keeps the production default).  ``demand_indexed=False`` runs
    the legacy full-walk scheduling passes (must be bit-identical);
    ``event_epsilon`` sets the simulator's coalescing window (0 = legacy
    pass-per-event loop, also bit-identical).

    ``name`` may also be a registry discipline ("srpt" / "las" / "psbs"
    / anything registered); those always build through the registry.
    ``via_registry=True`` forces the fifo/fair/hfsp variants through
    ``repro.core.disciplines.build_scheduler`` too — the routing the
    scenario runner uses — which must be bit-identical to direct
    construction.

    ``faults`` is an optional :class:`repro.core.FaultModel`; when
    enabled, the summary grows ``"faults"`` (the injector's counters)
    and ``"fault_trace_sha"`` (a content hash of the full ordered
    failure-event trace) — the fault-determinism goldens compare those
    alongside the completions.
    """
    cluster = fb_cluster(num_machines=num_machines)
    jobs, _ = fb_dataset(seed=seed, num_jobs=num_jobs)
    if name in ("fifo", "fair"):
        cfg = SchedulerConfig(
            paranoid_indexes=paranoid, demand_indexed=demand_indexed
        )
        if via_registry:
            sch = disciplines.build_scheduler(name, cluster, config=cfg)
        elif name == "fifo":
            sch = FIFOScheduler(cluster, cfg)
        else:
            sch = FairScheduler(cluster, cfg)
    else:
        cfg = HFSPConfig(
            paranoid_indexes=paranoid,
            vc_backend=vc_backend,
            demand_indexed=demand_indexed,
        )
        if vc_auto_threshold is not None:
            cfg.vc_auto_threshold = vc_auto_threshold
        if name == "hfsp-kill":
            cfg.preemption = Preemption.KILL
        if name in ("hfsp", "hfsp-kill") and not via_registry:
            sch = HFSPScheduler(cluster, cfg)
        else:
            sch = disciplines.build_scheduler(
                "hfsp" if name == "hfsp-kill" else name, cluster, config=cfg
            )
    sim = Simulator(
        cluster, sch, jobs, event_epsilon=event_epsilon, faults=faults
    )
    res = sim.run()
    st = res.stats
    out = {
        "completion": dict(res.completion),
        "locality": (res.locality_hits, res.locality_misses),
        "preemption": (st.suspensions, st.resumes, st.kills, st.waits),
        "delay": st.delay_sched_waits,
        "training": st.training_tasks,
        "passes": res.passes,
    }
    if res.faults is not None:
        out["faults"] = res.faults
        # sha256 of the repr, not hash(): the trace tuples contain
        # strings and must fingerprint identically across processes
        # (PYTHONHASHSEED randomizes str hashes).
        out["fault_trace_sha"] = hashlib.sha256(
            repr(sim._injector.trace).encode()
        ).hexdigest()
    return out


def assert_traces_equal(a: dict, b: dict) -> None:
    """Assert two run_trace summaries are bit-identical, diffing the
    first divergent completions on failure (an opaque dict-compare failure
    over 30 float completion times is useless for debugging)."""
    ca, cb = a["completion"], b["completion"]
    assert set(ca) == set(cb), (
        f"job sets differ: only-in-a={set(ca) - set(cb)} "
        f"only-in-b={set(cb) - set(ca)}"
    )
    diffs = {j: (ca[j], cb[j]) for j in ca if ca[j] != cb[j]}
    assert not diffs, f"completion times differ (job: (a, b)): {diffs}"
    for key in ("locality", "preemption", "delay", "training", "passes"):
        assert a[key] == b[key], f"{key} differs: {a[key]} != {b[key]}"
    for key in ("faults", "fault_trace_sha"):
        if key in a or key in b:
            assert a.get(key) == b.get(key), (
                f"{key} differs: {a.get(key)} != {b.get(key)}"
            )
