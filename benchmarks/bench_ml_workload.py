"""Beyond-paper: HFSP over the ML-job workload (DESIGN.md §2), two ways.

1. Simulated at production scale: jobs are train/serve runs of the
   assigned architectures (step quanta as tasks, sizes from the §Roofline
   step-time estimates), on a 32-gang pod.
2. Real execution: reduced-config JAX training jobs driven by the
   GangRuntime under FIFO vs HFSP on this host (sojourn in wall seconds).
"""

from __future__ import annotations

import tempfile

from benchmarks.common import CsvOut, SCHEDULERS
from repro.core import ClusterSpec, Simulator
from repro.workload import ml_dataset


def simulated(out=None) -> dict:
    cluster = ClusterSpec(
        num_machines=32, map_slots_per_machine=1, reduce_slots_per_machine=0
    )
    table = CsvOut("ml_sim", ["scheduler", "mean_sojourn_s", "p95_s"])
    import numpy as np

    means = {}
    for name in ("fifo", "fair", "hfsp"):
        jobs, _ = ml_dataset(seed=1, num_jobs=40, gang_slots=32)
        sch = SCHEDULERS[name](cluster)
        res = Simulator(cluster, sch, jobs).run()
        vals = np.asarray(list(res.sojourn.values()))
        means[name] = float(vals.mean())
        table.add(name, round(means[name], 1),
                  round(float(np.percentile(vals, 95)), 1))
    table.emit(out)
    print(f"# ml_sim: mean sojourn fifo={means['fifo']:.0f}s "
          f"fair={means['fair']:.0f}s hfsp={means['hfsp']:.0f}s")
    return means


def real(out=None) -> dict:
    """Small real-JAX run (a few jobs, reduced configs) — smoke-scale."""
    from repro.checkpoint import CheckpointStore
    from repro.configs import get_smoke
    from repro.core import FIFOScheduler, HFSPConfig, HFSPScheduler
    from repro.runtime import GangRuntime, MLJob

    def jobs():
        return [
            MLJob(0, get_smoke("olmo_1b"), total_steps=8, steps_per_quantum=2,
                  arrival_time=0.0, name="big"),
            MLJob(1, get_smoke("gemma2_2b"), total_steps=2,
                  steps_per_quantum=1, arrival_time=2.0, name="small-1"),
            MLJob(2, get_smoke("rwkv6_1b6"), total_steps=2,
                  steps_per_quantum=1, arrival_time=3.0, name="small-2"),
        ]

    cluster = ClusterSpec(num_machines=1, map_slots_per_machine=1,
                          reduce_slots_per_machine=0)
    table = CsvOut("ml_real", ["scheduler", "mean_sojourn_s", "small_mean_s"])
    means = {}
    for name, mk in (
        ("fifo", lambda c: FIFOScheduler(c)),
        ("hfsp", lambda c: HFSPScheduler(c, HFSPConfig(sample_set_size=1))),
    ):
        with tempfile.TemporaryDirectory() as d:
            rtm = GangRuntime(cluster, mk(cluster), jobs(), CheckpointStore(d))
            rep = rtm.run(max_wall_s=300)
        small = [rep["sojourn"][j] for j in (1, 2) if j in rep["sojourn"]]
        means[name] = rep["mean_sojourn"]
        table.add(name, round(rep["mean_sojourn"], 1),
                  round(sum(small) / max(len(small), 1), 1))
    table.emit(out)
    print(f"# ml_real: mean sojourn fifo={means['fifo']:.1f}s "
          f"hfsp={means['hfsp']:.1f}s (real JAX jobs on this host)")
    return means


def main(out=None) -> dict:
    a = simulated(out)
    b = real(out)
    return {"sim": a, "real": b}


if __name__ == "__main__":
    main()
