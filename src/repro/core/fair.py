"""The Hadoop Fair Scheduler ("FAIR", Sect. 2.2), single pool, with delay
scheduling [31].

"When a slot on a machine is free and needs to be assigned a task, FAIR
proceeds as follows: if there is any job below its minimum share, it
schedules a task of that particular job.  Otherwise, FAIR schedules a task
that belongs to the job that has received less resource, based on the
notion of 'deficit'."

With a single pool and default parameters the minimum share is 0, so the
deficit rule drives everything: free slots go to the job whose running-task
count is furthest below its max-min fair share.  No preemption.

Per pass, each job is granted up to its (max-min) fair target in deficit
order — equivalent to the slot-at-a-time deficit rule but one sort per
pass instead of one per slot.

Iteration goes through the base scheduler's demand indexes
(:meth:`~repro.core.scheduler.Scheduler.demand_union`): the fair targets
are computed over every phase-live job (running counts shape the
deficits), but the assignment sort covers only jobs with pending demand —
the only ones that can receive a slot — so the per-pass sort is
O(pending jobs x log) instead of O(live jobs x log).
"""

from __future__ import annotations

from repro.core.disciplines import FairDeficitRank
from repro.core.scheduler import Action, ClusterView, Scheduler
from repro.core.types import Phase
from repro.core.vcluster import discrete_allocation


class FairScheduler(Scheduler):
    name = "fair"
    #: The discipline rank this scheduler assembles (registry entry
    #: "fair"): the per-pass deficit sort uses exactly this key.
    rank_policy = FairDeficitRank

    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        self._begin_pass()
        actions: list[Action] = []
        for phase in (Phase.MAP, Phase.REDUCE):
            if self.config.paranoid_indexes:
                self._paranoid_check(view, phase)
            free = view.free_slots(phase)
            if not free:
                continue
            if self.config.demand_indexed:
                by_id = self.demand_union(phase)
            else:
                # Index-free reference: scan the live table directly.
                by_id = self.live_jobs_scan(phase)
            if not by_id:
                continue
            demands = {
                jid: (self._demand(js, phase), js.spec.weight)
                for jid, js in by_id.items()
            }
            # Equal-share max-min targets over *total* slots.
            targets = discrete_allocation(
                demands,
                self.cluster.slots(phase),
                {jid: 0 for jid in by_id},  # no small-first bias
            )
            # Deficit order: furthest below fair target first, FIFO ties.
            # Only jobs with pending tasks can take a slot; the demand
            # index narrows the sort to exactly those (a job without
            # pending demand is a no-op in _assign_pending regardless of
            # its deficit).
            if self.config.demand_indexed:
                # The pending index is a subset of demand_union by the
                # paranoid-checked invariant — no membership re-filter.
                cand = list(self._jobs_pending[phase.value])
            else:
                cand = list(by_id)
            order = sorted(
                cand, key=FairDeficitRank.deficit_key(targets, by_id, phase)
            )
            for j in order:
                if not free:
                    break
                js = by_id[j]
                deficit = targets[j] - js.n_running(phase)
                if deficit <= 0:
                    continue
                acts, free = self._assign_pending(js, phase, free, deficit, now)
                actions.extend(acts)
        return actions
