"""LiveEngine: one Simulator driven by wall-clock time, journaled so a
twin replay reproduces it bit-for-bit.

Clock mapping
-------------
``virtual_now = v0 + (wall - w0) * time_scale``.  ``time_scale`` exists
so tests and smoke runs compress hours of simulated workload into
sub-second wall time; production would run at 1.0.  All *scheduling*
happens in virtual time — the wall clock only decides *when* the master
bothers to advance, and each advance that processes events is journaled
with its virtual barrier time, making the wall clock's jitter part of
the recorded history instead of a source of divergence.

Determinism contract (the twin property)
----------------------------------------
The engine touches its Simulator exclusively through four journaled
operations, in journal order:

1. ``run(until=T)``        <- ``{"event": "advance", "t": T}``
2. ``submit(job)``         <- a job line (repro-trace schema)
3. ``inject_fault(T,k,m)`` <- ``{"event": "crash"|"recover", ...}``
4. ``set_event_epsilon``   <- ``{"event": "eps", ...}``

:func:`replay_journal` makes the identical call sequence on a fresh
Simulator, so every heap push happens in the same relative order with
the same timestamps — completions, preemptions and fault handling are
bit-identical, and ``completion_fingerprint`` of live and twin match.
The advance lines are written *ahead* of the run (an advance is
journaled only when the heap holds an event at or before the barrier,
i.e. exactly when the run will do work): if the master dies mid-pass,
the restored engine replays the advance to completion instead of
losing it.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import asdict

from repro.core import disciplines
from repro.core.faults import FaultModel
from repro.core.simulator import SimConfig, Simulator, auto_event_epsilon
from repro.core.types import ClusterSpec, JobSpec
from repro.scenarios.report import completion_fingerprint
from repro.scenarios.trace import job_from_record
from repro.service.journal import Journal, read_journal

#: Arrival-history window the auto-epsilon controller measures over.
EPS_HISTORY = 64


def live_fingerprint(sim: Simulator) -> int:
    """Order-insensitive completion-schedule fingerprint (the same
    reduction scenario reports record, shared so live, twin and offline
    runs compare directly)."""
    return completion_fingerprint(sim.result)


def _build_sim(meta: dict) -> Simulator:
    """Fresh Simulator from journal meta — shared by first boot, crash
    restore and the offline twin so all three are the same machine."""
    cluster = ClusterSpec(**meta["cluster"])
    scheduler = disciplines.build_scheduler(
        meta["policy"], cluster, **meta.get("scheduler_kwargs", {})
    )
    return Simulator(
        cluster,
        scheduler,
        [],
        config=SimConfig(
            heartbeat=meta.get("heartbeat", 3.0),
            event_epsilon=meta.get("event_epsilon", 0.0),
            faults=FaultModel(external=True),
        ),
    )


def replay_journal(path) -> Simulator:
    """Deterministic twin: drive a fresh Simulator through the recorded
    stimulus sequence and return it (fully advanced to the last
    journaled barrier)."""
    meta, entries = read_journal(path)
    sim = _build_sim(meta)
    for d in entries:
        ev = d.get("event")
        if ev is None:
            sim.submit(job_from_record(d))
        elif ev == "advance":
            sim.run(until=d["t"])
        elif ev in ("crash", "recover"):
            sim.inject_fault(d["t"], ev, d["machine"])
        elif ev == "eps":
            sim.set_event_epsilon(d["value"])
    return sim


class LiveEngine:
    """Wall-clock driver around one journaled Simulator."""

    def __init__(
        self,
        sim: Simulator,
        journal: Journal,
        *,
        time_scale: float = 1.0,
        v0: float = 0.0,
        next_job_id: int = 0,
        submitted: int = 0,
    ):
        self.sim = sim
        self.journal = journal
        self.time_scale = float(time_scale)
        self.v0 = float(v0)
        self.w0 = time.monotonic()
        self.next_job_id = next_job_id
        self.submitted = submitted
        #: Wall seconds per work-doing advance (scheduling passes +
        #: event mutation) — telemetry reports p50/p95/p99 of these.
        self.decision_latency_s: list[float] = []
        self._arrival_history: deque[float] = deque(maxlen=EPS_HISTORY)

    # -- construction ---------------------------------------------------
    @classmethod
    def create(
        cls,
        journal_path,
        policy: str,
        cluster: ClusterSpec,
        *,
        heartbeat: float = 3.0,
        event_epsilon: float | str = 0.0,
        time_scale: float = 1.0,
        scheduler_kwargs: dict | None = None,
    ) -> "LiveEngine":
        """Fresh service: new journal, empty simulator."""
        eps0 = 0.0 if event_epsilon == "auto" else float(event_epsilon)
        meta = {
            "policy": policy,
            "cluster": asdict(cluster),
            "heartbeat": heartbeat,
            "event_epsilon": eps0,
            "time_scale": time_scale,
        }
        if scheduler_kwargs:
            meta["scheduler_kwargs"] = dict(scheduler_kwargs)
        journal = Journal(journal_path, meta=meta)
        return cls(_build_sim(meta), journal, time_scale=time_scale)

    @classmethod
    def restore(
        cls, journal_path, *, time_scale: float | None = None
    ) -> "LiveEngine":
        """Crash restore: replay the (repaired) journal into a fresh
        simulator and resume the virtual clock at the recorded
        high-water mark.

        Scheduler and estimator state need no snapshot of their own —
        the journal *is* the checkpoint (log-structured): replaying it
        reconstructs every internal table bit-identically, which is the
        same property the twin tests assert.
        """
        journal = Journal(journal_path)  # repairs any torn tail
        meta, entries = read_journal(journal_path)
        sim = _build_sim(meta)
        hwm = 0.0
        next_id = 0
        submitted = 0
        arrivals = deque(maxlen=EPS_HISTORY)
        for d in entries:
            ev = d.get("event")
            if ev is None:
                sim.submit(job_from_record(d))
                hwm = max(hwm, d["arrival_time"])
                next_id = max(next_id, int(d["job_id"]) + 1)
                submitted += 1
                arrivals.append(float(d["arrival_time"]))
            elif ev == "advance":
                sim.run(until=d["t"])
                hwm = max(hwm, d["t"])
            elif ev in ("crash", "recover"):
                sim.inject_fault(d["t"], ev, d["machine"])
                hwm = max(hwm, d["t"])
            elif ev == "eps":
                sim.set_event_epsilon(d["value"])
        eng = cls(
            sim,
            journal,
            time_scale=(
                time_scale if time_scale is not None
                else meta.get("time_scale", 1.0)
            ),
            v0=hwm,
            next_job_id=next_id,
            submitted=submitted,
        )
        eng._arrival_history = arrivals
        return eng

    # -- clock ----------------------------------------------------------
    def virtual_now(self) -> float:
        return self.v0 + (time.monotonic() - self.w0) * self.time_scale

    # -- journaled operations -------------------------------------------
    def advance(self, v: float | None = None) -> bool:
        """Catch the simulator up to virtual time ``v`` (default: now).

        Journals the barrier (write-ahead) only when the heap holds an
        event due by ``v`` — idle ticks leave no trace, so the journal
        records history, not the pacer's polling rate.  Returns whether
        work was done.
        """
        if v is None:
            v = self.virtual_now()
        heap = self.sim._heap
        if not (heap and heap[0][0] <= v):
            return False
        self.journal.append_event({"event": "advance", "t": v})
        t0 = time.perf_counter()
        self.sim.run(until=v)
        self.decision_latency_s.append(time.perf_counter() - t0)
        return True

    def submit(
        self, payload: dict, *, user: str | None = None, tag: str | None = None
    ) -> JobSpec:
        """Admit one job now: assign id + arrival time, journal, inject."""
        v = self.virtual_now()
        self.advance(v)
        rec = dict(payload)
        rec["job_id"] = self.next_job_id
        rec["arrival_time"] = v
        spec = job_from_record(rec)
        self.journal.append_job(spec, user=user, tag=tag)
        self.next_job_id += 1
        self.submitted += 1
        self._arrival_history.append(v)
        self.sim.submit(spec)
        return spec

    def inject(self, kind: str, machine: int) -> float:
        """Scripted fault now (worker death -> crash, rejoin -> recover)."""
        v = self.virtual_now()
        self.advance(v)
        self.journal.append_event({"event": kind, "t": v, "machine": machine})
        self.sim.inject_fault(v, kind, machine)
        return v

    def retune_epsilon(self) -> float:
        """Auto-epsilon controller: re-derive the coalescing window from
        recent arrival burstiness; journal the retune iff it changed."""
        v = self.virtual_now()
        self.advance(v)
        eps = auto_event_epsilon(list(self._arrival_history), self.sim.heartbeat)
        if eps != self.sim.event_epsilon:
            self.journal.append_event({"event": "eps", "t": v, "value": eps})
            self.sim.set_event_epsilon(eps)
        return eps

    # -- observability ---------------------------------------------------
    def live_jobs(self) -> int:
        """Jobs submitted but not yet complete (admission backpressure)."""
        return self.submitted - len(self.sim.result.completion)

    def fingerprint(self) -> int:
        return live_fingerprint(self.sim)
