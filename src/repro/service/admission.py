"""Multi-tenant admission control: per-user queues, token-bucket rate
limits, max-live-jobs backpressure.

Admission decides *when* a submission reaches the engine, never *what*
the engine does with it — decisions are therefore allowed to depend on
wall-clock state (token buckets) without hurting the twin property:
only the admitted arrival, with its journaled arrival time, exists as
far as replay is concerned.

Flow for one submission:

1. token bucket for the user (``rate_limit`` jobs/s, burst ``burst``)
   — empty bucket rejects immediately (``reject-rate``: the client
   should back off, queueing would defeat the limit);
2. live-jobs backpressure — at or above ``max_live_jobs`` the job is
   queued per-user (FIFO) instead of admitted; a full queue rejects
   (``reject-queue``);
3. otherwise ``admit``.

Queued work drains round-robin across users (one job per user per
cycle — a burst from one tenant cannot starve the others) whenever
capacity frees up; the master calls :meth:`AdmissionControl.drain`
from its pacer and on every completion.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class AdmissionConfig:
    #: Backpressure threshold: submissions queue once this many jobs are
    #: live (submitted, not yet complete) in the engine.
    max_live_jobs: int = 64
    #: Per-user sustained admission rate in jobs/sec (None = unlimited).
    rate_limit: float | None = None
    #: Token-bucket depth: how many jobs a user may burst above the rate.
    burst: int = 8
    #: Per-user queue depth; submissions beyond it are rejected.
    max_queue_per_user: int = 256


@dataclass
class _Bucket:
    tokens: float
    stamp: float


@dataclass
class AdmissionControl:
    cfg: AdmissionConfig = field(default_factory=AdmissionConfig)

    def __post_init__(self):
        self._buckets: dict[str, _Bucket] = {}
        self._queues: dict[str, deque] = {}
        # Round-robin pointer: users in first-queued order; drain
        # rotates through them one job at a time.
        self._rr: deque[str] = deque()

    # -- token bucket ----------------------------------------------------
    def _take_token(self, user: str, wall_now: float) -> bool:
        rate = self.cfg.rate_limit
        if rate is None:
            return True
        b = self._buckets.get(user)
        if b is None:
            b = self._buckets[user] = _Bucket(float(self.cfg.burst), wall_now)
        b.tokens = min(
            float(self.cfg.burst), b.tokens + (wall_now - b.stamp) * rate
        )
        b.stamp = wall_now
        if b.tokens < 1.0:
            return False
        b.tokens -= 1.0
        return True

    # -- admission -------------------------------------------------------
    def offer(self, user: str, item, wall_now: float, live_jobs: int) -> str:
        """One submission; returns ``"admit"`` | ``"queued"`` |
        ``"reject-rate"`` | ``"reject-queue"``.  On ``"queued"`` the
        item is held until :meth:`drain` releases it."""
        if not self._take_token(user, wall_now):
            return "reject-rate"
        if live_jobs >= self.cfg.max_live_jobs:
            q = self._queues.get(user)
            if q is None:
                q = self._queues[user] = deque()
            if len(q) >= self.cfg.max_queue_per_user:
                return "reject-queue"
            if user not in self._rr:
                self._rr.append(user)
            q.append(item)
            return "queued"
        return "admit"

    def drain(self, live_jobs: int) -> list[tuple[str, object]]:
        """Release queued submissions round-robin across users up to the
        live-jobs ceiling; returns ``[(user, item), ...]`` in admission
        order."""
        out: list[tuple[str, object]] = []
        budget = self.cfg.max_live_jobs - live_jobs
        while budget > 0 and self._rr:
            user = self._rr[0]
            q = self._queues.get(user)
            if not q:
                self._rr.popleft()
                continue
            out.append((user, q.popleft()))
            budget -= 1
            self._rr.rotate(-1)
            if not q:
                # Drop the now-empty user from rotation (it re-enters
                # on its next queued submission).
                self._rr.remove(user)
        return out

    # -- restore ---------------------------------------------------------
    def queued_items(self) -> dict[str, list]:
        """Snapshot of queued submissions (checkpointed by the master —
        queued jobs are the only state not yet in the journal)."""
        return {u: list(q) for u, q in self._queues.items() if q}

    def requeue(self, queued: dict[str, list]) -> None:
        for user, items in queued.items():
            q = self._queues.setdefault(user, deque())
            q.extend(items)
            if q and user not in self._rr:
                self._rr.append(user)

    def queued_count(self) -> int:
        return sum(len(q) for q in self._queues.values())
