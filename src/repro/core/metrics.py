"""Sojourn-time metrics and ECDF helpers consumed by the benchmarks."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.simulator import SimResult


def ecdf(values: list[float]) -> tuple[np.ndarray, np.ndarray]:
    """Empirical CDF: returns (sorted values, cumulative probabilities)."""
    xs = np.sort(np.asarray(values, dtype=np.float64))
    ps = np.arange(1, len(xs) + 1) / len(xs)
    return xs, ps


@dataclass
class SojournSummary:
    mean: float
    median: float
    p95: float
    count: int

    @classmethod
    def of(cls, values: list[float]) -> "SojournSummary":
        if not values:
            return cls(0.0, 0.0, 0.0, 0)
        a = np.asarray(values, dtype=np.float64)
        return cls(
            float(a.mean()), float(np.median(a)), float(np.percentile(a, 95)),
            len(a),
        )


def per_class_sojourns(
    result: SimResult, class_of: dict[int, str]
) -> dict[str, list[float]]:
    out: dict[str, list[float]] = {}
    for jid, s in result.sojourn.items():
        out.setdefault(class_of.get(jid, "?"), []).append(s)
    return out


def summarize(result: SimResult, class_of: dict[int, str]) -> dict[str, SojournSummary]:
    per = per_class_sojourns(result, class_of)
    out = {c: SojournSummary.of(v) for c, v in sorted(per.items())}
    out["all"] = SojournSummary.of(list(result.sojourn.values()))
    return out


def per_job_delta(a: SimResult, b: SimResult) -> dict[int, float]:
    """sojourn_a - sojourn_b per job (positive = b is better), Fig. 4."""
    sa, sb = a.sojourn, b.sojourn
    return {j: sa[j] - sb[j] for j in sa if j in sb}


#: Percentiles reported by scenario reports (compact ECDF summary).
ECDF_PERCENTILES = (5, 25, 50, 75, 90, 95, 99)


def ecdf_quantiles(
    values: list[float], percentiles: tuple[int, ...] = ECDF_PERCENTILES
) -> dict[str, float]:
    """Compact machine-readable ECDF: {"p50": ..., "p95": ...}.

    The full :func:`ecdf` is exact but O(n) wide; scenario reports store
    these fixed quantiles instead so cross-PR JSON diffs stay readable.
    """
    if not values:
        return {f"p{p}": 0.0 for p in percentiles}
    a = np.asarray(values, dtype=np.float64)
    return {
        f"p{p}": float(np.percentile(a, p)) for p in percentiles
    }


#: Tail percentiles for the tails report block (99.9 renders as "p999").
TAIL_PERCENTILES = (99, 99.9)


def tail_quantiles(
    values: list[float],
    percentiles: tuple[float, ...] = TAIL_PERCENTILES,
) -> dict[str, float]:
    """Extreme-tail quantiles: {"p99": ..., "p999": ...}.

    Labels drop the decimal point (99.9 -> "p999") so the report keys
    stay valid identifiers.  The ECDF quantiles stop at p95/p99; these
    are the tails the service telemetry and the bench gate watch.
    """
    labels = [
        "p" + (f"{p:g}".replace(".", "")) for p in percentiles
    ]
    if not values:
        return {lab: 0.0 for lab in labels}
    a = np.asarray(values, dtype=np.float64)
    return {
        lab: float(np.percentile(a, p))
        for lab, p in zip(labels, percentiles)
    }


def jain_index(values: list[float]) -> float:
    """Jain's fairness index: (sum x)^2 / (n * sum x^2), in (0, 1].

    1.0 means every job got exactly the same value (perfect fairness);
    1/n means one job got everything.  Computed over per-job slowdowns
    it is the standard fairness-of-slowdown measure for size-based
    disciplines (the "is HFSP unfair to large jobs?" question of
    Sect. 4.2).  Empty or all-zero input returns 1.0 (trivially fair).
    """
    if not values:
        return 1.0
    a = np.asarray(values, dtype=np.float64)
    denom = len(a) * float((a * a).sum())
    if denom <= 0:
        return 1.0
    return float(a.sum()) ** 2 / denom


def slowdowns(
    result: SimResult, size_of: dict[int, float]
) -> dict[int, float]:
    """Per-job slowdown: sojourn / serialized size.

    ``size_of`` maps job_id -> serialized job size (sum of task runtimes
    on one slot — the paper's size notion, Sect. 3.1).  A job whose
    sojourn equals its serialized size ran as if alone on one slot;
    values below 1 reflect parallel speedup, large values reflect
    queueing.  Jobs with non-positive size are skipped.
    """
    out: dict[int, float] = {}
    for jid, s in result.sojourn.items():
        size = size_of.get(jid, 0.0)
        if size > 0:
            out[jid] = s / size
    return out
