"""CLI: ``python -m repro.service {master,worker,replay}``.

* ``master`` — boot (or crash-restore, if the journal already exists) a
  live master.  Prints ``LISTENING <port>`` on stdout once serving so
  wrappers can parse the ephemeral port; ``--port-file`` additionally
  writes it to a file (robust across a SIGKILL'd predecessor).
* ``worker`` — one worker agent for one machine.
* ``replay`` — run the deterministic twin over a recorded journal and
  print the completion fingerprint + summary (the offline half of the
  live-vs-twin assertion; scripts/service_smoke.py consumes the JSON).
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
from pathlib import Path

from repro.core.types import ClusterSpec
from repro.service.admission import AdmissionConfig
from repro.service.engine import LiveEngine, live_fingerprint, replay_journal
from repro.service.master import Master, MasterConfig
from repro.service.worker import run_worker


def _master(args) -> int:
    if Path(args.journal).exists():
        engine = LiveEngine.restore(args.journal, time_scale=args.time_scale)
    else:
        cluster = ClusterSpec(
            num_machines=args.machines,
            map_slots_per_machine=args.map_slots,
            reduce_slots_per_machine=args.reduce_slots,
        )
        engine = LiveEngine.create(
            args.journal,
            args.policy,
            cluster,
            heartbeat=args.heartbeat,
            event_epsilon=args.eps,
            time_scale=args.time_scale,
        )
    cfg = MasterConfig(
        host=args.host,
        port=args.port,
        checkpoint_path=args.checkpoint,
        worker_dead_wall=args.worker_dead_wall,
        eps_auto_every_wall=args.eps_auto_every,
        admission=AdmissionConfig(
            max_live_jobs=args.max_live_jobs,
            rate_limit=args.rate_limit,
            burst=args.burst,
        ),
    )

    async def main() -> None:
        master = Master(engine, cfg)
        await master.start()
        print(f"LISTENING {master.port}", flush=True)
        if args.port_file:
            Path(args.port_file).write_text(str(master.port))
        await master.serve_forever()

    asyncio.run(main())
    return 0


def _worker(args) -> int:
    host, port = args.connect.rsplit(":", 1)
    asyncio.run(
        run_worker(
            host, int(port), args.machine, heartbeat_wall=args.heartbeat_wall
        )
    )
    return 0


def _replay(args) -> int:
    sim = replay_journal(args.journal)
    out = {
        "journal": str(args.journal),
        "completion_fingerprint": live_fingerprint(sim),
        "jobs_completed": len(sim.result.completion),
        "makespan_s": sim.result.makespan,
        "events": sim.events_processed,
        "passes": sim.passes,
    }
    print(json.dumps(out, sort_keys=True))
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.service")
    sub = p.add_subparsers(dest="cmd", required=True)

    m = sub.add_parser("master", help="run (or crash-restore) a live master")
    m.add_argument("--journal", required=True)
    m.add_argument("--checkpoint", default=None)
    m.add_argument("--policy", default="hfsp")
    m.add_argument("--machines", type=int, default=4)
    m.add_argument("--map-slots", type=int, default=4)
    m.add_argument("--reduce-slots", type=int, default=2)
    m.add_argument("--heartbeat", type=float, default=3.0)
    m.add_argument("--eps", default=0.0,
                   help="event_epsilon seconds, or 'auto'")
    m.add_argument("--eps-auto-every", type=float, default=0.25,
                   help="wall secs between auto-epsilon retunes (0 = off)")
    m.add_argument("--time-scale", type=float, default=1.0)
    m.add_argument("--host", default="127.0.0.1")
    m.add_argument("--port", type=int, default=0)
    m.add_argument("--port-file", default=None)
    m.add_argument("--worker-dead-wall", type=float, default=0.5)
    m.add_argument("--max-live-jobs", type=int, default=64)
    m.add_argument("--rate-limit", type=float, default=None)
    m.add_argument("--burst", type=int, default=8)
    m.set_defaults(fn=_master)

    w = sub.add_parser("worker", help="run one worker agent")
    w.add_argument("--connect", required=True, metavar="HOST:PORT")
    w.add_argument("--machine", type=int, required=True)
    w.add_argument("--heartbeat-wall", type=float, default=0.05)
    w.set_defaults(fn=_worker)

    r = sub.add_parser("replay", help="deterministic twin over a journal")
    r.add_argument("--journal", required=True)
    r.set_defaults(fn=_replay)

    args = p.parse_args(argv)
    if args.cmd == "master" and args.eps != "auto":
        args.eps = float(args.eps)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
