from repro.runtime.gang import GangRuntime, MLJob

__all__ = ["GangRuntime", "MLJob"]
