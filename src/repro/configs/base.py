"""Model/shape configuration schema.

One :class:`ModelConfig` instance fully describes an architecture; the
model zoo (:mod:`repro.models`) builds init/apply functions from it, the
sharding layer derives PartitionSpecs from it, and ``input_specs`` produces
ShapeDtypeStruct stand-ins for the multi-pod dry-run (no allocation).

The 10 assigned architectures each get a module in :mod:`repro.configs`
exposing ``CONFIG`` (exact published hyper-parameters) and ``SMOKE``
(a reduced same-family config runnable on CPU in a test).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Literal

import jax
import jax.numpy as jnp

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int | None = None          # GQA; None => MHA
    head_dim: int | None = None              # None => d_model // num_heads

    # -- block flavour --------------------------------------------------
    act: Literal["silu_glu", "gelu", "gelu_glu", "relu_sq"] = "silu_glu"
    use_bias: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    non_parametric_norm: bool = False         # olmo: LN without scale/bias
    post_block_norm: bool = False             # gemma2 sandwich norms
    parallel_residual: bool = False           # command-r style
    tie_embeddings: bool = True

    # -- attention --------------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: int | None = None          # local attention window
    local_global_period: int | None = None     # gemma2: every Nth layer global
    attn_softcap: float | None = None          # gemma2 logit softcap
    final_softcap: float | None = None         # gemma2 final-logit softcap
    query_scale: float | None = None           # None => 1/sqrt(head_dim)

    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    shared_expert_d_ff: int = 0                # llama4 shared expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Group-limited routing (GShard-style): tokens are split into
    # ``moe_groups`` groups, each with its own capacity and dispatch
    # buffers.  Set to the data-parallel shard count so dispatch stays
    # LOCAL to each shard — global dispatch makes GSPMD materialize an
    # unsharded (E, C, d) buffer and TB-scale collectives.
    moe_groups: int = 1

    # -- SSM / RWKV ---------------------------------------------------------
    ssm_state: int = 0                         # mamba2 N
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    shared_attn_period: int = 0                # zamba2: shared attn every N
    rwkv_head_dim: int = 64

    # -- enc-dec (whisper) ----------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    num_frames: int = 1500                     # audio frontend stub length
    learned_pos_emb: bool = False

    # -- VLM (llava) -----------------------------------------------------------
    num_patches: int = 0                        # vision frontend stub length

    # -- sharding knobs ---------------------------------------------------------
    # Megatron-style vocab padding: embedding/unembedding use
    # vocab_size + vocab_pad so the vocab dim divides the model axis;
    # padded logits are masked to -inf before the softmax/loss.
    vocab_pad: int = 0
    # Mesh axis name(s) the MoE group dim is constrained to (set by the
    # launcher; None = no constraint, e.g. single-device tests).
    moe_group_axis: tuple | None = None
    # §Perf variant: shard the dispatch-buffer CAPACITY dim over this axis
    # and REPLICATE the (small) expert weights — removes the TP all-reduce
    # on the buffer gradient entirely.  Only sensible when expert weights
    # are small (granite: 40e x 1536 x 512).
    moe_capacity_axis: str | None = None

    # -- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"                     # activation/compute dtype
    param_dtype: str = "float32"                # master weights
    # scan_layers=False unrolls the layer stack as a python loop — used by
    # the dry-run's cost extrapolation (XLA cost_analysis counts while-loop
    # bodies ONCE, so flops are measured on small unrolled depths and
    # linearly extrapolated; see utils/roofline.py).
    scan_layers: bool = True
    # unroll_inner=True additionally unrolls intra-layer loops (attention
    # q-chunks, rwkv/ssd chunk scans) so their flops are fully visible to
    # cost_analysis.  Only the dry-run cost samples set this.
    unroll_inner: bool = False
    # q-chunk size for the memory-bounded attention path (the XLA analogue
    # of the flash kernel's blocking; scores materialize at (b,h,chunk,skv)
    # fp32 instead of (b,h,sq,skv)).
    attn_chunk: int = 1024
    # Chunk length for the rwkv/ssd chunked scans (the deployed TPU kernel
    # block size is 64; the dry-run cost samples may use a coarser chunk to
    # keep unrolled-graph compile times sane — a conservative upper bound
    # on the intra-chunk term).
    inner_chunk: int = 64
    # Per-LAYER activation rematerialisation (jax.checkpoint around each
    # block body): backward stores only layer-boundary activations.
    # Checkpointing the whole loss instead would keep every recomputed
    # intermediate live at once — no memory saving at all.
    remat: bool = False

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return self.vocab_size + self.vocab_pad

    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def head_size(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.shared_attn_period == 0

    @property
    def supports_long_context(self) -> bool:
        """True if serve-time memory/compute is sub-quadratic in context:
        recurrent-state families. Pure full-attention archs skip long_500k
        (DESIGN.md §Arch-applicability)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode_step(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    # -- parameter counting (roofline MODEL_FLOPS = 6·N·D) -----------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        h, kvh, hs = self.num_heads, self.kv_heads, self.head_size
        attn = d * h * hs + 2 * d * kvh * hs + h * hs * d  # q,k,v,o

        def glu(hidden: int) -> int:
            return 3 * d * hidden if self.act.endswith("_glu") else 2 * d * hidden

        if self.family == "moe":
            n_used = self.top_k if active_only else self.num_experts
            ffn = n_used * glu(self.expert_d_ff) + d * self.num_experts
            if self.shared_expert_d_ff:
                ffn += glu(self.shared_expert_d_ff)
        else:
            ffn = glu(dff)

        if self.family == "encdec":
            enc = self.enc_layers * (attn + glu(dff))
            dec = self.dec_layers * (2 * attn + glu(dff))
            return enc + dec + v * d + self.num_frames * d

        if self.family == "ssm":  # rwkv6
            d_in = d
            mix = 4 * d * d_in + d * d_in  # r,k,v,g,o projections (~5 d^2)
            cmix = 2 * d * self.d_ff
            return self.num_layers * (mix + cmix) + v * d

        if self.family == "hybrid":  # zamba2: mamba2 layers + 1 shared block
            d_inner = self.ssm_expand * d
            mamba = d * (2 * d_inner) + d_inner * d + d_inner * (
                2 * self.ssm_state
            )
            shared = attn + glu(dff)
            return self.num_layers * mamba + shared + v * d

        per_layer = attn + ffn
        total = self.num_layers * per_layer + v * d
        if not self.tie_embeddings:
            total += v * d
        return total


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch is exercised on 4 shapes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: 512k context is quadratic; "
            "run only for SSM/hybrid families (DESIGN.md)"
        )
    return True, ""


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, *, per_host_batch: int | None = None
) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run; no
    allocation).  Modality frontends are stubs: ``[vlm]``/``[audio]``
    entries receive precomputed patch/frame embeddings."""
    b = per_host_batch or shape.global_batch
    s = shape.seq_len
    # VLM: patch embeddings occupy the front of the sequence, so the text
    # token budget is seq_len - num_patches (total length stays exact).
    s_text = s - cfg.num_patches if cfg.family == "vlm" else s
    i32 = jnp.int32
    act = cfg.activation_dtype()
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s_text), i32)}
        if shape.kind == "train":
            # VLM loss covers text positions only; labels match text length.
            specs["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), act
            )
        if cfg.family == "encdec":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_frames, cfg.d_model), act
            )
        return specs
    # decode: one new token against a cache of seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "positions": jax.ShapeDtypeStruct((b,), i32),
    }
    return specs


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family variant for CPU smoke tests."""
    small = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.family == "moe":
        small.update(num_experts=4, top_k=min(cfg.top_k, 2), expert_d_ff=64)
        if cfg.shared_expert_d_ff:
            small["shared_expert_d_ff"] = 64
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=8, ssm_head_dim=16, rwkv_head_dim=16)
    if cfg.family == "encdec":
        small.update(enc_layers=2, dec_layers=2, num_frames=8)
    if cfg.family == "vlm":
        small.update(num_patches=4)
    if cfg.sliding_window:
        small["sliding_window"] = 8
    if cfg.shared_attn_period:
        small["shared_attn_period"] = 2
        small["num_layers"] = 4
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
