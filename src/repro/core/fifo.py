"""The default Hadoop scheduler: FIFO with optional priorities (Sect. 2.2).

"Task assignment is accomplished by scanning through all jobs that are
waiting to be scheduled, in order of priority and job submission time."
No preemption; delay scheduling is NOT part of the stock FIFO scheduler
(it greedily prefers local tasks among the chosen job's pending tasks but
never waits)."""

from __future__ import annotations

from repro.core.scheduler import Action, ClusterView, Scheduler, SchedulerConfig, job_sort_key_fifo
from repro.core.types import ClusterSpec, Phase


class FIFOScheduler(Scheduler):
    name = "fifo"

    def __init__(self, cluster: ClusterSpec, config: SchedulerConfig | None = None):
        cfg = config or SchedulerConfig()
        # Stock FIFO greedily picks local tasks but never delays a slot.
        cfg.locality_max_skips = 0
        super().__init__(cluster, cfg)

    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        self._begin_pass()
        actions: list[Action] = []
        for phase in (Phase.MAP, Phase.REDUCE):
            if self.config.paranoid_indexes:
                self._paranoid_check(view, phase)
            free = view.free_slots(phase)
            if not free:
                continue
            for js in sorted(self.live_jobs(phase), key=job_sort_key_fifo):
                if not free:
                    break
                acts, free = self._assign_pending(js, phase, free, len(free), now)
                actions.extend(acts)
        return actions
