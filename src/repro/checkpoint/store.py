"""Checkpoint store: pytree save/restore with async snapshots.

This substrate backs three features:

* **fault tolerance** — train jobs snapshot every N steps; a KILLed or
  failed job restarts from the latest durable checkpoint;
* **EAGER preemption** — suspend = serialize (params, opt, step) to the
  host store ("the swap partition" of DESIGN.md §2); resume = restore —
  possibly on a different gang;
* **elastic rescale** — checkpoints are topology-free (plain host arrays),
  so a job saved on 16 chips resumes on 64.

Format: one ``.npz`` per snapshot holding flattened leaves + a JSON tree
spec.  Async mode snapshots device arrays after jax.device_get on a
background thread, so the train loop only blocks for the D2H copy.
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field

import jax
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], str]:
    leaves, treedef = jax.tree.flatten(tree)
    spec = json.dumps(_treedef_to_json(tree))
    return [np.asarray(l) for l in leaves], spec


def _treedef_to_json(tree):
    if isinstance(tree, dict):
        return {k: _treedef_to_json(v) for k, v in sorted(tree.items())}
    if isinstance(tree, (list, tuple)):
        return [_treedef_to_json(v) for v in tree]
    return None  # leaf


def _unflatten_like(spec, leaves: list):
    it = iter(leaves)

    def build(node):
        if isinstance(node, dict):
            return {k: build(v) for k, v in sorted(node.items())}
        if isinstance(node, list):
            return [build(v) for v in node]
        return next(it)

    return build(spec)


@dataclass
class CheckpointStore:
    directory: str
    keep: int = 3
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _async_threads: list = field(default_factory=list, repr=False)

    def __post_init__(self) -> None:
        os.makedirs(self.directory, exist_ok=True)

    # -- paths ---------------------------------------------------------
    def path(self, tag: str, step: int) -> str:
        return os.path.join(self.directory, f"{tag}-{step:08d}.npz")

    def latest(self, tag: str) -> tuple[int, str] | None:
        best = None
        for f in os.listdir(self.directory):
            if f.startswith(f"{tag}-") and f.endswith(".npz"):
                try:
                    step = int(f[len(tag) + 1 : -4])
                except ValueError:
                    continue
                if best is None or step > best[0]:
                    best = (step, os.path.join(self.directory, f))
        return best

    # -- save / restore ---------------------------------------------------
    def save(self, tag: str, step: int, tree) -> str:
        leaves, spec = _flatten(tree)
        path = self.path(tag, step)
        tmp = path + ".tmp"
        with self._lock:
            np.savez(
                tmp, __spec__=np.frombuffer(spec.encode(), dtype=np.uint8),
                **{f"leaf_{i}": l for i, l in enumerate(leaves)},
            )
            os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
        self._gc(tag)
        return path

    def save_async(self, tag: str, step: int, tree) -> threading.Thread:
        """Device->host copy happens now; serialization on a thread."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        t = threading.Thread(
            target=self.save, args=(tag, step, host_tree), daemon=True
        )
        t.start()
        self._async_threads.append(t)
        return t

    def wait(self) -> None:
        for t in self._async_threads:
            t.join()
        self._async_threads.clear()

    def restore(self, tag: str, step: int | None = None):
        if step is None:
            found = self.latest(tag)
            if found is None:
                return None
            step, path = found
        else:
            path = self.path(tag, step)
            if not os.path.exists(path):
                return None
        with np.load(path) as z:
            spec = json.loads(bytes(z["__spec__"]).decode())
            leaves = [z[f"leaf_{i}"] for i in range(len(z.files) - 1)]
        return step, _unflatten_like(spec, leaves)

    def _gc(self, tag: str) -> None:
        snaps = sorted(
            f for f in os.listdir(self.directory)
            if f.startswith(f"{tag}-") and f.endswith(".npz")
        )
        for f in snaps[: -self.keep]:
            try:
                os.remove(os.path.join(self.directory, f))
            except OSError:
                pass
