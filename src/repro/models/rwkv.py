"""RWKV6 "Finch" blocks (arXiv:2404.05892): attention-free token mixing
with data-dependent per-channel decay.

Per head (key/value dims D):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state update)
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)     (readout with bonus u)

with w_t = exp(-exp(ww_t)) computed from the token (data-dependent decay),
and token-shift interpolation x'_t = lerp(x_t, x_{t-1}, mu) feeding every
projection (r, k, v, g, w).

Three compute paths:
* ``rwkv_scan_ref``      — sequential lax.scan oracle (tests);
* ``rwkv_scan_chunked``  — chunked parallel form (default jnp path; the
  intra-chunk part is O(c^2) matmuls, MXU-friendly);
* ``repro.kernels.rwkv6``— the Pallas TPU kernel of the same chunked form.

Decode keeps ``(S, shift)`` recurrent state — O(1) per token, which is why
rwkv6 runs the ``long_500k`` shape that full-attention archs skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_norm, dense_init


def init_rwkv_block(cfg: ModelConfig, key, *, layers: int | None = None) -> dict:
    d = cfg.d_model
    pref = () if layers is None else (layers,)
    keys = jax.random.split(key, 8)
    heads = d // cfg.rwkv_head_dim
    p = {
        # token-shift mixing coefficients per projection
        "mu": jnp.full((*pref, 5, d), 0.5, dtype=cfg.param_dtype),
        "wr": dense_init(keys[0], (*pref, d, d), d, cfg.param_dtype),
        "wk": dense_init(keys[1], (*pref, d, d), d, cfg.param_dtype),
        "wv": dense_init(keys[2], (*pref, d, d), d, cfg.param_dtype),
        "wg": dense_init(keys[3], (*pref, d, d), d, cfg.param_dtype),
        # data-dependent decay: low-rank ww = tanh(x' A) B + bias
        "wd_a": dense_init(keys[4], (*pref, d, 64), d, cfg.param_dtype),
        "wd_b": dense_init(keys[5], (*pref, 64, d), 64, cfg.param_dtype),
        "wd_bias": jnp.full((*pref, d), -6.0, dtype=cfg.param_dtype),
        "bonus_u": jnp.zeros((*pref, heads, cfg.rwkv_head_dim), dtype=cfg.param_dtype),
        "wo": dense_init(keys[6], (*pref, d, d), d, cfg.param_dtype),
        "ln_x_scale": jnp.ones((*pref, d), dtype=cfg.param_dtype),
    }
    return p


def init_channel_mix(cfg: ModelConfig, key, *, layers: int | None = None) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    pref = () if layers is None else (layers,)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": jnp.full((*pref, 2, d), 0.5, dtype=cfg.param_dtype),
        "wk": dense_init(k1, (*pref, d, dff), d, cfg.param_dtype),
        "wv": dense_init(k2, (*pref, dff, d), dff, cfg.param_dtype),
        "wr": dense_init(k3, (*pref, d, d), d, cfg.param_dtype),
    }


def token_shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """x_{t-1} stream: shift right by one, first slot = carry (b, d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


# ---------------------------------------------------------------------------
# WKV scans
# ---------------------------------------------------------------------------
def rwkv_scan_ref(r, k, v, w, u, state):
    """Sequential oracle.  r,k,w: (b, t, h, dk); v: (b, t, h, dv);
    u: (h, dk); state: (b, h, dk, dv).  Returns (out, final_state)."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (b,h,dk) / (b,h,dv) / decays (b,h,dk)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, o_t

    rs = jnp.moveaxis(r, 1, 0)
    ks = jnp.moveaxis(k, 1, 0)
    vs = jnp.moveaxis(v, 1, 0)
    ws = jnp.moveaxis(w, 1, 0)
    state, out = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return jnp.moveaxis(out, 0, 1), state


def rwkv_scan_chunked(r, k, v, w, u, state, chunk: int = 64, unroll: bool = False):
    """Chunked parallel form (mathematically identical to the ref).

    Within a chunk of length c, with cumulative decays
    A_t = prod_{i<=t} diag(w_i) (A_0 = I pre-token):

      o_t = r_t^T A_t^{pre} S_in + intra-chunk lower-triangular part
      S_out = A_c S_in + sum_t (prod_{i>t} w_i) k_t v_t^T

    The intra-chunk part is two (c x c) matmuls per head — MXU-shaped.
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    if t % chunk:
        pad = chunk - t % chunk
        zero = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zero(r), zero(k), zero(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    else:
        pad = 0
    tc = r.shape[1] // chunk
    shape_c = (b, tc, chunk, h, dk)
    rc = r.reshape(shape_c)
    kc = k.reshape(shape_c)
    vc = v.reshape(b, tc, chunk, h, dv)
    wc = w.reshape(shape_c)

    logw = jnp.log(jnp.maximum(wc.astype(jnp.float32), 1e-38))
    cum = jnp.cumsum(logw, axis=2)                    # A_t incl. token t
    cum_pre = cum - logw                              # A_t pre-token
    total = cum[:, :, -1:, :, :]                      # full-chunk decay

    a_pre = jnp.exp(cum_pre)                          # (b,tc,c,h,dk)
    a_post = jnp.exp(total - cum)                     # decay from t -> chunk end

    def chunk_step(S, inp):
        rcu, kcu, vcu, a_pre_u, a_post_u, tot_u, w_u = inp
        # Inter-chunk: queries read the carried state through their decay.
        r_dec = rcu * a_pre_u.astype(rcu.dtype)                  # (b,c,h,dk)
        o_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, S)
        # Intra-chunk: scores_ij = sum_k r_i a_pre_i / a_pre_j_incl * k_j
        k_dec = kcu * a_post_u.astype(kcu.dtype)                 # k_j decayed to end
        # score between i (query) and j<i (key): prod_{j<l<=i-1?} ... use
        # ratio form: a_pre_i / (a_pre_j * w_j) = decay over (j, i) exclusive.
        inv_k = kcu / jnp.maximum(
            (a_pre_u * w_u).astype(kcu.dtype), 1e-30
        )
        scores = jnp.einsum("bchk,bdhk->bhcd", r_dec, inv_k)
        c = rcu.shape[1]
        tri = jnp.tril(jnp.ones((c, c), dtype=bool), k=-1)       # strictly lower
        scores = jnp.where(tri[None, None], scores, 0.0)
        o_intra = jnp.einsum("bhcd,bdhv->bchv", scores, vcu)
        # Bonus diagonal term: u ⊙ k_t v_t^T read by r_t.
        diag = jnp.einsum("bchk,hk,bchk->bch", rcu, u.astype(rcu.dtype), kcu)
        o_diag = diag[..., None] * vcu
        # State update.
        kv_end = jnp.einsum("bchk,bchv->bhkv", k_dec, vcu)
        S = jnp.exp(tot_u)[:, 0, :, :, None].astype(S.dtype) * S + kv_end
        return S, o_inter + o_intra + o_diag

    xs = (
        jnp.moveaxis(rc, 1, 0),
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(a_pre, 1, 0),
        jnp.moveaxis(a_post, 1, 0),
        jnp.moveaxis(total, 1, 0),
        jnp.moveaxis(wc, 1, 0),
    )
    if unroll:
        # Python loop: keeps per-chunk flops visible to cost_analysis
        # (while-loop bodies are counted once); dry-run cost samples only.
        outs = []
        for i in range(tc):
            state, o_i = chunk_step(state, jax.tree.map(lambda x: x[i], xs))
            outs.append(o_i)
        out = jnp.stack(outs)
    else:
        state, out = jax.lax.scan(chunk_step, state, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(b, tc * chunk, h, dv)
    if pad:
        out = out[:, :t]
    return out, state


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _projections(cfg: ModelConfig, p: dict, x: jnp.ndarray, prev: jnp.ndarray):
    dtype = x.dtype
    shifted = token_shift(x, prev)
    mu = p["mu"].astype(dtype)  # (5, d)
    mix = lambda i: x + mu[i] * (shifted - x)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    b, t, d = x.shape
    h, dk = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(dtype)).reshape(b, t, h, dk)
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(dtype)).reshape(b, t, h, dk)
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(dtype)).reshape(b, t, h, dk)
    g = jnp.einsum("btd,de->bte", xg, p["wg"].astype(dtype))
    ww = (
        jnp.einsum(
            "bte,ef->btf",
            jnp.tanh(jnp.einsum("btd,de->bte", xw, p["wd_a"].astype(dtype))),
            p["wd_b"].astype(dtype),
        )
        + p["wd_bias"].astype(dtype)
    )
    w = jnp.exp(-jnp.exp(ww.astype(jnp.float32))).reshape(b, t, h, dk)
    return r, k, v, g, w


def rwkv_time_mix(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    state: dict,
    *,
    use_ref: bool = False,
    use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """state: {"S": (b,h,dk,dv), "shift": (b,d)}."""
    b, t, d = x.shape
    r, k, v, g, w = _projections(cfg, p, x, state["shift"])
    u = p["bonus_u"].astype(jnp.float32)
    S0 = state["S"]
    if use_pallas:
        from repro.kernels.rwkv6.ops import rwkv6_chunked

        out, S = rwkv6_chunked(r, k, v, w, u, S0, interpret=interpret)
    elif use_ref:
        out, S = rwkv_scan_ref(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, u, S0,
        )
    else:
        out, S = rwkv_scan_chunked(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, u, S0, chunk=cfg.inner_chunk,
            unroll=cfg.unroll_inner,
        )
    out = out.reshape(b, t, d).astype(x.dtype)
    # Per-head group norm then gate.
    out = out.reshape(b, t, d // cfg.rwkv_head_dim, cfg.rwkv_head_dim)
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = ((out - mean) * jax.lax.rsqrt(var + 64e-5)).reshape(b, t, d)
    out = out * p["ln_x_scale"].astype(out.dtype)
    out = out * jax.nn.silu(g)
    y = jnp.einsum("btd,de->bte", out, p["wo"].astype(out.dtype))
    new_state = {"S": S, "shift": x[:, -1, :]}
    return y, new_state


def rwkv_channel_mix(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, shift_prev: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    dtype = x.dtype
    shifted = token_shift(x, shift_prev)
    mu = p["mu"].astype(dtype)
    xk = x + mu[0] * (shifted - x)
    xr = x + mu[1] * (shifted - x)
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["wk"].astype(dtype))))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"].astype(dtype))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"].astype(dtype)))
    return r * kv, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int, *, layers: int) -> dict:
    h = cfg.d_model // cfg.rwkv_head_dim
    dk = cfg.rwkv_head_dim
    return {
        "S": jnp.zeros((layers, batch, h, dk, dk), dtype=jnp.float32),
        "shift": jnp.zeros((layers, batch, cfg.d_model), dtype=cfg.activation_dtype()),
        "cmix_shift": jnp.zeros(
            (layers, batch, cfg.d_model), dtype=cfg.activation_dtype()
        ),
    }
