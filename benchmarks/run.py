"""Benchmark driver: one benchmark per paper table/figure + the
beyond-paper ML-workload and kernel/roofline benches.  Emits CSV blocks.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig7] [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_cluster_size,
    bench_estimation_error,
    bench_kernels,
    bench_locality,
    bench_ml_workload,
    bench_per_job_delta,
    bench_preemption,
    bench_roofline,
    bench_sojourn,
)

BENCHES = {
    "fig3": bench_sojourn.main,
    "fig4": bench_per_job_delta.main,
    "fig5": bench_cluster_size.main,
    "fig6": bench_estimation_error.main,
    "fig7": bench_preemption.main,
    "locality": bench_locality.main,
    "ml": bench_ml_workload.main,
    "kernels": bench_kernels.main,
    "roofline": bench_roofline.main,
}

FAST_SKIP = {"fig5", "fig6", "ml"}  # the long ones


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    names = list(BENCHES)
    if args.only:
        names = [n for n in args.only.split(",") if n in BENCHES]
    elif args.fast:
        names = [n for n in names if n not in FAST_SKIP]

    failed = []
    for name in names:
        print(f"\n==== {name} " + "=" * (60 - len(name)), flush=True)
        t0 = time.time()
        try:
            BENCHES[name]()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
