"""Fig. 3 — ECDFs of sojourn times per job class, FIFO vs FAIR vs HFSP.

Paper claims to validate:
* HFSP ~= FAIR for small jobs, significantly shorter for medium/large;
* FIFO mean sojourn is a multiple (paper: ~5x) of HFSP's.

Thin wrapper over the ``paper-fb`` scenario preset (the Sect. 4
experiment matrix lives in :mod:`repro.scenarios.presets`); this module
only formats the expanded cells' reports as the fig3 CSV blocks.
"""

from __future__ import annotations

from benchmarks.common import CsvOut
from repro.scenarios import get_preset, run_sweep


def main(out=None) -> dict:
    results = run_sweep(get_preset("paper-fb"))

    table = CsvOut("fig3_sojourn", [
        "scheduler", "class", "mean_s", "median_s", "p95_s", "count",
    ])
    q = CsvOut("fig3_ecdf", ["scheduler", "class", "p25_s", "p50_s", "p75_s", "p90_s"])
    means = {}
    for cid, rep in results.items():
        name = cid.split("=", 1)[1]  # scheduler.policy=<name>
        classes = dict(rep["per_class"])
        classes["all"] = rep["sojourn"]
        for cls, s in sorted(classes.items()):
            table.add(name, cls, round(s["mean_s"], 1), round(s["median_s"], 1),
                      round(s["p95_s"], 1), s["count"])
            if cls != "all":
                e = s["ecdf"]
                q.add(name, cls, *[round(e[f"p{p}"], 1) for p in (25, 50, 75, 90)])
        means[name] = rep["mean_sojourn_s"]
    table.emit(out)
    q.emit(out)

    ratio = means["fifo"] / means["hfsp"]
    print(f"# fig3: FIFO/HFSP mean sojourn ratio = {ratio:.2f}x "
          f"(paper: ~5x on their trace); HFSP {means['hfsp']:.0f}s "
          f"FAIR {means['fair']:.0f}s FIFO {means['fifo']:.0f}s")
    return {"means": means, "fifo_over_hfsp": ratio}


if __name__ == "__main__":
    main()
