"""Scheduler framework.

A scheduler is *pure decision logic*: it is driven by events
(`on_job_arrival`, `on_task_complete`, ...) and, when asked, emits a list of
:class:`Action` that an executor applies to the physical cluster.  The same
scheduler object runs unmodified under

* :mod:`repro.core.simulator` — the discrete-event simulator (the paper's
  Mumak analogue), and
* :mod:`repro.runtime`       — the JAX gang-scheduling runtime (the paper's
  Amazon-cluster analogue).

The executor exposes the physical state through the read-only
:class:`ClusterView` protocol; schedulers keep their own per-job bookkeeping
in :class:`~repro.core.types.JobState`.

Every helper here is written to be cheap per scheduling pass: O(free slots
+ live jobs + emitted actions), never O(total tasks) — schedulers run on
every simulator event.

Incremental run-state engine
----------------------------
The base scheduler maintains live indexes of the cluster's RUNNING tasks —
``_slot_of`` (task key -> slot), ``_run_by_job`` ((job, phase) -> attempts)
and ``_run_by_machine`` ((machine, phase) -> attempts) — updated in O(1)
per event instead of being rebuilt from ``view.occupied_slots`` on every
scheduling pass.  Executors MUST report every applied action through the
``on_task_started`` / ``on_task_resumed`` / ``on_task_suspended`` /
``on_task_killed`` hooks (completions already flow through
``on_task_complete``).  Both bundled executors do.  The hooks are a hard
requirement for correctness: the cheap per-pass fallback
(`_maybe_resync_indexes`) only catches drift that changes the running-task
COUNT, so an executor that skips the hooks but happens to keep counts
balanced (e.g. applying a Suspend + Resume pair) runs on stale indexes
undetected.  Validate new executors with
``SchedulerConfig.paranoid_indexes``, which cross-checks content and order
every pass.

Index invariants (checked every pass under
``SchedulerConfig.paranoid_indexes``):

* the indexes contain exactly the RUNNING tasks, keyed consistently with
  the executor's occupied-slot map;
* within one (machine, phase) or (job, phase) bucket, insertion order
  equals the executor's slot-occupancy insertion order — preemption
  victim selection is order-sensitive, so this keeps incremental and
  rebuild-from-scratch schedules bit-identical;
* indexes never change during a pass (the executor applies actions only
  after ``schedule()`` returns), so a pass sees a consistent snapshot.

Demand-indexed scheduling core
------------------------------
On top of the run-state indexes, the base scheduler maintains per-phase
*demand* indexes keyed by what a scheduling pass can actually act on:

* ``_jobs_pending``   — jobs with at least one PENDING task (can take a
  free slot, or preempt on unmet demand);
* ``_jobs_suspended`` — jobs with at least one SUSPENDED task (can
  resume in place);
* ``_jobs_running``   — jobs with at least one RUNNING task (preemption
  victims; also part of the run-state engine above);
* ``_n_live_phase``   — O(1) count of phase-live jobs (the denominator
  of fair-share quotas).

All four are updated in O(1) through the same executor hooks plus the
arrival/completion events, so a pass iterates only jobs with actionable
demand instead of every live job (see ``docs/scheduler_internals.md`` for
the invariants and the update protocol).  REDUCE membership is gated on
the slow-start unlock: a job's REDUCE demand is registered exactly once,
by ``_register_reduce`` (at arrival when already unlocked, else at the
MAP completion that crosses ``reduce_slowstart``).

The demand indexes obey the same contract as the run-state indexes: the
executor MUST call the hooks, membership never changes during a pass,
and ``SchedulerConfig.paranoid_indexes`` rebuilds reference sets from
the live-job table every pass and asserts equality.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Iterable, Protocol

from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    SchedulerStats,
    SlotKey,
    TaskAttempt,
    TaskState,
)


# ---------------------------------------------------------------------------
# Executor-side view & actions
# ---------------------------------------------------------------------------
class ClusterView(Protocol):
    """Read-only physical cluster state, implemented by each executor."""

    spec: ClusterSpec

    def free_slots(self, phase: Phase) -> list[SlotKey]: ...
    def slot_occupant(self, slot: SlotKey) -> TaskAttempt | None: ...
    def occupied_slots(self, phase: Phase) -> dict[SlotKey, TaskAttempt]: ...
    def machine_suspended_count(self, machine: int) -> int: ...
    def machine_suspended_bytes(self, machine: int) -> int: ...
    def total_suspended_bytes(self) -> int: ...


@dataclass
class Action:
    pass


@dataclass
class Start(Action):
    attempt: TaskAttempt
    slot: SlotKey
    local: bool = True


@dataclass
class Resume(Action):
    attempt: TaskAttempt
    slot: SlotKey


@dataclass
class Suspend(Action):
    attempt: TaskAttempt


@dataclass
class Kill(Action):
    attempt: TaskAttempt


# ---------------------------------------------------------------------------
# Base scheduler
# ---------------------------------------------------------------------------
@dataclass
class SchedulerConfig:
    # Delay scheduling (Sect. 3.1 "Data locality"): how many scheduling
    # opportunities a job may skip waiting for a data-local MAP slot.
    locality_max_skips: int = 3
    locality_enabled: bool = True
    # Debug mode: rebuild the run-state indexes from the view on every pass
    # and assert they match the incrementally-maintained ones.  Slow; used
    # by the equivalence tests.
    paranoid_indexes: bool = False
    # Perf/debug switch: when False, scheduling passes fall back to the
    # legacy full walk over every phase-live job (no actionable-demand
    # pre-filter, no position cutoff).  Schedules are bit-identical either
    # way — the demand-index equivalence tests and the sched-overhead
    # benchmark's sparse-demand cell compare the two paths.
    demand_indexed: bool = True


class Scheduler(abc.ABC):
    """Common machinery: job registry, locality-aware slot matching."""

    name = "base"

    def __init__(self, cluster: ClusterSpec, config: SchedulerConfig | None = None):
        self.cluster = cluster
        self.config = config or SchedulerConfig()
        self.jobs: dict[int, JobState] = {}
        self.stats = SchedulerStats()
        self._skip_counts: dict[int, int] = {}
        self._skip_marked: dict[int, int] = {}  # job -> pass seq of last skip
        self._pass_seq = 0
        # Live-job index (jobs with completion_time None), kept incrementally.
        self._live: dict[int, JobState] = {}
        # Tasks already given an action in the *current* pass (the executor
        # has not applied the actions yet, so JobState still shows them as
        # PENDING/SUSPENDED — helpers must not hand them out twice).
        self._claimed: set[tuple] = set()
        # Per-(job, phase) count of claims that targeted PENDING tasks,
        # kept alongside _claimed so _unclaimed_pending is O(1) instead of
        # O(#claimed) per queried job.
        self._claimed_pending: dict[tuple[int, str], int] = {}
        # Per-phase count of claims that targeted RUNNING tasks (preemption
        # victims) — lets the preemptable-pool check stay O(1) per call.
        self._claimed_running: dict[str, int] = {}
        # -- incremental run-state engine (see module docstring) ------------
        # Live views of RUNNING tasks, updated in O(1) by the executor
        # hooks below; read by preemption logic instead of rebuilding from
        # view.occupied_slots() every pass.
        self._slot_of: dict[tuple, SlotKey] = {}
        self._run_by_job: dict[tuple[int, str], dict[tuple, TaskAttempt]] = {}
        self._run_by_machine: dict[tuple[int, str], dict[tuple, TaskAttempt]] = {}
        self._n_running_idx: dict[str, int] = {
            Phase.MAP.value: 0, Phase.REDUCE.value: 0,
        }
        # Jobs with at least one RUNNING task, per phase — lets preemption
        # victim collection iterate O(running jobs) instead of O(live jobs).
        self._jobs_running: dict[str, set[int]] = {
            Phase.MAP.value: set(), Phase.REDUCE.value: set(),
        }
        # -- demand indexes (see module docstring) --------------------------
        # Jobs with >=1 PENDING / >=1 SUSPENDED task per phase, as
        # insertion-ordered dict-sets (deterministic iteration).  REDUCE
        # membership is gated on the slow-start unlock (_register_reduce).
        self._jobs_pending: dict[str, dict[int, None]] = {
            Phase.MAP.value: {}, Phase.REDUCE.value: {},
        }
        self._jobs_suspended: dict[str, dict[int, None]] = {
            Phase.MAP.value: {}, Phase.REDUCE.value: {},
        }
        # O(1) per-phase live-job count (== len(live_jobs(phase))).
        self._n_live_phase: dict[str, int] = {
            Phase.MAP.value: 0, Phase.REDUCE.value: 0,
        }
        # Jobs whose REDUCE phase has been registered with the demand
        # indexes (slow-start crossed) — registration happens exactly once.
        self._reduce_open: set[int] = set()
        # -- attained-service counters (Discipline API) ---------------------
        # Per-(job, phase) *useful* attained service: progress that still
        # counts toward completion, materialized at executor events
        # (complete / suspend / resume / kill).  Running tasks' progress
        # accrues continuously in simulation time but is only folded in
        # when an event materializes it, so the counters are
        # event-constant — the contract that lets rank policies (SRPT
        # remaining, LAS attained; repro.core.disciplines) cache their
        # job order between events.  KILL discards the task's counted
        # progress (the work must be redone).
        self._attained: dict[tuple[int, str], float] = {}
        # Per-task absolute progress already folded into _attained.
        self._svc_counted: dict[tuple, float] = {}
        # Monotone version of the run/demand state: bumped on every
        # index mutation (task started / resumed / suspended / killed /
        # completed, arrivals, REDUCE unlocks, job completion).  Between
        # two passes with equal epochs, the indexes — and therefore any
        # pure function of them — are provably unchanged; the engine's
        # cross-pass actor/feasibility caches key on it (together with
        # the rank epoch; see repro.core.hfsp).
        self._run_epoch = 0

    def _begin_pass(self) -> None:
        self._claimed.clear()
        self._claimed_pending.clear()
        self._claimed_running.clear()
        self._pass_seq += 1

    def _claim(self, att: TaskAttempt) -> None:
        """Mark a task as acted on this pass.  All claims must go through
        here so the per-(job, phase) pending-claim and per-phase
        running-claim counters stay exact."""
        key = att.spec.key
        self._claimed.add(key)
        if att.state is TaskState.PENDING:
            jk = (key[0], key[1])
            self._claimed_pending[jk] = self._claimed_pending.get(jk, 0) + 1
        elif att.state is TaskState.RUNNING:
            self._claimed_running[key[1]] = (
                self._claimed_running.get(key[1], 0) + 1
            )

    # -- events (executor -> scheduler) -------------------------------------
    def on_job_arrival(self, spec: JobSpec, now: float) -> JobState:
        self._run_epoch += 1
        js = JobState(spec=spec)
        self.jobs[spec.job_id] = js
        self._live[spec.job_id] = js
        mv = Phase.MAP.value
        if js.n_unfinished(Phase.MAP):
            self._n_live_phase[mv] += 1
            if js.n_pending(Phase.MAP):
                self._jobs_pending[mv][spec.job_id] = None
        if js.reduce_unlocked():
            self._register_reduce(js)
        return js

    def _register_reduce(self, js: JobState) -> None:
        """Open the job's REDUCE phase for the demand indexes (called once,
        when the slow-start fraction is crossed)."""
        jid = js.spec.job_id
        if jid in self._reduce_open:
            return
        self._run_epoch += 1
        self._reduce_open.add(jid)
        rv = Phase.REDUCE.value
        if js.n_unfinished(Phase.REDUCE):
            self._n_live_phase[rv] += 1
            if js.n_pending(Phase.REDUCE):
                self._jobs_pending[rv][jid] = None
        self._on_reduce_unlocked(js)

    def _on_reduce_unlocked(self, js: JobState) -> None:
        """Subclass hook: the job's REDUCE phase just became schedulable
        (FIFO inserts into its arrival-ordered queue here)."""

    def on_task_complete(self, job_id: int, key: tuple, now: float) -> None:
        self._index_remove(key)
        js = self.jobs.get(job_id)
        if js is None:
            return
        pv = key[1]
        phase = Phase(pv)
        # Attained service: fold in the task's final segment (its full
        # duration minus whatever earlier suspends already counted).
        delta = js.tasks[key].spec.duration - self._svc_counted.pop(key, 0.0)
        jk = (job_id, pv)
        self._attained[jk] = self._attained.get(jk, 0.0) + delta
        if js.n_unfinished(phase) == 0:
            # Phase drained: drop the job from this phase's demand indexes.
            self._n_live_phase[pv] -= 1
            self._jobs_pending[pv].pop(job_id, None)
            self._jobs_suspended[pv].pop(job_id, None)
        if phase is Phase.MAP and js.reduce_unlocked():
            self._register_reduce(js)

    def on_task_progress(
        self, job_id: int, key: tuple, fraction: float, elapsed: float, now: float
    ) -> None:
        pass

    def on_job_complete(self, job_id: int, now: float) -> None:
        self._run_epoch += 1
        self._live.pop(job_id, None)
        # Prune the (empty-by-now) per-job run buckets and demand entries.
        for pv in (Phase.MAP.value, Phase.REDUCE.value):
            self._run_by_job.pop((job_id, pv), None)
            self._jobs_pending[pv].pop(job_id, None)
            self._jobs_suspended[pv].pop(job_id, None)
            self._attained.pop((job_id, pv), None)
        self._reduce_open.discard(job_id)

    def on_tick(self, now: float) -> None:
        """Periodic heartbeat (executors call this every few sim-seconds)."""

    def on_wall_tick(self, wall_now: float, now: float) -> None:
        """Wall-clock tick seam for the live service (repro.service).

        The live master's pacer calls this once per real-time heartbeat
        with both clocks: ``wall_now`` is wall seconds, ``now`` is the
        mapped simulation time the engine has been advanced to.  Offline
        executors never call it.  Default: no-op — disciplines that want
        wall-time-based behaviour (telemetry snapshots, watchdog
        self-checks) override it; everything that affects *scheduling*
        must key off simulation time so the replay twin stays
        deterministic."""

    # -- run-state engine hooks (executor -> scheduler) ----------------------
    # Executors call these right after physically applying each action so
    # the indexes mirror the cluster without per-pass rebuilds.  Each hook
    # also folds the O(1) demand-index update for the state transition it
    # reports (PENDING->RUNNING, SUSPENDED->RUNNING, RUNNING->SUSPENDED,
    # RUNNING->PENDING).
    def on_task_started(self, att: TaskAttempt, slot: SlotKey) -> None:
        self._index_add(att, slot)
        js = self.jobs.get(att.spec.job_id)
        if js is not None and not js.n_pending(att.spec.phase):
            self._jobs_pending[att.spec.phase.value].pop(att.spec.job_id, None)

    def on_task_resumed(self, att: TaskAttempt, slot: SlotKey) -> None:
        self._index_add(att, slot)
        # RESUME may have rolled progress back (DMA swap-in cost): re-sync
        # the counted progress so attained service reflects the rollback.
        self._svc_mark(att)
        js = self.jobs.get(att.spec.job_id)
        if js is not None and not js.n_suspended(att.spec.phase):
            self._jobs_suspended[att.spec.phase.value].pop(
                att.spec.job_id, None
            )

    def on_task_suspended(self, att: TaskAttempt) -> None:
        self._index_remove(att.spec.key)
        self._svc_mark(att)  # progress was just materialized by the executor
        self._jobs_suspended[att.spec.phase.value][att.spec.job_id] = None

    def on_task_killed(self, att: TaskAttempt) -> None:
        self._index_remove(att.spec.key)
        self._svc_mark(att)  # progress reset to 0: discards counted service
        # KILL re-queues the task: the job has pending demand again.
        self._jobs_pending[att.spec.phase.value][att.spec.job_id] = None

    # -- fault hooks (executor -> scheduler; see repro.core.faults) ----------
    def on_task_failed(self, att: TaskAttempt) -> None:
        """The task just transitioned to FAILED (injected failure or
        machine crash).  Its progress has been reset to 0 by the executor;
        it re-enters PENDING later via ``on_task_readmitted``.  A FAILED
        task is *not* actionable demand, so the job may drop out of every
        demand index for the phase while staying phase-live."""
        self._index_remove(att.spec.key)
        self._svc_mark(att)  # progress reset to 0: discards counted service
        js = self.jobs.get(att.spec.job_id)
        if js is not None and not js.n_suspended(att.spec.phase):
            # Covers FAILED-from-SUSPENDED (machine crash while swapped out).
            self._jobs_suspended[att.spec.phase.value].pop(
                att.spec.job_id, None
            )

    def on_task_readmitted(self, att: TaskAttempt) -> None:
        """The task's re-admission backoff expired (FAILED -> PENDING)."""
        self._run_epoch += 1
        self._jobs_pending[att.spec.phase.value][att.spec.job_id] = None

    def on_machine_crashed(self, machine: int) -> None:
        """A machine went down; its tasks fail separately through
        ``on_task_failed``.  Free-slot availability changed."""
        self._run_epoch += 1

    def on_machine_recovered(self, machine: int) -> None:
        self._run_epoch += 1

    def on_sample_lost(self, att: TaskAttempt) -> None:
        """Fault layer: a completed task's size-sample observation was
        dropped before reaching the estimator.  Fired immediately before
        ``on_task_complete`` for the same task; only estimate-driven
        schedulers react (see HFSPScheduler)."""

    def _svc_mark(self, att: TaskAttempt) -> None:
        """Fold the task's materialized ``progress`` into the attained-
        service counter (O(1); exact because executors materialize
        progress before calling the hooks)."""
        key = att.spec.key
        prev = self._svc_counted.get(key, 0.0)
        if att.progress != prev:
            jk = (att.spec.job_id, att.spec.phase.value)
            self._attained[jk] = (
                self._attained.get(jk, 0.0) + att.progress - prev
            )
            self._svc_counted[key] = att.progress

    def attained_service(self, job_id: int, phase: Phase) -> float:
        """Useful attained service of a job's phase (seconds of task
        progress that still count toward completion), as of the last
        executor event.  O(1); the rank-key input for the SRPT and LAS
        disciplines (:mod:`repro.core.disciplines`)."""
        return self._attained.get((job_id, phase.value), 0.0)

    def _index_add(self, att: TaskAttempt, slot: SlotKey) -> None:
        self._run_epoch += 1
        key = att.spec.key
        pv = slot.phase.value
        self._slot_of[key] = slot
        jk = (att.spec.job_id, pv)
        bucket = self._run_by_job.get(jk)
        if bucket is None:
            bucket = self._run_by_job[jk] = {}
        if not bucket:
            self._jobs_running[pv].add(att.spec.job_id)
        bucket[key] = att
        mk = (slot.machine, pv)
        bucket = self._run_by_machine.get(mk)
        if bucket is None:
            bucket = self._run_by_machine[mk] = {}
        bucket[key] = att
        self._n_running_idx[pv] += 1

    def _index_remove(self, key: tuple) -> None:
        self._run_epoch += 1
        slot = self._slot_of.pop(key, None)
        if slot is None:
            return
        pv = slot.phase.value
        bucket = self._run_by_job[(key[0], pv)]
        bucket.pop(key, None)
        if not bucket:
            self._jobs_running[pv].discard(key[0])
        self._run_by_machine[(slot.machine, pv)].pop(key, None)
        self._n_running_idx[pv] -= 1

    def _maybe_resync_indexes(self, view: ClusterView, phase: Phase) -> None:
        """Fallback for executors that do not call the run-state hooks:
        when the indexed running count disagrees with the view, rebuild
        this phase's indexes from scratch (the legacy per-pass path)."""
        occ = view.occupied_slots(phase)
        if self._n_running_idx[phase.value] == len(occ):
            return
        pv = phase.value
        for key in [k for k, s in self._slot_of.items() if s.phase is phase]:
            del self._slot_of[key]
        for mk in [k for k in self._run_by_machine if k[1] == pv]:
            del self._run_by_machine[mk]
        for jk in [k for k in self._run_by_job if k[1] == pv]:
            del self._run_by_job[jk]
        self._n_running_idx[pv] = 0
        self._jobs_running[pv].clear()
        for slot, att in occ.items():
            self._index_add(att, slot)
        self._rebuild_demand_indexes(phase)

    def _demand_reference(
        self, phase: Phase
    ) -> tuple[dict[int, None], dict[int, None], int]:
        """(pending, suspended, phase-live count) recomputed from the
        live-job table — the single definition of phase-liveness, shared
        by the resync fallback and the paranoid cross-check."""
        pend: dict[int, None] = {}
        susp: dict[int, None] = {}
        n_live = 0
        for jid, js in self._live.items():
            if phase is Phase.REDUCE and not js.reduce_unlocked():
                continue
            if not js.n_unfinished(phase):
                continue
            n_live += 1
            if js.n_pending(phase):
                pend[jid] = None
            if js.n_suspended(phase):
                susp[jid] = None
        return pend, susp, n_live

    def _rebuild_demand_indexes(self, phase: Phase) -> None:
        """Recompute this phase's demand indexes from the live-job table
        (the resync fallback for hook-less executors)."""
        pv = phase.value
        pend, susp, n_live = self._demand_reference(phase)
        self._jobs_pending[pv] = pend
        self._jobs_suspended[pv] = susp
        self._n_live_phase[pv] = n_live

    def _paranoid_check(self, view: ClusterView, phase: Phase) -> None:
        """Rebuild reference indexes from the view and assert the
        incremental ones match — content AND per-bucket order (preemption
        victim selection is order-sensitive)."""
        pv = phase.value
        ref_slot_of: dict[tuple, SlotKey] = {}
        ref_by_machine: dict[int, list[tuple]] = {}
        ref_by_job: dict[int, list[tuple]] = {}
        for slot, att in view.occupied_slots(phase).items():
            ref_slot_of[att.spec.key] = slot
            ref_by_machine.setdefault(slot.machine, []).append(att.spec.key)
            ref_by_job.setdefault(att.spec.job_id, []).append(att.spec.key)
        got_slot_of = {k: s for k, s in self._slot_of.items() if s.phase is phase}
        assert got_slot_of == ref_slot_of, (
            f"slot_of mismatch ({phase}): {got_slot_of} != {ref_slot_of}"
        )
        got_by_machine = {
            mk[0]: list(bucket)
            for mk, bucket in self._run_by_machine.items()
            if mk[1] == pv and bucket
        }
        assert got_by_machine == ref_by_machine, (
            f"run_by_machine mismatch ({phase})"
        )
        got_by_job = {
            jk[0]: list(bucket)
            for jk, bucket in self._run_by_job.items()
            if jk[1] == pv and bucket
        }
        assert got_by_job == ref_by_job, f"run_by_job mismatch ({phase})"
        assert self._n_running_idx[pv] == len(ref_slot_of)
        assert self._jobs_running[pv] == set(ref_by_job), (
            f"jobs_running mismatch ({phase})"
        )
        # Demand indexes: membership must equal a rebuild from the live
        # table (order inside the dict-sets is not semantically relevant —
        # every consumer re-sorts — so membership equality is the contract).
        pend_d, susp_d, ref_live = self._demand_reference(phase)
        ref_pend, ref_susp = set(pend_d), set(susp_d)
        assert set(self._jobs_pending[pv]) == ref_pend, (
            f"jobs_pending mismatch ({phase}): "
            f"{set(self._jobs_pending[pv])} != {ref_pend}"
        )
        assert set(self._jobs_suspended[pv]) == ref_susp, (
            f"jobs_suspended mismatch ({phase}): "
            f"{set(self._jobs_suspended[pv])} != {ref_susp}"
        )
        assert self._n_live_phase[pv] == ref_live, (
            f"n_live_phase mismatch ({phase}): "
            f"{self._n_live_phase[pv]} != {ref_live}"
        )

    # -- decisions -----------------------------------------------------------
    @abc.abstractmethod
    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        """Return the actions to apply given current physical state."""

    # -- shared helpers --------------------------------------------------------
    def live_jobs(self, phase: Phase) -> list[JobState]:
        """Phase-live jobs (>=1 unfinished task, REDUCE gated on unlock).

        Served from the demand indexes: the membership union
        pending | suspended | running *is* the phase-live set (every
        unfinished task is in exactly one of those three states), so this
        is O(phase-live) instead of O(all live jobs)."""
        return list(self.demand_union(phase).values())

    def demand_union(self, phase: Phase) -> dict[int, JobState]:
        """{job_id: JobState} of jobs with any demand in ``phase`` —
        pending, suspended, or running tasks.  Deterministic (index
        insertion order; callers needing a specific order re-sort with a
        total key).  This is the one iteration path all three policies
        share; its size is ``n_live_phase(phase)``."""
        jobs = self.jobs
        out: dict[int, JobState] = {}
        for jid in self._jobs_pending[phase.value]:
            out[jid] = jobs[jid]
        for jid in self._jobs_suspended[phase.value]:
            if jid not in out:
                out[jid] = jobs[jid]
        for jid in self._jobs_running[phase.value]:
            if jid not in out:
                out[jid] = jobs[jid]
        return out

    def n_live_phase(self, phase: Phase) -> int:
        """O(1) count of phase-live jobs (== len(live_jobs(phase)))."""
        return self._n_live_phase[phase.value]

    def live_jobs_scan(self, phase: Phase) -> dict[int, JobState]:
        """Phase-live jobs recomputed straight from the live-job table —
        O(all live jobs), no demand indexes involved.  The
        ``demand_indexed=False`` legacy passes derive phase-liveness,
        fair-share denominators, and the training-module probes from this
        scan, keeping them a reference that is free of the PR-4 demand
        and training indexes: a membership bug there diverges the two
        modes and is caught by the equivalence suite (an index-backed
        legacy walk would reproduce the corruption bit for bit).  The
        PR-1 run-state indexes (slot_of / run_by_job / jobs_running and
        the training _active registry) remain shared by both modes —
        those are cross-checked by ``paranoid_indexes`` instead."""
        out: dict[int, JobState] = {}
        for jid, js in self._live.items():
            if phase is Phase.REDUCE and not js.reduce_unlocked():
                continue
            if js.n_unfinished(phase):
                out[jid] = js
        return out

    def _demand(self, js: JobState, phase: Phase) -> int:
        """Slots the job could use *right now* in this phase."""
        return js.n_pending(phase) + js.n_suspended(phase) + js.n_running(phase)

    def _unclaimed_pending(self, js: JobState, phase: Phase) -> int:
        """Pending tasks not yet claimed this pass.  O(1): `_claim` counts
        claims of PENDING tasks per (job, phase) as they happen (task
        states cannot change mid-pass, so the counter is exact)."""
        if not self._claimed_pending:
            return js.n_pending(phase)
        return js.n_pending(phase) - self._claimed_pending.get(
            (js.spec.job_id, phase.value), 0
        )

    # .. locality-aware assignment of pending tasks to free slots ...........
    def _assign_pending(
        self,
        js: JobState,
        phase: Phase,
        free: list[SlotKey],
        budget: int,
        now: float,
        only_keys: Iterable[tuple] | None = None,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Assign up to ``budget`` pending tasks of ``js`` to ``free`` slots.

        MAP tasks use delay scheduling: prefer slots on machines that hold
        the task's input; a job may skip ``locality_max_skips`` scheduling
        opportunities before accepting a non-local slot.  Returns the
        actions plus the still-free slots.  ``only_keys`` restricts the
        candidate tasks (used by the HFSP Training module to dispatch just
        the sample set).
        """
        actions: list[Action] = []
        if budget <= 0 or not free:
            return actions, free
        jid = js.spec.job_id
        restrict: set[tuple] | None = set(only_keys) if only_keys is not None else None

        def eligible(att: TaskAttempt) -> bool:
            k = att.spec.key
            if att.state is not TaskState.PENDING or k in self._claimed:
                return False
            return restrict is None or k in restrict

        if phase is Phase.MAP and self.config.locality_enabled:
            rest_slots: list[SlotKey] = []
            for slot in free:
                if budget <= 0:
                    rest_slots.append(slot)
                    continue
                att = next(
                    (a for a in js.local_pending(slot.machine) if eligible(a)),
                    None,
                )
                if att is not None:
                    self._claim(att)
                    actions.append(Start(att, slot, local=True))
                    js.locality_hits += 1
                    budget -= 1
                    self._skip_counts[jid] = 0
                else:
                    rest_slots.append(slot)
            free = rest_slots
            if budget > 0 and free:
                # Bounded scan: at most ``budget`` tasks can be assigned
                # from either group, so stop once both are full — O(budget)
                # per pass instead of O(pending) for wide jobs.
                no_host: list[TaskAttempt] = []
                remaining: list[TaskAttempt] = []
                for a in js.iter_pending(phase):
                    if not eligible(a):
                        continue
                    if a.spec.input_hosts:
                        if len(remaining) < budget:
                            remaining.append(a)
                    elif len(no_host) < budget:
                        no_host.append(a)
                    if len(remaining) >= budget and len(no_host) >= budget:
                        break
                # Tasks with no locality information cannot benefit from
                # waiting — assign them immediately (ML step quanta, or
                # jobs whose replicas are all dead).
                free = list(free)
                for att in no_host:
                    if budget <= 0 or not free:
                        break
                    slot = free.pop(0)
                    self._claim(att)
                    actions.append(Start(att, slot, local=True))
                    budget -= 1
                if remaining and budget > 0 and free:
                    skips = self._skip_counts.get(jid, 0)
                    if skips < self.config.locality_max_skips:
                        # Delay: skip this opportunity hoping for a local
                        # slot.  Counted at most once per scheduling pass
                        # (the Training module and the job scheduler may
                        # both consider the same job in one pass).
                        if self._skip_marked.get(jid) != self._pass_seq:
                            self._skip_counts[jid] = skips + 1
                            self._skip_marked[jid] = self._pass_seq
                            self.stats.delay_sched_waits += 1
                    else:
                        while remaining and budget > 0 and free:
                            att = remaining.pop(0)
                            slot = free.pop(0)
                            self._claim(att)
                            actions.append(Start(att, slot, local=False))
                            js.locality_misses += 1
                            budget -= 1
                        self._skip_counts[jid] = 0
        else:
            # REDUCE tasks (or locality disabled): any slot will do.
            free = list(free)
            for att in js.iter_pending(phase):
                if budget <= 0 or not free:
                    break
                if not eligible(att):
                    continue
                slot = free.pop(0)
                self._claim(att)
                actions.append(Start(att, slot, local=True))
                budget -= 1
        return actions, free

    def _resume_suspended(
        self,
        js: JobState,
        phase: Phase,
        free: list[SlotKey],
        budget: int,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Resume suspended tasks on their *own* machines (Sect. 3.3 —
        suspended state is materialized locally and must resume in place)."""
        actions: list[Action] = []
        if budget <= 0:
            return actions, free
        free_by_machine: dict[int, list[SlotKey]] = {}
        for s in free:
            free_by_machine.setdefault(s.machine, []).append(s)
        for att in js.suspended(phase):
            if budget <= 0:
                break
            if att.spec.key in self._claimed:
                continue
            slots = free_by_machine.get(att.machine if att.machine is not None else -1)
            if slots:
                slot = slots.pop(0)
                self._claim(att)
                actions.append(Resume(att, slot))
                budget -= 1
        used = {a.slot for a in actions if isinstance(a, Resume)}
        return actions, [s for s in free if s not in used]


def job_sort_key_fifo(js: JobState) -> tuple:
    return (-js.spec.weight, js.spec.arrival_time, js.spec.job_id)


class LazySet:
    """Set-like view materialized on first membership test.

    Used for pass-scoped sets that are expensive to build but rarely
    consulted (e.g. the preemption-protected sample keys: only preemption
    walks read them, and most passes never preempt).  The factory runs at
    most once; until then the set costs nothing."""

    __slots__ = ("_factory", "_set")

    def __init__(self, factory):
        self._factory = factory
        self._set: set | None = None

    def materialize(self) -> set:
        if self._set is None:
            self._set = self._factory()
        return self._set

    def __contains__(self, key) -> bool:
        return key in self.materialize()

    def __len__(self) -> int:
        return len(self.materialize())
