"""Public model API: one entry point per model operation, dispatched on
``cfg.family``.  Everything downstream (train/serve/launch/runtime) goes
through these four functions.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as E
from repro.models import transformer as T
from repro.models.common import cross_entropy


def init_model(cfg: ModelConfig, key) -> dict:
    if cfg.family == "encdec":
        return E.init_encdec(cfg, key)
    return T.init_lm(cfg, key)


def forward(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    use_flash: bool = False,
    interpret: bool = False,
    unembed_last_only: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Train / prefill forward.  Returns (logits, aux_loss)."""
    if cfg.family == "encdec":
        return E.encdec_forward(
            cfg, params, batch, unembed_last_only=unembed_last_only
        )
    return T.lm_forward(
        cfg, params, batch, use_flash=use_flash, interpret=interpret,
        unembed_last_only=unembed_last_only,
    )


def loss_fn(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    use_flash: bool = False,
    interpret: bool = False,
    aux_weight: float = 0.01,
) -> tuple[jnp.ndarray, dict]:
    logits, aux = forward(
        cfg, params, batch, use_flash=use_flash, interpret=interpret
    )
    labels = batch["labels"]
    # VLM: logits cover [patches, text]; loss only on the text positions.
    if cfg.family == "vlm":
        logits = logits[:, cfg.num_patches :]
    ce = cross_entropy(logits, labels)
    total = ce + aux_weight * aux
    return total, {"loss": total, "ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    if cfg.family == "encdec":
        return E.init_encdec_cache(cfg, batch, max_seq)
    return T.init_lm_cache(cfg, batch, max_seq)


def decode_step(
    cfg: ModelConfig,
    params: dict,
    tokens: jnp.ndarray,
    positions: jnp.ndarray,
    cache: dict,
) -> tuple[jnp.ndarray, dict]:
    if cfg.family == "encdec":
        return E.encdec_decode(cfg, params, tokens, positions, cache)
    return T.lm_decode(cfg, params, tokens, positions, cache)
