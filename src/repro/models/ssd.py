"""Mamba2 / SSD (state-space dual) blocks, used by the zamba2 hybrid.

Per head h (head dim P, state dim N), with scalar decay per head/step:

    a_t = exp(-exp(A_log_h) * dt_t)                  (data-dependent, scalar)
    h_t = a_t * h_{t-1} + dt_t * (x_t ⊗ B_t)         (h: P x N)
    y_t = h_t C_t + D_h * x_t

dt_t = softplus(dt_proj(u) + dt_bias); B, C are shared across heads
(multi-value attention analogy).  A short causal conv (window 4) precedes
the SSM — its tail is the decode-time "conv state".

Compute paths mirror rwkv.py: sequential ref scan, chunked-parallel jnp
(default), and the Pallas ``repro.kernels.ssd`` kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

CONV_K = 4


def init_ssd_block(cfg: ModelConfig, key, *, layers: int | None = None) -> dict:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    p_dim = cfg.ssm_head_dim
    heads = d_inner // p_dim
    pref = () if layers is None else (layers,)
    keys = jax.random.split(key, 8)
    # Projections are kept per-component (z, x, B, C, dt) rather than fused:
    # the depthwise conv is per-channel, so splitting is mathematically
    # identical to the fused form, and each output dim gets a clean
    # tensor-parallel sharding (no mid-shard split offsets).
    return {
        "wz": dense_init(keys[0], (*pref, d, d_inner), d, cfg.param_dtype),
        "wx": dense_init(keys[3], (*pref, d, d_inner), d, cfg.param_dtype),
        "wB": dense_init(keys[4], (*pref, d, n), d, cfg.param_dtype),
        "wC": dense_init(keys[5], (*pref, d, n), d, cfg.param_dtype),
        "wdt": dense_init(keys[6], (*pref, d, heads), d, cfg.param_dtype),
        # Per-component depthwise convs (x sharded over model; B/C small,
        # replicated) — equivalent to the fused conv, sharding-clean.
        "conv_x_w": dense_init(keys[1], (*pref, CONV_K, d_inner), CONV_K, cfg.param_dtype),
        "conv_x_b": jnp.zeros((*pref, d_inner), dtype=cfg.param_dtype),
        "conv_B_w": dense_init(keys[2], (*pref, CONV_K, n), CONV_K, cfg.param_dtype),
        "conv_B_b": jnp.zeros((*pref, n), dtype=cfg.param_dtype),
        "conv_C_w": dense_init(keys[7], (*pref, CONV_K, n), CONV_K, cfg.param_dtype),
        "conv_C_b": jnp.zeros((*pref, n), dtype=cfg.param_dtype),
        "A_log": jnp.zeros((*pref, heads), dtype=cfg.param_dtype),
        "D": jnp.ones((*pref, heads), dtype=cfg.param_dtype),
        "dt_bias": jnp.zeros((*pref, heads), dtype=cfg.param_dtype),
        "w_out": dense_init(keys[2], (*pref, d_inner, d), d_inner, cfg.param_dtype),
        "norm_scale": jnp.ones((*pref, d_inner), dtype=cfg.param_dtype),
    }


def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state: jnp.ndarray):
    """Depthwise causal conv, window CONV_K.  x: (b, t, c); state: (b, K-1, c)
    carries the previous K-1 inputs.  Returns (y, new_state)."""
    full = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    t = x.shape[1]
    y = jnp.zeros_like(x)
    for i in range(CONV_K):
        y = y + full[:, i : i + t, :] * w[i].astype(x.dtype)
    y = jax.nn.silu(y + b.astype(x.dtype))
    return y, full[:, -(CONV_K - 1):, :]


# ---------------------------------------------------------------------------
# SSD scans
# ---------------------------------------------------------------------------
def ssd_scan_ref(xh, dt, a, B, C, state):
    """Sequential oracle.  xh: (b,t,h,p); dt,a: (b,t,h); B,C: (b,t,n);
    state: (b,h,p,n).  Returns (y, final_state)."""

    def step(S, inp):
        x_t, dt_t, a_t, b_t, c_t = inp
        dBx = jnp.einsum("bhp,bn,bh->bhpn", x_t, b_t, dt_t)
        S = a_t[..., None, None] * S + dBx
        y_t = jnp.einsum("bhpn,bn->bhp", S, c_t)
        return S, y_t

    xs = tuple(jnp.moveaxis(v, 1, 0) for v in (xh, dt, a, B, C))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def ssd_scan_chunked(xh, dt, a, B, C, state, chunk: int = 64, unroll: bool = False):
    """Chunked parallel SSD form (identical math to the ref).

    With per-(token,head) scalar decays a_t and L_t = prod_{i<=t} a_i:

      intra: y_t += sum_{j<=t} (L_t / L_j) dt_j (C_t · B_j) x_j
      inter: y_t += L_t^{pre} * (S_in C_t)
      state: S_out = L_c S_in + sum_j (L_c / L_j) dt_j x_j B_j^T
    """
    b, t, h, p = xh.shape
    n = B.shape[-1]
    if t % chunk:
        pad = chunk - t % chunk
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    else:
        pad = 0
    tc = xh.shape[1] // chunk
    xc = xh.reshape(b, tc, chunk, h, p)
    dtc = dt.reshape(b, tc, chunk, h)
    ac = a.reshape(b, tc, chunk, h)
    Bc = B.reshape(b, tc, chunk, n)
    Cc = C.reshape(b, tc, chunk, n)

    loga = jnp.log(jnp.maximum(ac.astype(jnp.float32), 1e-38))
    cum = jnp.cumsum(loga, axis=2)          # L_t (inclusive)
    total = cum[:, :, -1, :]                # (b, tc, h)

    def chunk_step(S, inp):
        # Derivation (S_in = carried state, L_t = exp(cum_t) inclusive):
        #   S_t = L_t S_in + sum_{j<=t} (L_t/L_j) dt_j x_j B_j^T
        #   y_t = S_t C_t
        #       = L_t (S_in C_t)                                   [inter]
        #       + sum_{j<=t} (L_t/L_j) dt_j (B_j . C_t) x_j        [intra]
        #   S_out = L_c S_in + sum_j (L_c/L_j) dt_j x_j B_j^T
        xcu, dtu, Bu, Cu, cumu, totu = inp
        c = xcu.shape[1]
        ratio = jnp.exp(cumu[:, :, None, :] - cumu[:, None, :, :])  # (b,t,j,h)
        tri = jnp.tril(jnp.ones((c, c), dtype=bool))                # j <= t
        ratio = jnp.where(tri[None, :, :, None], ratio, 0.0)
        cb = jnp.einsum(
            "bin,bjn->bij", Cu.astype(jnp.float32), Bu.astype(jnp.float32)
        )
        scores = ratio * cb[..., None] * dtu[:, None, :, :]         # (b,t,j,h)
        y_intra = jnp.einsum("btjh,bjhp->bthp", scores, xcu.astype(jnp.float32))
        y_inter = jnp.einsum(
            "bhpn,bcn,bch->bchp", S, Cu.astype(jnp.float32), jnp.exp(cumu)
        )
        S_new = jnp.exp(totu)[:, :, None, None] * S + jnp.einsum(
            "bch,bchp,bcn->bhpn",
            dtu * jnp.exp(totu[:, None, :] - cumu),
            xcu.astype(jnp.float32),
            Bu.astype(jnp.float32),
        )
        return S_new, y_intra + y_inter

    xs = tuple(
        jnp.moveaxis(v, 1, 0) for v in (xc, dtc, Bc, Cc, cum, total)
    )
    if unroll:
        # Python loop: keeps per-chunk flops visible to cost_analysis.
        youts = []
        for i in range(tc):
            state, y_i = chunk_step(state, jax.tree.map(lambda x: x[i], xs))
            youts.append(y_i)
        ys = jnp.stack(youts)
    else:
        state, ys = jax.lax.scan(chunk_step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, tc * chunk, h, p)
    if pad:
        y = y[:, :t]
    return y, state


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def ssd_block(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    state: dict,
    *,
    use_ref: bool = False,
    use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """state: {"ssm": (b,h,p,n) fp32, "conv": (b, K-1, d_inner+2n)}."""
    b, t, d = x.shape
    d_inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    p_dim = cfg.ssm_head_dim
    heads = d_inner // p_dim
    dtype = x.dtype

    z = jnp.einsum("btd,de->bte", x, p["wz"].astype(dtype))
    x_p = jnp.einsum("btd,de->bte", x, p["wx"].astype(dtype))
    B_p = jnp.einsum("btd,dn->btn", x, p["wB"].astype(dtype))
    C_p = jnp.einsum("btd,dn->btn", x, p["wC"].astype(dtype))
    dt_raw = jnp.einsum("btd,dh->bth", x, p["wdt"].astype(dtype))
    x_in, cs_x = causal_conv(x_p, p["conv_x_w"], p["conv_x_b"], state["conv_x"])
    B, cs_B = causal_conv(B_p, p["conv_B_w"], p["conv_B_b"], state["conv_B"])
    C, cs_C = causal_conv(C_p, p["conv_C_w"], p["conv_C_b"], state["conv_C"])
    conv_state = {"conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C}
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (b, t, h)
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32))[None, None, :] * dt)
    xh = x_in.reshape(b, t, heads, p_dim)

    if use_pallas:
        from repro.kernels.ssd.ops import ssd_chunked

        y, S = ssd_chunked(xh, dt, a, B, C, state["ssm"], interpret=interpret)
    elif use_ref:
        y, S = ssd_scan_ref(
            xh.astype(jnp.float32), dt, a,
            B.astype(jnp.float32), C.astype(jnp.float32), state["ssm"],
        )
    else:
        y, S = ssd_scan_chunked(
            xh.astype(jnp.float32), dt, a,
            B.astype(jnp.float32), C.astype(jnp.float32), state["ssm"],
            chunk=cfg.inner_chunk, unroll=cfg.unroll_inner,
        )
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, d_inner).astype(dtype)
    # Gated RMSNorm (mamba2's norm-before-out-proj).
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(dtype)
    y = y * p["norm_scale"].astype(dtype)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"].astype(dtype))
    return out, {"ssm": S, **conv_state}


def init_ssd_state(cfg: ModelConfig, batch: int, *, layers: int) -> dict:
    d_inner = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    heads = d_inner // cfg.ssm_head_dim
    act = cfg.activation_dtype()
    return {
        "ssm": jnp.zeros(
            (layers, batch, heads, cfg.ssm_head_dim, n), dtype=jnp.float32
        ),
        "conv_x": jnp.zeros((layers, batch, CONV_K - 1, d_inner), dtype=act),
        "conv_B": jnp.zeros((layers, batch, CONV_K - 1, n), dtype=act),
        "conv_C": jnp.zeros((layers, batch, CONV_K - 1, n), dtype=act),
    }
