"""Serving example: batched greedy decode with a KV cache + the
continuous-batching queue, on a reduced assigned architecture.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import init_cache, init_model
from repro.serve import BatchingQueue, greedy_generate, make_decode_step


def batch_generate() -> None:
    print("=== batched greedy generation (gemma2 smoke config) ===")
    cfg = get_smoke("gemma2_2b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[5, 9, 2], [7, 7, 7]], jnp.int32)
    out = greedy_generate(cfg, params, prompt, max_new_tokens=6)
    for row in out.tolist():
        print("  tokens:", row)


def continuous_batching() -> None:
    print("=== continuous batching queue (slot-based) ===")
    cfg = get_smoke("olmo_1b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_decode_step(cfg))
    slots, max_seq = 2, 16
    q = BatchingQueue(cfg, batch_slots=slots, max_seq=max_seq)
    for i in range(4):
        q.submit({"id": i, "prompt": [1 + i, 2 + i], "max_new_tokens": 3})

    cache = init_cache(cfg, slots, max_seq)
    tokens = jnp.zeros((slots, 1), jnp.int32)
    positions = jnp.zeros((slots,), jnp.int32)
    slot_req = {}
    while not q.idle or q.active:
        for slot, req in q.admit():
            slot_req[slot] = req
        if not q.active:
            break
        # Build the per-slot token/position vectors.
        tok_list, pos_list = [], []
        for s in range(slots):
            req = q.active.get(s)
            if req is None:
                tok_list.append(0)
                pos_list.append(0)
            else:
                p = req["pos"]
                tok_list.append(
                    req["prompt"][p] if p < len(req["prompt"])
                    else req["generated"][-1]
                )
                pos_list.append(p)
        tokens = jnp.asarray(tok_list, jnp.int32)[:, None]
        positions = jnp.asarray(pos_list, jnp.int32)
        logits, cache = step(params, tokens, positions, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        for s in list(q.active):
            q.step_done(s, int(nxt[s]))
    print(f"  served {len(q.finished)} requests:")
    for req in q.finished:
        print(f"   req {req['id']}: prompt {req['prompt']} -> {req['generated']}")


if __name__ == "__main__":
    batch_generate()
    continuous_batching()
