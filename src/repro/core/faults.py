"""Deterministic fault injection for the scheduling engine.

The simulator drives four fault kinds as first-class events, every one of
them drawn from a seeded :class:`FaultModel`:

* **machine crash / recover** — a machine goes down for an
  exponentially-distributed outage; every task running or suspended on it
  fails and re-enters the pending demand through the executor hooks;
* **single-task failure** — an attempt dies partway through its work
  (the failure point is itself a draw), modeling JVM / container deaths;
* **transient slowdown (straggler)** — an attempt runs at
  ``1/straggler_factor`` of nominal speed, triggering speculative
  re-execution with first-finisher-wins kill of the loser;
* **estimation-sample loss** — a completed sample task's duration
  observation is dropped before the TrainingModule sees it, so the size
  estimate must be re-fit from the remaining samples (the
  lost-information regime of "Revisiting Size-Based Scheduling").

Determinism contract (see ``docs/faults.md``): every random decision uses
a *key-derived* RNG — ``np.random.default_rng((seed, stream, *key))`` —
never a shared sequential stream.  A decision's draw depends only on its
identity (machine id and outage ordinal; task key and attempt number),
not on the global order decisions happen to be made in.  That makes the
full failure trace bit-reproducible across reruns, across the
numpy/jax/auto vcluster backends, and across ``event_epsilon`` settings
(coalescing reorders *scheduling passes*, never the event mutations the
draws hang off).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.types import TaskAttempt

# RNG stream tags (the second element of every derivation key).  Distinct
# per decision family so streams never collide.
_STREAM_CRASH = 11     # (seed, 11, machine, ordinal) -> outage/uptime draws
_STREAM_FATE = 12      # (seed, 12, job, phase, index, attempt) -> fail/straggle
_STREAM_SAMPLE = 13    # (seed, 13, job, phase, index, attempt) -> sample loss

_PHASE_IDX = {"map": 0, "reduce": 1}


@dataclass(frozen=True)
class FaultModel:
    """Seeded description of the fault regime.  All rates default to 0:
    a default-constructed model is inert (``enabled`` is False) and the
    simulator skips the fault layer entirely."""

    seed: int = 0
    # Machine churn: mean time between failures / to recovery, per
    # machine, in sim-seconds.  mtbf <= 0 disables crashes.
    machine_mtbf: float = 0.0
    machine_mttr: float = 60.0
    # Probability that any given task attempt dies partway through.
    task_fail_rate: float = 0.0
    # Injected-failure retry budget per task.  Crash-induced failures do
    # NOT consume the budget (the task did nothing wrong), so liveness is
    # guaranteed: every task is eventually retried to completion.
    max_task_retries: int = 5
    # Capped exponential re-admission backoff after a failure (seconds).
    backoff_base: float = 1.0
    backoff_cap: float = 60.0
    # Probability an attempt straggles, and how slow it then runs
    # (execution rate = 1 / straggler_factor).
    straggler_prob: float = 0.0
    straggler_factor: float = 3.0
    # Probability a completed sample task's duration observation is lost
    # before the TrainingModule records it.
    sample_loss_rate: float = 0.0
    # Blacklisting: a machine accumulating this many injected task
    # failures without an intervening success is taken out of the free
    # pool for ``probation`` seconds (strikes carry over: one more
    # failure right after probation re-blacklists it).
    blacklist_threshold: int = 3
    probation_s: float = 120.0
    # Speculative re-execution of straggling attempts (first finisher
    # wins; the loser is killed and its work counted as lost).
    speculation: bool = True
    # A speculative copy is only worth launching if the straggler still
    # has at least this much nominal work left (seconds).
    speculation_min_remaining: float = 1.0
    # Externally-driven faults: keep the injector armed even with every
    # stochastic rate at zero, so scripted crash/recover events
    # (Simulator.inject_fault — the live service maps worker death and
    # rejoin onto these) route through the same failure/readmission
    # machinery.  No events are *drawn*: an external-only model seeds no
    # outages and injects no task failures.
    external: bool = False

    @property
    def enabled(self) -> bool:
        return (
            self.machine_mtbf > 0.0
            or self.task_fail_rate > 0.0
            or self.straggler_prob > 0.0
            or self.sample_loss_rate > 0.0
            or self.external
        )


class FaultInjector:
    """Draws fault decisions from a :class:`FaultModel` and keeps the
    deterministic failure trace plus blacklist strike counts.

    The injector is pure bookkeeping — the simulator owns all mutation
    (failing tasks, taking machines down, scheduling re-admissions)."""

    def __init__(self, model: FaultModel, num_machines: int):
        self.model = model
        self.num_machines = num_machines
        self._strikes: dict[int, int] = {}
        # Per-machine ordinal of the next crash-stream draw.
        self._crash_draws: dict[int, int] = {}
        # Deterministic event trace: (time, kind, detail) tuples in
        # injection order.  Compared verbatim by the conformance goldens.
        self.trace: list[tuple] = []
        self.stats = {
            "machine_crashes": 0,
            "machine_recoveries": 0,
            "task_failures": 0,
            "crash_task_failures": 0,
            "stragglers": 0,
            "sample_losses": 0,
            "retries": 0,
            "retries_exhausted": 0,
            "blacklists": 0,
            "probations_ended": 0,
            "speculative_launches": 0,
            "speculative_wins": 0,
            "speculative_losses": 0,
            "work_lost_s": 0.0,
        }

    # -- key-derived draws ---------------------------------------------------
    def _rng(self, stream: int, *key: int) -> np.random.Generator:
        return np.random.default_rng((self.model.seed, stream, *key))

    def next_outage_delay(self, machine: int) -> float:
        """Uptime until this machine's next crash (exponential, mean
        mtbf).  Ordinal-keyed: the k-th draw for machine m is the same
        regardless of what any other machine did."""
        k = self._crash_draws.get(machine, 0)
        self._crash_draws[machine] = k + 1
        rng = self._rng(_STREAM_CRASH, machine, k)
        return float(rng.exponential(self.model.machine_mtbf))

    def next_recover_delay(self, machine: int) -> float:
        k = self._crash_draws.get(machine, 0)
        self._crash_draws[machine] = k + 1
        rng = self._rng(_STREAM_CRASH, machine, k)
        return float(rng.exponential(self.model.machine_mttr))

    def attempt_fate(self, att: TaskAttempt) -> tuple[float | None, float]:
        """Fate of one (re)started attempt: ``(fail_fraction, rate)``.

        ``fail_fraction`` is the fraction of the attempt's remaining work
        at which it dies (None = survives); ``rate`` is the execution
        speed (1.0 nominal, ``1/straggler_factor`` if straggling).  All
        three underlying uniforms are drawn unconditionally so a fate is
        a pure function of (task identity, attempt ordinal)."""
        s = att.spec
        rng = self._rng(
            _STREAM_FATE, s.job_id, _PHASE_IDX[s.phase.value], s.index,
            att.attempts,
        )
        u_fail = float(rng.random())
        frac = float(rng.random())
        u_strag = float(rng.random())
        m = self.model
        fail_at = None
        if m.task_fail_rate > 0.0 and u_fail < m.task_fail_rate:
            # Die somewhere strictly inside the attempt.
            fail_at = min(max(frac, 1e-6), 1.0 - 1e-6)
        rate = 1.0
        if m.straggler_prob > 0.0 and u_strag < m.straggler_prob:
            rate = 1.0 / max(1.0, m.straggler_factor)
        return fail_at, rate

    def sample_lost(self, att: TaskAttempt) -> bool:
        """Whether this completed attempt's size-sample observation is
        dropped before reaching the TrainingModule."""
        m = self.model
        if m.sample_loss_rate <= 0.0:
            return False
        s = att.spec
        rng = self._rng(
            _STREAM_SAMPLE, s.job_id, _PHASE_IDX[s.phase.value], s.index,
            att.attempts,
        )
        return bool(rng.random() < m.sample_loss_rate)

    # -- retry / backoff -----------------------------------------------------
    def backoff(self, failures: int) -> float:
        """Re-admission delay after the task's ``failures``-th failure:
        capped exponential, ``min(base * 2^(failures-1), cap)``."""
        m = self.model
        return min(m.backoff_base * (2.0 ** max(0, failures - 1)), m.backoff_cap)

    # -- blacklist strikes ---------------------------------------------------
    def note_injected_failure(self, machine: int) -> bool:
        """Record an injected task failure on ``machine``; True when the
        strike count just reached the blacklist threshold."""
        n = self._strikes.get(machine, 0) + 1
        self._strikes[machine] = n
        return n == self.model.blacklist_threshold

    def note_success(self, machine: int) -> None:
        """A task completed cleanly on ``machine``: reset its strikes."""
        if self._strikes.get(machine):
            self._strikes[machine] = 0

    def end_probation(self, machine: int) -> None:
        """Probation served: the machine rejoins the pool one strike shy
        of the threshold — a single further failure re-blacklists it."""
        self._strikes[machine] = self.model.blacklist_threshold - 1

    # -- trace / reporting ---------------------------------------------------
    def record(self, time: float, kind: str, *detail) -> None:
        self.trace.append((round(time, 9), kind, *detail))

    def stats_dict(self) -> dict:
        out = dict(self.stats)
        out["trace_len"] = len(self.trace)
        return out


class FirstFinisherWins:
    """Tiny arbiter for racing redundant executions of the same work.

    Contenders call :meth:`finish` when done; the first caller for a key
    wins (True), every later caller is the loser (False) and should
    discard its work.  Shared by the simulator's speculative task
    re-execution, the gang runtime's spare-gang speculation, and the
    sweep runner's straggler re-issue."""

    def __init__(self):
        self._winner: dict = {}

    def finish(self, key, contender) -> bool:
        if key in self._winner:
            return False
        self._winner[key] = contender
        return True

    def winner(self, key):
        return self._winner.get(key)

    def decided(self, key) -> bool:
        return key in self._winner

    def reset(self, key) -> None:
        self._winner.pop(key, None)
