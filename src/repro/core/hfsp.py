"""The Hadoop Fair Sojourn Protocol scheduler (Sect. 3).

HFSP is a *hierarchical* scheduler (Sect. 3.1.1):

* the **top-level scheduler** balances slots between the Training module
  (job size estimation, Sect. 3.2) and the job scheduler;
* the **job scheduler** ranks jobs by their projected finish time under a
  simulated max-min-fair processor-sharing discipline (the *virtual
  cluster*, Sect. 3.1) and focuses real cluster resources on the jobs that
  would finish first, preempting jobs that would finish later;
* **preemption** (Sect. 3.3) is EAGER (suspend/resume), WAIT (drain) or
  KILL, with a hysteresis fallback EAGER->WAIT when too much task state is
  suspended ("Finite machine resources").

Interaction rules between delay scheduling and preemption (these matter —
naive composition causes suspend/resume thrash):

* a job that *voluntarily declined* free slots this pass (delay
  scheduling, hoping for data locality) must NOT preempt other jobs in the
  same pass — preemption is for jobs that genuinely cannot be served;
* slots freed *by* preemption are assigned immediately, bypassing the
  delay-scheduling wait (locality was already forfeited by deciding to
  preempt).

The scheduler is pure decision logic: it runs unmodified under the
discrete-event simulator (:mod:`repro.core.simulator`, the paper's Mumak
analogue) and under the JAX gang runtime (:mod:`repro.runtime`).

Performance notes (incremental scheduler-state engine)
------------------------------------------------------
The scheduler runs a full pass on every executor event, so per-pass cost is
the practicality bottleneck (Sect. 4's "negligible overhead" claim).
Profiling the 100-job FB trace on the pre-incremental code showed the
per-pass ``ensure_indices`` rebuild of the running-task indexes consuming
8.6 s of a 16.4 s simulation (53%): 66,891 full rebuilds, ~13 M
``dict.setdefault`` + ``list.append`` calls and ~2 M list sorts, all to
recreate state that changes by only a handful of tasks per event.

This module now reads the base scheduler's *incremental* run-state indexes
(``Scheduler._slot_of`` / ``_run_by_job`` / ``_run_by_machine``, updated in
O(1) by executor hooks — see :mod:`repro.core.scheduler`), making a pass
O(changed-tasks + actions) instead of O(running-tasks).  Together with lazy
virtual-cluster aging (:mod:`repro.core.vcluster`) and the machine-grouped
suspended index (:class:`repro.core.types.JobState`), the same trace runs
>=3x faster end-to-end with a bit-identical schedule.

Invariants the fast paths rely on (all cross-checked every pass under
``SchedulerConfig.paranoid_indexes``):

* the run indexes mirror exactly the executor's occupied slots, including
  per-bucket insertion order (preemption victim selection is
  order-sensitive);
* indexes never change *during* a pass — the executor applies actions only
  after ``schedule()`` returns, so claim filtering (``_claimed``) is the
  only intra-pass state;
* the job loop visits jobs in ascending projected-finish position and
  claims only grow, so victim eligibility shrinks monotonically within a
  pass — an empty victim walk on a machine stays empty (``victim_dead``),
  and per-machine victim lists can be memoized pass-wide (``victim_memo``);
* a machine with neither a free slot nor a running later-ordered task is a
  provable no-op for the suspended-task resume path, so whole machines are
  skipped via the per-(job, phase, machine) suspended index.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Callable

from repro.core.disciplines import (
    AgingPolicy,
    PreemptionPolicy,
    RankPolicy,
    VirtualClusterAging,
    VirtualFinishRank,
    WallClockAging,
)
from repro.core.estimator import (
    FirstOrderEstimator,
    TaskTimeEstimator,
    TrainingModule,
)
from repro.core.scheduler import (
    Action,
    ClusterView,
    Kill,
    LazySet,
    Resume,
    Scheduler,
    SchedulerConfig,
    Suspend,
)
from repro.core.types import (
    ClusterSpec,
    JobSpec,
    JobState,
    Phase,
    Preemption,
    SlotKey,
    TaskAttempt,
    TaskState,
)
from repro.core import vcluster
from repro.core.vcluster import VirtualCluster


@dataclass
class HFSPConfig(SchedulerConfig):
    """Paper defaults (Sect. 4.1): sample set 5, Delta = 60 s, xi = 1,
    Training module may use the whole cluster, eager preemption on."""

    preemption: Preemption = Preemption.EAGER
    sample_set_size: int = 5
    delta: float = 60.0
    xi: float = 1.0
    # Max slots the top-level scheduler grants the Training module (Sect.
    # 3.2: bounded "to avoid starvation in the job scheduler, for workloads
    # with bursty arrivals").  None = all slots (the paper's configuration).
    max_training_slots: int | None = None
    estimator_factory: Callable[[], TaskTimeEstimator] = FirstOrderEstimator
    # Multiplicative error injected into finalized size estimates, used by
    # the Fig. 6 robustness experiment: a wrong estimate is drawn uniformly
    # in [size*(1-alpha), size*(1+alpha)].
    error_alpha: float = 0.0
    error_seed: int = 0
    # Virtual-cluster numeric backend ("numpy" | "jax" | "auto"); None
    # defers to $REPRO_VC_BACKEND, then "auto" — numpy kernels that latch
    # to jax once a phase's live-job count reaches vc_auto_threshold
    # (see docs/vcluster.md; the backends are conformance-tested
    # bit-identical, so the switch is behavior-neutral).
    vc_backend: str | None = None
    # Live-job threshold for the "auto" backend's numpy->jax latch.
    vc_auto_threshold: int = vcluster.AUTO_JAX_THRESHOLD
    # Live-service wall-tick maintenance cadence (seconds of *wall*
    # clock between stale-estimate refreshes through the preemption
    # policy's on_wall_refresh hook).  Only reachable via the service
    # master's on_wall_tick pacer — offline simulation never ticks, so
    # the knob is inert there.  <= 0 disables.
    wall_refresh_every: float = 10.0


class HFSPScheduler(Scheduler):
    """The size-based scheduling *engine*, assembled into a discipline.

    With the default policies (``rank=VirtualFinishRank()``, plain
    preemption, virtual-cluster aging) this IS the paper's HFSP,
    bit-identical to the pre-Discipline-API scheduler.  The seams —
    ``rank`` (job order), ``preemption_policy`` (primitive + hysteresis
    veto), ``aging`` (priority movement over time) — let the registry
    (:mod:`repro.core.disciplines`) assemble SRPT, LAS, PSBS, or any
    third-party discipline out of the same engine: demand-indexed
    passes, the Training module, delay scheduling, and the preemption
    machinery are shared; only the policies differ.  The rank policy's
    capability flags gate the subsystems: ``needs_estimates`` runs the
    Training module, ``uses_vcluster`` maintains and ages the virtual
    cluster.
    """

    name = "hfsp"

    def __init__(
        self,
        cluster: ClusterSpec,
        config: HFSPConfig | None = None,
        *,
        rank: RankPolicy | None = None,
        preemption_policy: PreemptionPolicy | None = None,
        aging: AgingPolicy | None = None,
        name: str | None = None,
    ):
        cfg = config or HFSPConfig()
        if (
            preemption_policy is not None
            and preemption_policy.mode is not cfg.preemption
        ):
            # The policy's mode is authoritative: the engine's preemption
            # machinery keeps reading config.preemption, so the two must
            # agree — on a private copy, never by mutating the caller's
            # config object (which may be shared across schedulers).
            cfg = dataclasses.replace(cfg, preemption=preemption_policy.mode)
        super().__init__(cluster, cfg)
        self.config: HFSPConfig = cfg
        self.rank = rank or VirtualFinishRank()
        self.preemption_policy = preemption_policy or PreemptionPolicy(
            mode=cfg.preemption
        )
        self.aging = aging or (
            VirtualClusterAging()
            if self.rank.uses_vcluster
            else WallClockAging()
        )
        if name is not None:
            self.name = name
        self.training = TrainingModule(
            sample_set_size=cfg.sample_set_size,
            delta=cfg.delta,
            xi=cfg.xi,
            estimator=cfg.estimator_factory(),
        )
        self.vc: dict[Phase, VirtualCluster] = {
            p: VirtualCluster(
                phase=p,
                slots=cluster.slots(p),
                backend=cfg.vc_backend,
                auto_threshold=cfg.vc_auto_threshold,
            )
            for p in (Phase.MAP, Phase.REDUCE)
        }
        self._clock = 0.0
        self._eager_enabled = True  # hysteresis state (Sect. 3.3)
        # (job_id, phase.value) pairs whose phase has been started
        # (training begun / virtual job added) — the run-once guard for
        # the REDUCE slow-start unlock, policy-independent.
        self._phase_started: set[tuple[int, str]] = set()
        # Largest rank-stability position spread observed by the
        # preemption-hysteresis hook (whatif_diagnostics).
        self._max_rank_spread = 0
        # Monotone rank-state version: bumped (via _rank_dirty) whenever
        # the schedule order may change.  Together with the base
        # scheduler's _run_epoch it keys the cross-pass caches below —
        # between passes with equal epochs, the actor list and the
        # per-machine victim maxima are provably identical, so a
        # steady-state (heartbeat-only) pass reuses them in O(1).
        self._rank_epoch = 0
        # phase.value -> (epoch key, sorted actor list).
        self._actor_cache: dict[str, tuple[tuple, list[int]]] = {}
        # (machine, phase.value) -> max schedule position among the
        # machine's RUNNING tasks (-1 = none ranked); lazily filled, and
        # dropped wholesale when either epoch moves.
        self._mvmax: dict[tuple[int, str], int] = {}
        self._mvmax_epoch: tuple[int, int] | None = None
        # Pass-scoped victim-order cache (reset per phase pass).
        self._pass_victims: list[int] | None = None
        # Machines currently out of the cluster (crashed or blacklisted
        # by the fault layer); the virtual clusters' capacity is
        # recomputed from this set so crash/recover stays idempotent.
        self._down_machines: set[int] = set()
        if cfg.error_alpha > 0:
            import numpy as _np

            self._err_rng = _np.random.default_rng(cfg.error_seed)
        else:
            self._err_rng = None
        # Last wall-clock stale-estimate refresh (see on_wall_tick).
        self._last_wall_refresh: float | None = None

    # ------------------------------------------------------------------
    # Aging (Sect. 3.1): each event distributes elapsed time as progress
    # to every allocated virtual task.
    # ------------------------------------------------------------------
    def _advance(self, now: float) -> None:
        dt = now - self._clock
        if dt > 0:
            self.aging.advance(self, dt, now)
            self._clock = now

    # ------------------------------------------------------------------
    # Events
    # ------------------------------------------------------------------
    def on_job_arrival(self, spec: JobSpec, now: float) -> JobState:
        self._advance(now)
        js = super().on_job_arrival(spec, now)
        self._start_phase(js, Phase.MAP)
        self._maybe_unlock_reduce(js)
        return js

    def _perturb(self, est: float) -> float:
        """Fig. 6 error injection on *finalized* estimates.

        Floored at a tiny positive size: with ``error_alpha > 1`` (the
        paper-psbs-calibration preset's heavier-than-Fig.-6 regime) the
        uniform factor can go negative, and a negative size is
        meaningless to the virtual cluster.  For ``alpha <= 1`` the
        factor is >= 0 and the floor changes nothing (an exactly-zero
        draw has probability zero), so every pre-existing cell result
        is untouched."""
        if self._err_rng is None or not math.isfinite(est):
            return est
        a = self.config.error_alpha
        return float(
            max(est * self._err_rng.uniform(1.0 - a, 1.0 + a), 1e-9)
        )

    def _start_phase(self, js: JobState, phase: Phase) -> None:
        tasks = js.spec.tasks(phase)
        self._phase_started.add((js.spec.job_id, phase.value))
        if self.rank.needs_estimates:
            est = self.training.start_phase(js, phase)
            js.est_size[phase] = est
            if tasks and self.rank.uses_vcluster:
                self.vc[phase].add_job(
                    js.spec.job_id, est, len(tasks), weight=js.spec.weight
                )
        self._rank_dirty(phase)

    def _maybe_unlock_reduce(self, js: JobState) -> None:
        if (
            js.spec.reduce_tasks
            and (js.spec.job_id, Phase.REDUCE.value) not in self._phase_started
            and js.reduce_unlocked()
        ):
            self._start_phase(js, Phase.REDUCE)

    def on_task_complete(self, job_id: int, key: tuple, now: float) -> None:
        self._advance(now)
        super().on_task_complete(job_id, key, now)  # run-state index upkeep
        js = self.jobs.get(job_id)
        if js is None:
            return
        phase = Phase(key[1])
        att = js.tasks[key]
        if self.rank.needs_estimates:
            new_est = self.training.observe_completion(
                js, phase, key, att.spec.duration
            )
            if new_est is not None:
                new_est = self._perturb(new_est)
                js.est_size[phase] = new_est
                if self.rank.uses_vcluster:
                    self.vc[phase].set_size(job_id, new_est)
                self.preemption_policy.on_estimate(self, job_id, phase)
        if js.n_unfinished(phase) == 0 and self.rank.uses_vcluster:
            self.vc[phase].remove_job(job_id)
        # NOTE: real task completions do NOT shrink the virtual cap — the
        # virtual cluster is a pure PS simulation (see vcluster docstring).
        if phase is Phase.MAP:
            self._maybe_unlock_reduce(js)
        # Attained service / estimates / membership changed for THIS
        # phase only (a MAP completion cannot move REDUCE rank keys; a
        # freshly-unlocked REDUCE phase was invalidated by _start_phase
        # above).
        self._rank_dirty(phase)

    def on_task_progress(
        self, job_id: int, key: tuple, fraction: float, elapsed: float, now: float
    ) -> None:
        """REDUCE-style early size estimation: sigma = Delta / p (Sect. 3.2.1)."""
        self._advance(now)
        js = self.jobs.get(job_id)
        if js is None:
            return
        phase = Phase(key[1])
        if not self.rank.needs_estimates:
            return
        new_est = self.training.observe_progress(js, phase, key, fraction, elapsed)
        if new_est is not None:
            new_est = self._perturb(new_est)
            js.est_size[phase] = new_est
            if self.rank.uses_vcluster:
                self.vc[phase].set_size(job_id, new_est)
            self.preemption_policy.on_estimate(self, job_id, phase)
            self._rank_dirty(phase)

    def on_job_complete(self, job_id: int, now: float) -> None:
        self._advance(now)
        super().on_job_complete(job_id, now)
        for vc in self.vc.values():
            vc.remove_job(job_id)
        for pv in (Phase.MAP.value, Phase.REDUCE.value):
            self._phase_started.discard((job_id, pv))
        self._skip_counts.pop(job_id, None)
        # Let the policies evict their per-job state (hysteresis verdict
        # cache, PSBS bump counts) so long runs stay O(live jobs).
        self.preemption_policy.forget(job_id)
        self.aging.forget(job_id)
        self._rank_dirty()

    # -- run-state hooks: keep the Training module's demand indexes in
    # lockstep with sample-task state changes (O(sample set) per event).
    def _training_sync(self, att) -> None:
        phase = att.spec.phase
        jid = att.spec.job_id
        if self.training.is_training(jid, phase):
            js = self.jobs.get(jid)
            if js is not None:
                self.training.sync_job(js, phase)

    def on_task_started(self, att, slot) -> None:
        super().on_task_started(att, slot)
        self._training_sync(att)

    def on_task_resumed(self, att, slot) -> None:
        super().on_task_resumed(att, slot)
        self._training_sync(att)
        self._rank_dirty(att.spec.phase)

    def on_task_suspended(self, att) -> None:
        super().on_task_suspended(att)
        self._training_sync(att)
        self._rank_dirty(att.spec.phase)

    def on_task_killed(self, att) -> None:
        super().on_task_killed(att)
        self._training_sync(att)
        self._rank_dirty(att.spec.phase)

    # -- fault hooks (see repro.core.faults / docs/faults.md) ------------
    def on_task_failed(self, att) -> None:
        super().on_task_failed(att)
        self._training_sync(att)  # a FAILED sample is neither wanted nor running
        self._rank_dirty(att.spec.phase)

    def on_task_readmitted(self, att) -> None:
        super().on_task_readmitted(att)
        self._training_sync(att)  # a re-admitted sample is dispatchable again
        self._rank_dirty(att.spec.phase)

    def on_machine_crashed(self, machine: int) -> None:
        super().on_machine_crashed(machine)
        self._down_machines.add(machine)
        self._resize_vclusters()

    def on_machine_recovered(self, machine: int) -> None:
        super().on_machine_recovered(machine)
        self._down_machines.discard(machine)
        self._resize_vclusters()

    def _resize_vclusters(self) -> None:
        """Recompute virtual capacity from the down-machine set (an
        idempotent recompute, so crash-while-blacklisted sequences cannot
        double-count a machine)."""
        if self.rank.uses_vcluster:
            n_down = len(self._down_machines)
            for phase, per in (
                (Phase.MAP, self.cluster.map_slots_per_machine),
                (Phase.REDUCE, self.cluster.reduce_slots_per_machine),
            ):
                self.vc[phase].set_slots(
                    max(1, self.cluster.slots(phase) - n_down * per)
                )
        self._rank_dirty()

    def on_sample_lost(self, att) -> None:
        """A completed sample task's duration observation was dropped in
        flight: re-request a replacement sample so the size estimate is
        fit from real observations.  Fires before ``on_task_complete``,
        whose normal refit/sync path then sees the updated sample set."""
        if not self.rank.needs_estimates:
            return
        js = self.jobs.get(att.spec.job_id)
        if js is None:
            return
        self.training.lose_sample(js, att.spec.phase, att.spec.key)
        self._rank_dirty(att.spec.phase)

    def _paranoid_check(self, view: ClusterView, phase: Phase) -> None:
        super()._paranoid_check(view, phase)
        # The Training module's demand indexes share the hook-update
        # contract, so the paranoid pass cross-checks them too.
        self.training.check_indexes(phase, self.jobs)

    def on_tick(self, now: float) -> None:
        self._advance(now)

    # ------------------------------------------------------------------
    # Scheduling
    # ------------------------------------------------------------------
    def schedule(self, view: ClusterView, now: float) -> list[Action]:
        self._advance(now)
        self._begin_pass()
        self._update_hysteresis(view)
        if self.rank.uses_vcluster:
            self._warm_order_caches(now)
        actions: list[Action] = []
        for phase in (Phase.MAP, Phase.REDUCE):
            actions.extend(self._phase_schedule(view, phase, now))
        return actions

    def _warm_order_caches(self, now: float) -> None:
        """Cross-phase batched projection warm (jax backend only).

        When BOTH phases' schedule-order caches are cold — the typical
        state right after a structural event batch (arrivals, size
        re-estimates) touching MAP and REDUCE — the two PS projections
        are priced in one vmapped dispatch instead of two, halving kernel
        launches on the structural-event path.  Behavior-neutral: the
        padded batch computes bit-identical finish times (masked padding
        adds exact float zeros), and each phase's order cache is warmed
        with exactly what ``schedule_order`` would have computed.

        Only applied while both phases fit one sub-1024 padding bucket:
        there the batch is a pure dispatch amortization (single calls
        would not segment either).  At larger widths the batch kernel
        (no shrinking-bucket compaction, lockstep rows padded to the
        wider phase) does MORE work than two segmented single-phase
        projections, so the normal per-phase path wins."""
        vcs = [self.vc[p] for p in (Phase.MAP, Phase.REDUCE)]
        if any(vc.backend != "jax" for vc in vcs):
            return
        cold = [vc for vc in vcs if vc.order_cache_cold()]
        if len(cold) < 2:
            return
        from repro.core import vcluster_jax
        import numpy as np

        states = []
        for vc in cold:
            vc._materialize()
            states.append(vc._state_arrays())
        width = max(len(s[0]) for s in states)
        if vcluster_jax._bucket(width) > 1024:
            return  # segmented per-phase projections are cheaper here
        b = len(cold)
        rem_b = np.zeros((b, width))
        caps_b = np.zeros((b, width))
        ws_b = np.zeros((b, width))
        n_valid = np.zeros(b, dtype=np.int64)
        for i, (ids, rem, caps, ws) in enumerate(states):
            n_valid[i] = len(ids)
            rem_b[i, : len(ids)] = rem
            caps_b[i, : len(ids)] = caps
            ws_b[i, : len(ids)] = ws
        fin_b = vcluster_jax.project_finish_times_batch(
            rem_b,
            caps_b,
            ws_b,
            np.array([float(vc.slots) for vc in cold]),
            float(now),
            n_valid=n_valid,
        )
        for vc, (ids, _, _, _), row in zip(cold, states, fin_b):
            vc.warm_order_cache(
                {j: float(f) for j, f in zip(ids, row[: len(ids)])}
            )

    # -- what-if projections (batched on the jax backend) ---------------
    def whatif_finish_times(
        self, phase: Phase, scenarios: list[dict[int, float]], now: float
    ) -> list[dict[int, float]]:
        """PS finish times under hypothetical remaining-work overrides.

        Each scenario maps job_id -> hypothetical remaining serialized
        work; unnamed jobs keep their current state.  On the jax backend
        every scenario prices in one vmapped call — this is the hook for
        preemption-policy experiments (e.g. "would suspending J actually
        move the needle?") and epsilon-window event batching."""
        self._advance(now)
        return self.vc[phase].projected_finish_batch(scenarios, now)

    def rank_stability(
        self, job_id: int, phase: Phase, now: float
    ) -> list[int]:
        """Schedule positions ``job_id`` would occupy across the Training
        module's candidate sizes (leave-one-out refits of the current
        sample observations) — a measure of how settled the job's rank is
        while its size estimate is still provisional.  All candidates are
        evaluated in a single batched projection with ``set_size``
        semantics (remaining AND virtual parallelism re-derived per
        candidate, exactly what the estimator update would apply)."""
        self._advance(now)
        js = self.jobs.get(job_id)
        vc = self.vc[phase]
        if js is None or job_id not in vc:
            return []
        sizes = self.training.candidate_sizes(js, phase)
        if not sizes:
            return []
        scenarios = [{job_id: s} for s in sizes]
        fins = vc.projected_finish_batch(scenarios, now, as_sizes=True)
        return [vc._order_from_fin(fin).index(job_id) for fin in fins]

    def rank_stability_batch(
        self, phase: Phase, job_ids: list[int], now: float
    ) -> dict[int, list[int]]:
        """Rank-stability positions for MANY jobs in ONE batched
        projection.

        Concatenates every job's candidate-size scenarios (exactly the
        per-job :meth:`rank_stability` scenarios, in the same per-job
        order) into a single ``projected_finish_batch`` call, then slices
        the results back per job.  Scenario rows are independent, so each
        job's positions are bit-identical to its per-job call — this is
        the epsilon-window fusion: after a coalesced event window many
        in-training jobs need re-pricing at once, and one batched
        dispatch replaces one per job (the ROADMAP "re-project whole
        windows through one projected_finish_batch call" item).
        """
        self._advance(now)
        vc = self.vc[phase]
        spans: list[tuple[int, int, int]] = []  # (job_id, start, count)
        scenarios: list[dict[int, float]] = []
        for jid in job_ids:
            js = self.jobs.get(jid)
            if js is None or jid not in vc:
                spans.append((jid, len(scenarios), 0))
                continue
            sizes = self.training.candidate_sizes(js, phase)
            spans.append((jid, len(scenarios), len(sizes)))
            scenarios.extend({jid: s} for s in sizes)
        if not scenarios:
            return {jid: [] for jid, _, _ in spans}
        self.stats.rank_stability_batched += sum(
            1 for _, _, n in spans if n
        )
        fins = vc.projected_finish_batch(scenarios, now, as_sizes=True)
        out: dict[int, list[int]] = {}
        for jid, start, count in spans:
            out[jid] = [
                vc._order_from_fin(fin).index(jid)
                for fin in fins[start:start + count]
            ]
        return out

    def note_rank_stability(self, spread: int, vetoed: bool) -> None:
        """Record one preemption-hysteresis consultation (called by
        :class:`repro.core.disciplines.StabilityHysteresis`); surfaces
        in :meth:`whatif_diagnostics` and the scenario report layer."""
        self.stats.rank_stability_checks += 1
        if vetoed:
            self.stats.rank_stability_vetoes += 1
        if spread > self._max_rank_spread:
            self._max_rank_spread = spread

    def on_wall_tick(self, wall_now: float, now: float) -> None:
        """Live-service wall-clock maintenance (the first consumer of
        the :meth:`~repro.core.scheduler.Scheduler.on_wall_tick` seam).

        Every ``config.wall_refresh_every`` wall seconds, drain the
        preemption policy's stale-verdict backlog through
        ``on_wall_refresh`` — during long idle stretches between
        simulation events the lazy refresh paths (``on_pass`` /
        ``may_preempt``) never run, so without this tick a burst
        arriving after an idle period pays the whole batched projection
        on its first decision.  Sim-time purity: the hook is
        decision-neutral by contract (refreshed cache entries are
        bit-identical to what the lazy path would compute), so the
        journal replay twin — which never ticks — produces the same
        schedule; tests pin the completion fingerprint with and without
        ticks."""
        every = self.config.wall_refresh_every
        if every is None or every <= 0:
            return
        last = self._last_wall_refresh
        if last is not None and wall_now - last < every:
            return
        self._last_wall_refresh = wall_now
        refreshed = self.preemption_policy.on_wall_refresh(self, now)
        self.stats.wall_refreshes += 1
        self.stats.wall_refreshed_verdicts += int(refreshed or 0)

    def whatif_diagnostics(self) -> dict:
        """Preemption-hysteresis / what-if diagnostics for the scenario
        report layer (one dict per cell; all JSON-serializable).  Counts
        cover the whole run: how often the preemption policy priced a
        batched what-if projection (``rank_stability``), how often it
        vetoed a preemption, the largest rank spread it saw, and the
        PSBS late-job bumps.  These counters appear ONLY here — the
        report's ``stats`` block keeps its pre-Discipline-API fields
        (the suspended-bytes EAGER->WAIT fallbacks live there)."""
        return {
            "discipline": self.name,
            "rank_policy": self.rank.name,
            "aging_policy": self.aging.name,
            "rank_stability_checks": self.stats.rank_stability_checks,
            "rank_stability_vetoes": self.stats.rank_stability_vetoes,
            "rank_stability_batched": self.stats.rank_stability_batched,
            "max_rank_spread": self._max_rank_spread,
            "late_job_bumps": self.stats.late_job_bumps,
            # Calibration knobs of the assembled policies (None when the
            # assembly has no such knob — e.g. hfsp's plain FSP aging):
            # the paper-psbs-calibration preset reads its swept
            # late_factor/max_spread back from here per cell.
            "late_factor": getattr(self.aging, "late_factor", None),
            "max_spread": getattr(self.preemption_policy, "max_spread", None),
            # Live-only wall-tick maintenance (always 0 offline).
            "wall_refreshes": self.stats.wall_refreshes,
            "wall_refreshed_verdicts": self.stats.wall_refreshed_verdicts,
        }

    def _update_hysteresis(self, view: ClusterView) -> None:
        """EAGER -> WAIT fallback on suspended-state pressure (Sect. 3.3)."""
        total = view.total_suspended_bytes()
        if self._eager_enabled and total > self.cluster.suspend_bytes_hi:
            self._eager_enabled = False
            self.stats.hysteresis_fallbacks += 1
        elif not self._eager_enabled and total < self.cluster.suspend_bytes_lo:
            self._eager_enabled = True

    def _phase_schedule(
        self, view: ClusterView, phase: Phase, now: float
    ) -> list[Action]:
        actions: list[Action] = []
        pv = phase.value
        demand_indexed = self.config.demand_indexed
        live_scan: dict[int, JobState] | None = None
        if demand_indexed:
            if not self._n_live_phase[pv]:
                return actions
        else:
            # Index-free reference mode: phase-liveness comes from a
            # fresh live-table scan, so demand-index corruption diverges
            # the two modes instead of reproducing bit for bit.
            live_scan = self.live_jobs_scan(phase)
            if not live_scan:
                return actions
        # Run-state engine upkeep: O(1) count check (resyncs only under a
        # hook-less executor); full rebuild + assert in paranoid mode.
        self._maybe_resync_indexes(view, phase)
        if self.config.paranoid_indexes:
            self._paranoid_check(view, phase)
        # Pass-scoped priority adjustments (PSBS late-job re-injection)
        # run before the rank order is read so they shape this pass.
        self.aging.on_pass(self, phase, now)
        free = list(view.free_slots(phase))
        # Preemption-policy pass hook: when the pass starts slot-starved,
        # StabilityHysteresis re-prices every stale in-training verdict
        # through ONE rank_stability_batch projection here, so the
        # may_preempt consultations below are pure cache hits (identical
        # verdicts — vcluster state is static within a pass).
        self.preemption_policy.on_pass(self, phase, now, bool(free))
        # Jobs in the discipline's rank order (HFSP: ascending projected
        # PS finish time, Sect. 3.1; SRPT: estimated remaining; LAS:
        # attained service).  Positions come from the policy's order
        # cache — valid across passes until the next structural event —
        # so a steady-state pass pays O(1) here, not O(live jobs).
        order, pos_of = self.rank.order_and_pos(self, phase, now)
        # Pass-scoped victim-order cache (running jobs by ascending
        # position), built lazily on the first preemption walk.
        self._pass_victims = None

        eager_ok = (
            self.config.preemption is Preemption.EAGER and self._eager_enabled
        )
        n_live = (
            self._n_live_phase[pv] if demand_indexed else len(live_scan)
        )
        # Lazy: only preemption walks consult the protected set, and the
        # pool check materializes it at most once per phase pass.
        protected = LazySet(
            lambda: self._protected_keys(phase, n_live, live_scan)
        )
        # Pass-scoped memo of per-machine victim lists (position-sorted).
        # The run indexes are static during a pass, so each machine's list
        # is computed at most once per pass — previously the single most
        # expensive part of a pass when jobs held many suspended tasks.
        # ``victim_dead`` marks machines whose victim walk came up empty:
        # the job loop visits jobs in ascending position and claims only
        # grow, so victim eligibility (vpos > pos, unclaimed, unprotected)
        # shrinks monotonically within a pass — an empty walk stays empty.
        victim_memo: dict[int, list[tuple[int, TaskAttempt]]] = {}
        victim_dead: set[int] = set()

        # -- 1. Top-level scheduler: Training-module slots first.  "The
        # top-level scheduler responds to the arrival of a new job by
        # allocating a given set of resources to the Training module"
        # (Sect. 3.1.1) — under full load that requires preempting up to
        # the training job's fair share.
        acts, free = self._schedule_training(
            phase, free, now, pos_of, eager_ok, protected, n_live, live_scan,
        )
        actions.extend(acts)

        # -- 2. Job scheduler: focus resources in projected-finish order.
        # Only jobs with actionable demand — pending or suspended tasks —
        # can emit an action here, so those demand-index members are the
        # candidate set.  Jobs with running tasks only matter as
        # preemption victims and are reached through the victim order.
        pend = self._jobs_pending[pv]
        susp = self._jobs_suspended[pv]
        if demand_indexed and not pend and not susp:
            return actions
        rmax = -2  # lazy: highest schedule position of any running job
        if demand_indexed:
            lim = None
            if not free:
                rmax = self._max_running_pos(phase, order)
                if rmax < 0:
                    return actions
                lim = rmax
            actors = self._actors(
                phase, pend, susp, pos_of, lim, eager_ok, bool(free)
            )
        else:
            # Legacy walk: every phase-live job in schedule order.
            actors = [j for j in order if j in live_scan]
        jobs = self.jobs
        for jid in actors:
            pos = pos_of[jid]
            if demand_indexed and not free:
                if rmax == -2:
                    rmax = self._max_running_pos(phase, order)
                if pos >= rmax:
                    break  # ascending order: every later actor is a no-op too
            js = jobs[jid]
            # Resume suspended tasks in place (Sect. 3.3 locality), possibly
            # suspending tasks of *later-ordered* jobs on the same machine.
            if js.n_suspended(phase):
                acts, free = self._resume_with_preemption(
                    js, pos, phase, free, pos_of,
                    victim_memo, victim_dead, eager_ok, protected,
                )
                actions.extend(acts)
            # Start pending tasks on free slots (delay scheduling inside).
            n_delayed_before = self.stats.delay_sched_waits
            acts, free = self._assign_pending(js, phase, free, len(free), now)
            actions.extend(acts)
            delayed = self.stats.delay_sched_waits > n_delayed_before
            # Preempt later jobs for remaining unmet demand — but never on
            # behalf of a job that just declined slots to wait for locality.
            unmet = self._unclaimed_pending(js, phase)
            if (
                unmet > 0 and not free and not delayed
                # Hysteresis veto (checked last — it may price a batched
                # what-if projection): a discipline's preemption policy
                # can decline to preempt on behalf of this job this pass
                # (PSBS: while the job's rank is still unstable).
                and self.preemption_policy.may_preempt(self, js, phase, now)
            ):
                acts, freed = self._preempt_for(
                    js, pos, phase, unmet, pos_of, eager_ok, protected,
                )
                actions.extend(acts)
                if freed:
                    # Bypass delay scheduling: locality was forfeited when we
                    # chose to preempt.
                    saved = self.config.locality_enabled
                    self.config.locality_enabled = False
                    try:
                        acts, left = self._assign_pending(
                            js, phase, freed, len(freed), now
                        )
                    finally:
                        self.config.locality_enabled = saved
                    actions.extend(acts)
                    free.extend(left)
        return actions

    def _max_running_pos(self, phase: Phase, order: list[int]) -> int:
        """Highest schedule position among jobs with RUNNING tasks (-1 if
        none run).  Walks the cached order from the back, so the cost is
        O(trailing non-running jobs) — small in the focused steady state
        where HFSP serves the earliest-finishing jobs."""
        running = self._jobs_running[phase.value]
        if not running:
            return -1
        for i in range(len(order) - 1, -1, -1):
            if order[i] in running:
                return i
        return -1

    def _rank_dirty(self, phase: Phase | None = None) -> None:
        """The schedule order may have changed: bump the rank epoch
        (invalidating the cross-pass actor/mvmax caches) and forward the
        invalidation to the rank policy's own order cache."""
        self._rank_epoch += 1
        self.rank.invalidate(phase)

    def _actors(
        self,
        phase: Phase,
        pend: dict[int, None],
        susp: dict[int, None],
        pos_of: dict[int, int],
        lim: int | None,
        eager_ok: bool,
        have_free: bool,
    ) -> list[int]:
        """The pass's actor list (jobs that can emit an action), sorted
        by ascending rank position — cached across passes until the
        run/rank epochs move.

        Actor eligibility: known to the rank order and, when no slot is
        free, positioned before some running job (``lim``) — a job can
        then act only by preempting (or displacing into) a
        *later-ordered* running victim, so actors past every running
        job are provable no-ops (their victim walks break immediately
        and count nothing, in every preemption mode).

        Suspended-only actors get one further provable prune when no
        slot is free: resume is machine-local (Sect. 3.3), so such an
        actor can act only by suspending a later-ordered victim on a
        machine that holds its suspended state.  If no such machine has
        a running task positioned after the actor (``mvmax``), every
        candidate inside ``_resume_with_preemption`` fails the position
        test and the walk emits nothing — and without eager preemption
        the resume path cannot act at all without free slots.  Claims
        and the protected set only shrink eligibility further, so the
        position-only filter is exact for exclusion.  (Ranks like LAS
        can hold thousands of tied suspended jobs below ``lim``
        indefinitely; without this prune every heartbeat pass re-walked
        them all.)

        The epoch key makes the cache sound: the list is a pure function
        of the demand/run indexes (run epoch), the rank order (rank
        epoch), free-slot availability, and the hysteresis state — a
        pass that emitted actions bumps the run epoch through the
        executor hooks, so only genuinely idle passes hit the cache.
        The legacy walk (``demand_indexed=False``) never uses it; the
        equivalence suite pins the filter's neutrality."""
        pv = phase.value
        key = (self._run_epoch, self._rank_epoch, have_free, eager_ok)
        hit = self._actor_cache.get(pv)
        if hit is not None and hit[0] == key:
            return hit[1]
        cand = [
            j for j in pend
            if j in pos_of and (lim is None or pos_of[j] < lim)
        ]
        if have_free:
            cand.extend(
                j for j in susp
                if j not in pend
                and j in pos_of
                and (lim is None or pos_of[j] < lim)
            )
        elif eager_ok:
            jobs = self.jobs
            for j in susp:
                if j in pend or j not in pos_of:
                    continue
                p = pos_of[j]
                if lim is not None and p >= lim:
                    continue
                for m in jobs[j].suspended_by_machine(phase):
                    if self._machine_max_victim_pos(m, pv, pos_of) > p:
                        cand.append(j)
                        break
        actors = sorted(cand, key=pos_of.__getitem__)
        self._actor_cache[pv] = (key, actors)
        return actors

    def _machine_max_victim_pos(
        self, m: int, pv: str, pos_of: dict[int, int]
    ) -> int:
        """Highest schedule position among RUNNING tasks on machine
        ``m`` (-1 if none ranked) — the machine-local analogue of
        ``_max_running_pos``, cached across passes on the same epoch
        key as the actor list."""
        epoch = (self._run_epoch, self._rank_epoch)
        if self._mvmax_epoch != epoch:
            self._mvmax.clear()
            self._mvmax_epoch = epoch
        mk = (m, pv)
        v = self._mvmax.get(mk)
        if v is None:
            v = -1
            bucket = self._run_by_machine.get(mk)
            if bucket:
                for key in bucket:
                    p = pos_of.get(key[0])
                    if p is not None and p > v:
                        v = p
            self._mvmax[mk] = v
        return v

    def _victim_order(self, phase: Phase, pos_of: dict[int, int]) -> list[int]:
        """Jobs with RUNNING tasks by ascending schedule position, cached
        for the pass (the run indexes are static during a pass)."""
        if self._pass_victims is None:
            self._pass_victims = sorted(
                (
                    j for j in self._jobs_running[phase.value]
                    if j in pos_of
                ),
                key=pos_of.__getitem__,
            )
        return self._pass_victims

    def _pool_ok(self, phase: Phase, protected) -> bool:
        """True while >=1 RUNNING task could still be preempted this pass:
        running tasks minus protected sample tasks minus victims already
        claimed.  O(1) after the protected set materializes; turns the
        saturated-training pathology (every hungry job fruitlessly walking
        every running-but-protected task) into a single check."""
        pv = phase.value
        return (
            self._n_running_idx[pv]
            - len(protected)
            - self._claimed_running.get(pv, 0)
        ) > 0

    # -- training module (Sect. 3.2) -----------------------------------
    def _schedule_training(
        self,
        phase: Phase,
        free: list[SlotKey],
        now: float,
        pos_of: dict[int, int],
        eager_ok: bool,
        protected,
        n_live: int,
        live_scan: dict[int, JobState] | None,
    ) -> tuple[list[Action], list[SlotKey]]:
        actions: list[Action] = []
        legacy = live_scan is not None
        # Only jobs with a dispatchable sample task matter: iterate the
        # Training module's wanted index (O(actionable training jobs)),
        # not every in-training job — a job whose samples are all running
        # or observed cannot receive a training slot this pass.  The
        # index-free reference mode probes every active job instead (the
        # pre-index walk; `wanted_sample_tasks` below is the per-job
        # filter either way).
        if legacy:
            training_jobs = [
                live_scan[j]
                for j in self.training.active_jobs(phase)
                if j in live_scan
            ]
        else:
            training_jobs = [
                js
                for js in (
                    self._live.get(j) for j in self.training.wanted_jobs(phase)
                )
                if js is not None
            ]
        if not training_jobs:
            return actions, free
        # "Execution slots are assigned according to a 'fewer remaining
        # tasks' discipline, which implies short jobs are given priority."
        # job_id tiebreak = the live-dict (arrival) order the previous
        # stable sort inherited.
        training_jobs.sort(
            key=lambda js: (
                js.n_unfinished(phase), js.spec.arrival_time, js.spec.job_id,
            )
        )
        budget = self._training_budget(phase, live_scan)
        fair = max(1, self.cluster.slots(phase) // max(n_live, 1))
        mode = self.config.preemption
        can_preempt = not (
            mode is Preemption.WAIT
            or (mode is Preemption.EAGER and not eager_ok)
        )
        for js in training_jobs:
            wanted = self.training.wanted_sample_tasks(js, phase)
            if not wanted:
                continue
            quota = min(len(wanted), fair)
            # Free-slot assignments consume the global training budget;
            # preemption below merely SUBSTITUTES one training slot for
            # another, so it is not budget-gated.
            acts, free = self._assign_pending(
                js, phase, free, min(quota, max(budget, 0)), now,
                only_keys=wanted,
            )
            self.stats.training_tasks += len(acts)
            budget -= len(acts)
            quota -= len(acts)
            actions.extend(acts)
            # In-flight sample tasks count toward the fair share already
            # granted; only preempt for the genuinely unmet remainder.
            if legacy:
                running_samples = sum(
                    1
                    for k in self.training.sample_keys(js.spec.job_id, phase)
                    if js.tasks[k].state is TaskState.RUNNING
                )
            else:
                running_samples = len(
                    self.training.running_sample_keys(js.spec.job_id, phase)
                )
            unmet = min(quota, max(0, fair - running_samples))
            if unmet > 0 and not free and can_preempt:
                # Victims: last-ordered (largest) jobs first, never self.
                acts, freed = self._preempt_for(
                    js, -1, phase, unmet, pos_of, eager_ok, protected,
                )
                actions.extend(acts)
                if freed:
                    saved = self.config.locality_enabled
                    self.config.locality_enabled = False
                    try:
                        a2, left = self._assign_pending(
                            js, phase, freed, len(freed), now,
                            only_keys=self.training.wanted_sample_tasks(js, phase),
                        )
                    finally:
                        self.config.locality_enabled = saved
                    self.stats.training_tasks += len(a2)
                    budget -= len(a2)
                    actions.extend(a2)
                    free.extend(left)
        return actions, free

    def _training_budget(
        self, phase: Phase, live_scan: dict[int, JobState] | None = None
    ) -> int:
        cap = self.config.max_training_slots
        if cap is None:
            cap = self.cluster.slots(phase)
        # Slots currently held by still-training sample tasks count
        # against the budget — an O(1) read of the Training module's
        # running-sample counter (kept by the sync hooks).  The
        # index-free reference mode probes every active job's sample
        # states instead (the pre-index walk).
        if live_scan is None:
            in_flight = self.training.n_running_samples(phase)
        else:
            in_flight = 0
            for jid in self.training.active_jobs(phase):
                js = live_scan.get(jid)
                if js is None:
                    continue
                for k in self.training.sample_keys(jid, phase):
                    if js.tasks[k].state is TaskState.RUNNING:
                        in_flight += 1
        return max(0, cap - in_flight)

    # -- preemption (Sect. 3.3) ------------------------------------------
    def _protected_keys(
        self,
        phase: Phase,
        n_live: int,
        live_scan: dict[int, JobState] | None = None,
    ) -> set:
        """Running sample tasks shielded from preemption.  The Training
        module holds "at least a fair share" (Sect. 3.1.1) — a QUOTA of
        slots/num_jobs per training job, NOT blanket immunity (protecting
        every sample task would let one big in-training job starve a tiny
        arrival for a full task length)."""
        # Integer fair share, floored at 1: a running sample task is ALWAYS
        # shielded — two in-training jobs may otherwise kill each other's
        # samples every pass (progress resets under KILL => livelock).
        quota = max(1, self.cluster.slots(phase) // max(n_live, 1))
        out: set = set()
        if live_scan is not None:
            # Index-free reference mode: probe every active job's sample
            # states (the pre-index walk).
            for jid in self.training.active_jobs(phase):
                js = live_scan.get(jid)
                if js is None:
                    continue
                shielded = 0
                for key in self.training.sample_keys(jid, phase):
                    if shielded >= quota:
                        break
                    if js.tasks[key].state is TaskState.RUNNING:
                        out.add(key)
                        shielded += 1
            return out
        # Only jobs with >=1 RUNNING sample can contribute — read the
        # Training module's running-sample index (sample-set order per
        # job) instead of probing every active job's sample states.
        for keys in self.training.running_sample_jobs(phase).values():
            shielded = 0
            for key in keys:
                if shielded >= quota:
                    break
                out.add(key)
                shielded += 1
        return out

    def _preempt_for(
        self,
        js: JobState,
        pos: int,
        phase: Phase,
        unmet: int,
        pos_of: dict[int, int],
        eager_ok: bool,
        protected,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Free up to ``unmet`` slots held by later-ordered jobs, walking
        the victim order (running jobs by schedule position) from the back
        (largest projected finish / size first).  Victims come straight
        from the incremental ``_run_by_job`` index — O(victims inspected),
        no pass-wide rebuild — and the walk stops at the first victim not
        ordered after ``pos``.  The preemptable-pool check skips the walk
        entirely once nothing unprotected is left running."""
        actions: list[Action] = []
        freed: list[SlotKey] = []
        if not self._pool_ok(phase, protected):
            return actions, freed
        mode = self.config.preemption
        wait_mode = mode is Preemption.WAIT or (
            mode is Preemption.EAGER and not eager_ok
        )
        pv = phase.value
        vorder = self._victim_order(phase, pos_of)
        self_id = js.spec.job_id
        for i in range(len(vorder) - 1, -1, -1):  # back-to-front
            if unmet <= 0:
                break
            vjid = vorder[i]
            if pos_of[vjid] <= pos:
                break  # ascending victim order: no later-ordered jobs left
            if vjid == self_id:
                continue
            bucket = self._run_by_job.get((vjid, pv))
            victims: list[TaskAttempt] | tuple = (
                list(bucket.values()) if bucket else ()
            )
            if victims and self.training.is_training(vjid, phase):
                # Prefer non-sample tasks: suspending a sample silently
                # cancels its runtime observation and stalls estimation.
                sample = set(self.training.sample_keys(vjid, phase))
                victims = sorted(
                    victims, key=lambda a: a.spec.key in sample
                )
            for att in victims:
                if unmet <= 0:
                    break
                key = att.spec.key
                if (
                    key in self._claimed
                    or att.state is not TaskState.RUNNING
                    or key in protected
                ):
                    continue
                if wait_mode:
                    self.stats.waits += 1
                    unmet -= 1  # we *would* preempt; count and move on
                    continue
                slot = self._slot_of.get(key)
                if slot is None:
                    continue
                self._claim(att)
                if mode is Preemption.EAGER:
                    actions.append(Suspend(att))
                    self.stats.suspensions += 1
                else:  # KILL
                    actions.append(Kill(att))
                    self.stats.kills += 1
                freed.append(slot)
                unmet -= 1
        return actions, freed

    def _resume_with_preemption(
        self,
        js: JobState,
        pos: int,
        phase: Phase,
        free: list[SlotKey],
        pos_of: dict[int, int],
        victim_memo: dict[int, list[tuple[int, TaskAttempt]]],
        victim_dead: set[int],
        eager_ok: bool,
        protected,
    ) -> tuple[list[Action], list[SlotKey]]:
        """Resume suspended tasks *on the machine that holds their state*
        (Sect. 3.3 "Impact on data locality"): free slot if available, else
        suspend a later-ordered job's task on that machine, else wait.

        Free slots are bucketed by machine once (O(free)) instead of being
        linearly scanned per suspended task; victims come from the
        incremental per-(machine, phase) run index, position-sorted at most
        once per machine per pass (``victim_memo``; the indexes are static
        during a pass, so the memo mirrors the old pass-wide snapshot)."""
        actions: list[Action] = []
        if not js.n_suspended(phase):
            return actions, free
        if not free and not eager_ok:
            return actions, free  # no slots and no preemption: nothing can move
        if not free and not self._pool_ok(phase, protected):
            # No slots and nothing unprotected left running: both the
            # free-slot and the victim path fail for every suspended task.
            return actions, free
        pv = phase.value
        # Potential-victim machines: machines hosting a running task of a
        # later-ordered job (only those can yield a slot via preemption).
        # Bounded collection: if later-running tasks outnumber this job's
        # suspended tasks, scanning the suspended tasks directly is
        # cheaper — fall back to the full scan (victim_machines=None).
        victim_machines: set[int] | None = set()
        if eager_ok:
            slot_of = self._slot_of
            n_later = 0
            budget = js.n_suspended(phase)
            # Walk the pass-cached victim order (running jobs ascending
            # by position) from the back: the later-ordered victims are
            # exactly its suffix, so the scan is O(min(later victims,
            # budget)) instead of O(running jobs) — same resulting set
            # (or the same None bail once later-running tasks outnumber
            # the suspended budget), since membership does not depend on
            # iteration order.
            vorder = self._victim_order(phase, pos_of)
            for i in range(len(vorder) - 1, -1, -1):
                vjid = vorder[i]
                if pos_of[vjid] <= pos:
                    break  # ascending order: no later-ordered jobs left
                bucket = self._run_by_job.get((vjid, pv))
                if not bucket:
                    continue
                n_later += len(bucket)
                if n_later > budget:
                    victim_machines = None
                    break
                for k in bucket:
                    victim_machines.add(slot_of[k].machine)
        if not free and victim_machines is not None and not victim_machines:
            # No free slot anywhere and no later-ordered job is running:
            # every suspended task would fail both the free-slot and the
            # victim path — provably a no-op, skip the O(suspended) scan
            # (the common steady state while a preempted job waits).
            return actions, free
        free_by_machine: dict[int, list[SlotKey]] = {}
        for s in free:
            free_by_machine.setdefault(s.machine, []).append(s)
        used: set[SlotKey] = set()
        claimed = self._claimed
        sbm = js.suspended_by_machine(phase)
        if victim_machines is None:
            # Full scan in suspension order (original path).
            candidates = js.suspended(phase)
        else:
            # Only machines that can actually act: a free slot to resume
            # into, or a later-ordered victim to displace.  A machine in
            # neither set is a provable no-op for every suspended task on
            # it (its victim walk would break on vpos <= pos immediately).
            candidates = []
            for m, bucket in sbm.items():
                if m in free_by_machine or m in victim_machines:
                    candidates.extend(bucket.values())
            candidates.sort(key=lambda a: a.susp_seq)
        for att in candidates:
            if att.spec.key in claimed:
                continue
            m = att.machine if att.machine is not None else -1
            slots = free_by_machine.get(m)
            if slots:
                slot = slots.pop(0)
                used.add(slot)
                self._claim(att)
                actions.append(Resume(att, slot))
                self.stats.resumes += 1
                continue
            if not eager_ok or m in victim_dead:
                continue
            # Largest-position (latest-finishing) victim on this machine.
            entries = victim_memo.get(m)
            if entries is None:
                entries = []
                bucket = self._run_by_machine.get((m, pv))
                if bucket:
                    for victim in bucket.values():
                        vp = pos_of.get(victim.spec.job_id)
                        if vp is not None:
                            entries.append((vp, victim))
                    entries.sort(key=lambda t: t[0])
                victim_memo[m] = entries
            found = False
            for vpos, victim in reversed(entries):
                if vpos <= pos:
                    break  # all remaining victims are earlier-ordered: wait
                vkey = victim.spec.key
                if (
                    vkey in claimed
                    or victim.state is not TaskState.RUNNING
                    or vkey in protected
                ):
                    continue
                vslot = self._slot_of.get(vkey)
                if vslot is None:
                    continue
                self._claim(victim)
                actions.append(Suspend(victim))
                self.stats.suspensions += 1
                self._claim(att)
                actions.append(Resume(att, vslot))
                self.stats.resumes += 1
                found = True
                break
            if not found:
                victim_dead.add(m)
        if used:
            free = [s for s in free if s not in used]
        return actions, free
