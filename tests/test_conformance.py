"""Golden-trace conformance: numpy vs jax virtual-cluster backends.

The jax kernels (repro.core.vcluster_jax) must be *behaviorally*
interchangeable with the numpy reference: identical completion times,
locality counters, and preemption stats on the golden FB traces, for every
scheduler.  fifo/fair carry no virtual cluster, so their rows pin that the
backend knob is inert where it should be; the hfsp variants exercise the
water-fill, projection, and batched cross-phase warm paths on every
scheduling pass.
"""

import pytest

from conformance import GOLDEN_SEEDS, TRACE_SCHEDULERS, assert_traces_equal, run_trace

pytest.importorskip("jax")


@pytest.mark.parametrize("seed", GOLDEN_SEEDS)
@pytest.mark.parametrize("name", TRACE_SCHEDULERS)
def test_backend_conformance(name, seed):
    ref = run_trace(name, seed, vc_backend="numpy")
    jax_run = run_trace(name, seed, vc_backend="jax")
    assert_traces_equal(ref, jax_run)
