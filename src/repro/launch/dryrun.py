import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis() / cost_analysis(), and emit the roofline terms.

The XLA_FLAGS line above MUST precede every other import — jax locks the
device count at first init.  This module is the ONLY place that forces 512
host devices; smoke tests and benchmarks see the real device count.

Per cell, THREE compiles:
1. full-depth, scan-over-layers  -> proves lowering/compile + memory fit;
2. depth u,  unrolled            -> cost sample 1   (u = layer-pattern period)
3. depth 2u, unrolled            -> cost sample 2
XLA's cost_analysis counts while-loop bodies once, so roofline costs come
from the unrolled samples, extrapolated linearly in depth (see
utils/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --both-meshes --out dryrun.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, input_specs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import decode_step, init_cache, init_model
from repro.serve.engine import make_prefill_step
from repro.sharding.specs import (
    batch_specs,
    cache_specs_sharding,
    param_specs,
    state_specs,
    to_named,
)
from repro.train import OptimizerConfig, TrainConfig, make_train_step
from repro.train.step import init_train_state
from repro.utils.roofline import (
    extrapolate_depth,
    measure_compiled,
    model_flops,
)


def _depth_unit(cfg) -> int:
    """Smallest depth whose per-layer costs repeat (the layer pattern)."""
    if cfg.family == "hybrid" and cfg.shared_attn_period:
        return cfg.shared_attn_period
    if cfg.local_global_period:
        return cfg.local_global_period
    return 1


def _at_depth(cfg, depth: int, *, scan: bool, seq_len: int = 4096):
    kw: dict = {"scan_layers": scan, "unroll_inner": not scan}
    if not scan and cfg.family in ("ssm", "hybrid"):
        # Coarser chunks keep the unrolled cost-sample graphs compilable
        # (<= 16 unrolled chunk blocks per layer); intra-chunk flops are
        # then an upper bound vs the deployed 64-wide kernel blocks —
        # noted in EXPERIMENTS.md §Roofline.
        kw["inner_chunk"] = max(256, seq_len // 16)
    if cfg.family == "encdec":
        kw.update(enc_layers=depth, dec_layers=depth, num_layers=2 * depth)
    else:
        kw.update(num_layers=depth)
    return dataclasses.replace(cfg, **kw)


def _full_depth(cfg) -> int:
    return cfg.enc_layers if cfg.family == "encdec" else cfg.num_layers


def _dp_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# §Perf hillclimb variants (see EXPERIMENTS.md §Perf):
#   serve_prefill     — prefill returns last-token logits only
#   moe_capacity      — shard dispatch-buffer capacity dim; replicate experts
#   zero_opt          — shard Adam moments' layer dim over data (ZeRO-2-ish)
VARIANTS: set = set()


def _build_jitted(cfg, shape, mesh):
    """(jitted, abstract_args) for this cell under this mesh."""
    if cfg.family == "moe":
        # Group-limited routing: dispatch stays local to each DP shard.
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        cfg = dataclasses.replace(
            cfg, moe_groups=_dp_size(mesh), moe_group_axis=dp
        )
        if "moe_capacity" in VARIANTS:
            cfg = dataclasses.replace(cfg, moe_capacity_axis="model")
    specs = input_specs(cfg, shape)
    bspecs = to_named(mesh, batch_specs(cfg, mesh, shape))
    if shape.kind == "train":
        state_shape = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0))
        )
        sspecs = to_named(
            mesh,
            state_specs(cfg, mesh, state_shape,
                        zero_opt="zero_opt" in VARIANTS),
        )
        step = make_train_step(cfg, OptimizerConfig(), TrainConfig())
        jitted = jax.jit(
            step, in_shardings=(sspecs, bspecs), out_shardings=(sspecs, None)
        )
        return jitted, (state_shape, specs)
    params_shape = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    pspecs = to_named(mesh, param_specs(cfg, mesh, params_shape))
    if shape.kind == "prefill":
        step = make_prefill_step(
            cfg, last_token_only="serve_prefill" in VARIANTS
        )
        jitted = jax.jit(step, in_shardings=(pspecs, bspecs), out_shardings=None)
        return jitted, (params_shape, specs)
    # decode
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )
    cspecs = to_named(mesh, cache_specs_sharding(cfg, mesh, shape, cache_shape))
    step = lambda p, t, pos, c: decode_step(cfg, p, t, pos, c)
    jitted = jax.jit(
        step,
        in_shardings=(pspecs, bspecs["tokens"], bspecs["positions"], cspecs),
        out_shardings=(None, cspecs),
    )
    return jitted, (params_shape, specs["tokens"], specs["positions"], cache_shape)


def _compile(cfg, shape, mesh):
    jitted, args = _build_jitted(cfg, shape, mesh)
    with mesh:
        lowered = jitted.lower(*args)
        return lowered.compile()


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, skip_cost: bool = False) -> dict:
    """Lower + compile one (arch, shape, mesh) cell; return the report."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "SKIP", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    report = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
              "chips": mesh.size}

    # 1) full-depth scan compile: lowering proof + memory analysis.
    t0 = time.time()
    compiled = _compile(cfg, shape, mesh)
    t_full = time.time() - t0
    mem = compiled.memory_analysis()
    report.update(
        status="OK",
        compile_s=round(t_full, 1),
        argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        output_bytes=getattr(mem, "output_size_in_bytes", None),
        temp_bytes=getattr(mem, "temp_size_in_bytes", None),
    )

    if not skip_cost and "cost_from_scan" in VARIANTS:
        # Fallback for archs whose unrolled cost samples exceed the CPU
        # host's compile budget (zamba2): measure the scan compile
        # directly.  Loop bodies are counted ONCE, so flops/bytes are a
        # LOWER bound — flagged in the report and §Roofline.
        roof = measure_compiled(compiled)
        mflops = model_flops(cfg, shape, backward=(shape.kind == "train"))
        report.update(
            cost_method="scan_lower_bound",
            **roof.summary(),
            collective_counts=roof.collectives.count_by_op,
            model_flops=mflops,
            useful_ratio=None,
        )
        if verbose:
            print(f"  cost (scan LOWER BOUND): flops={roof.flops:.3e} "
                  f"dominant={roof.dominant}")
        return report

    if not skip_cost:
        # 2+3) unrolled cost samples at depths u and 2u -> extrapolate.
        u = _depth_unit(cfg)
        t0 = time.time()
        r1 = measure_compiled(
            _compile(_at_depth(cfg, u, scan=False, seq_len=shape.seq_len),
                     shape, mesh)
        )
        r2 = measure_compiled(
            _compile(_at_depth(cfg, 2 * u, scan=False, seq_len=shape.seq_len),
                     shape, mesh)
        )
        roof = extrapolate_depth(r1, r2, u, _full_depth(cfg))
        t_cost = time.time() - t0
        mflops = model_flops(cfg, shape, backward=(shape.kind == "train"))
        hlo_global = roof.flops * mesh.size
        report.update(
            cost_compile_s=round(t_cost, 1),
            **roof.summary(),
            collective_counts=roof.collectives.count_by_op,
            collective_bytes_by_op={
                k: round(v) for k, v in roof.collectives.bytes_by_op.items()
            },
            model_flops=mflops,
            useful_ratio=(mflops / hlo_global) if hlo_global else None,
        )

    if verbose:
        print(f"[{arch} x {shape_name} x {'2pod' if multi_pod else '1pod'}] OK "
              f"compile={report['compile_s']}s", flush=True)
        print(f"  memory_analysis/device: args={report['argument_bytes']:,} "
              f"temp={report['temp_bytes']:,} out={report['output_bytes']:,}")
        if not skip_cost:
            print(f"  cost_analysis/device (depth-extrapolated): "
                  f"flops={report['flops']:.3e} bytes={report['bytes']:.3e}")
            print(f"  collectives: {report['collective_counts']} "
                  f"wire_bytes={report['coll_bytes']:.3e}")
            print(f"  roofline: compute={report['compute_s']*1e3:.2f}ms "
                  f"memory={report['memory_s']*1e3:.2f}ms "
                  f"collective={report['collective_s']*1e3:.2f}ms "
                  f"dominant={report['dominant']} "
                  f"useful={report['useful_ratio']:.3f}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-cost", action="store_true",
                    help="lowering/memory proof only (multi-pod pass)")
    ap.add_argument("--variants", default="",
                    help="comma-separated §Perf variants: "
                         "serve_prefill,moe_capacity,zero_opt")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    VARIANTS.update(v for v in args.variants.split(",") if v)
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]

    reports = []
    failed = 0
    for a, s, m in cells:
        try:
            reports.append(
                lower_cell(a, s, multi_pod=m, skip_cost=args.skip_cost)
            )
        except Exception as e:  # a failure here is a bug in our sharding
            failed += 1
            traceback.print_exc()
            reports.append({"arch": a, "shape": s, "multi_pod": m,
                            "status": "FAIL", "error": str(e)[-2000:]})
        if args.out:  # incremental write: long sweeps survive interruption
            with open(args.out, "w") as f:
                json.dump(reports, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=1)
    n_ok = sum(1 for r in reports if r["status"] == "OK")
    n_skip = sum(1 for r in reports if r["status"] == "SKIP")
    print(f"\ndry-run: {n_ok} OK, {n_skip} SKIP, {failed} FAIL "
          f"of {len(reports)} cells")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
